"""Serve-engine speedup + SLO latency: fused windows vs the seed path.

Runs the same mixed workload (staggered arrivals, uneven prompt/output
lengths, all-greedy for parity) through the fused ``Engine`` — once per
decode-attention implementation (``xla`` jnp path, ``pallas_decode``
blocked kernel with fused KV scatter, interpret mode on CPU) — and
through ``EngineReference`` (the seed per-tick path: per-token prefill,
one host round-trip per tick).  Each leg verifies token-for-token greedy
parity against the reference and appends its OWN record to
``BENCH_serve.json`` with ``leg``/``attn_impl`` fields, so a future
regression is attributable to the kernel or to the engine.  Every timing
loop blocks on the engine's device state before reading the clock
(``clock: "blocking"`` in the records — benchmarks/gate.py ratchets the
per-leg speedups against history).  Floors enforced here (and in CI):
parity must hold and the warm speedup must be >= 10x on every leg.

A final ``poisson_burst`` leg drives the warm xla engine with the real
traffic generator — Poisson arrivals with sinusoidal burst modulation,
lognormal heavy-tailed prompt/output lengths, admission by arrival tick
— and lands TTFT / TPOT / end-to-end p50/p95/p99 percentiles (wall-clock
AND tick-domain, serve/telemetry.py) in the ``latest`` record, plus a
scheduling-independence parity check (bursty arrivals must not change
greedy outputs).

The xla-leg record also carries the engine's serve-mode NVM verdicts —
the decode-tick SRAM vs STT/SOT energy/EDP ratios from the measured
traffic (core.crosslayer.analyze_serve), closing the loop to the paper.
"""
from __future__ import annotations

import time
from datetime import datetime, timezone
from pathlib import Path

import jax

from benchmarks.common import append_bench_record, emit
from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serve import (Engine, EngineReference, latency_summary,
                         mixed_requests, poisson_requests, run_arrivals,
                         run_staggered, staggered_groups)

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_serve.json"

ARCH = "llama3-8b"
SLOTS = 4
MAX_LEN = 64
TICKS_PER_SYNC = 8
N_REQUESTS = 16
PROMPT_LENS = (32, 56)       # serving is prompt-heavy; the seed prefills
MAX_NEW = (4, 10)            # these one decode_step call per prompt token
SPEEDUP_FLOOR = 10.0
ATTN_IMPLS = ("xla", "pallas_decode")

# poisson_burst leg: heavy-tailed lengths under a bursty arrival process
N_TRAFFIC = 32
ARRIVAL_RATE = 0.5           # mean arrivals per decode tick
BURST_AMP = 0.6
BURST_PERIOD = 48.0
TRAFFIC_PROMPTS = (2, 24)
TRAFFIC_NEW = (1, 12)


def _workload(seed: int):
    return mixed_requests(N_REQUESTS, seed=seed, vocab=512,
                          prompt_lens=PROMPT_LENS, max_new=MAX_NEW)


def _drive(engine, seed: int):
    out = run_staggered(engine, staggered_groups(_workload(seed), SLOTS))
    _block(engine)
    return out


def _block(engine):
    """Block on the engine's device state before stopping any timer —
    outputs are host ints already, but this pins the discipline even if
    a future engine keeps results device-side past the drain."""
    jax.block_until_ready(engine.cache)
    state = getattr(engine, "_state", None)
    if state is not None:
        jax.block_until_ready(state)


def _traffic(seed: int):
    return poisson_requests(
        N_TRAFFIC, seed=seed, vocab=512, arrival_rate=ARRIVAL_RATE,
        burst_amp=BURST_AMP, burst_period=BURST_PERIOD,
        prompt_bounds=TRAFFIC_PROMPTS, new_bounds=TRAFFIC_NEW)


def _base_record(**extra):
    rec = {
        "timestamp": datetime.now(timezone.utc).isoformat(),
        "grid": (f"{N_REQUESTS} reqs x prompts {PROMPT_LENS} x new "
                 f"{MAX_NEW} on {SLOTS} slots, max_len {MAX_LEN}, "
                 f"K={TICKS_PER_SYNC} ({ARCH} reduced)"),
    }
    rec.update(extra)
    return rec


def _latency_leg(eng, failures):
    """Bursty-traffic latency percentiles on the warm xla engine."""
    eng.reset()
    reqs = _traffic(seed=2)
    t0 = time.perf_counter()
    out = run_arrivals(eng, reqs)
    _block(eng)
    burst_s = time.perf_counter() - t0
    summary = latency_summary(reqs)

    # scheduling independence: the same prompts all at once must decode
    # to the same greedy tokens the bursty schedule produced
    eng.reset()
    out_flat = run_staggered(eng, [list(_traffic(seed=2))])
    bursty_parity = out == out_flat

    record = _base_record(
        grid=(f"{N_TRAFFIC} poisson reqs, rate {ARRIVAL_RATE}/tick, "
              f"burst amp {BURST_AMP} period {BURST_PERIOD}, prompts "
              f"{TRAFFIC_PROMPTS} new {TRAFFIC_NEW} on {SLOTS} slots, "
              f"K={TICKS_PER_SYNC} ({ARCH} reduced)"),
        leg="poisson_burst",
        attn_impl=eng.attn_impl,
        arrival_rate=ARRIVAL_RATE,
        burst_amp=BURST_AMP,
        burst_period=BURST_PERIOD,
        burst_wall_s=burst_s,
        engine_ticks=eng.ticks,
        latency=summary,
        bursty_parity=bursty_parity,
    )
    append_bench_record(BENCH_PATH, record)
    lat = summary["ticks"]["e2e"]
    emit("serve_latency_poisson", burst_s * 1e6,
         f"ttft p50 {summary['ticks']['ttft']['p50']:.1f}t p99 "
         f"{summary['ticks']['ttft']['p99']:.1f}t | e2e p50 "
         f"{lat['p50']:.1f}t p99 {lat['p99']:.1f}t | parity="
         f"{'ok' if bursty_parity else 'MISMATCH'} -> {BENCH_PATH.name}")
    if not bursty_parity:
        failures.append("poisson_burst: bursty arrival schedule changed "
                        "greedy outputs (scheduling independence broken)")
    if summary["completed"] != N_TRAFFIC or not summary["wall"]:
        failures.append("poisson_burst: latency percentiles empty or "
                        f"incomplete ({summary['completed']}/{N_TRAFFIC})")


def run():
    cfg = reduced(get_config(ARCH), dtype="float32")
    model = build_model(cfg, max_seq=MAX_LEN)
    params = model.init(jax.random.PRNGKey(0))

    ref = EngineReference(model, params, slots=SLOTS, max_len=MAX_LEN)
    _drive(ref, seed=0)                       # warm the decode jit
    legacy_s = 1e9                            # min-of-2: favors the seed path
    for _ in range(2):
        ref.reset()
        t0 = time.perf_counter()
        out_ref = _drive(ref, seed=1)         # _drive blocks before return
        legacy_s = min(legacy_s, time.perf_counter() - t0)
    tokens = sum(len(o) for o in out_ref.values())
    ref_tps = tokens / legacy_s

    failures = []
    xla_engine = None
    for attn_impl in ATTN_IMPLS:
        eng = Engine(model, params, slots=SLOTS, max_len=MAX_LEN,
                     ticks_per_sync=TICKS_PER_SYNC,
                     record_traffic=(attn_impl == "xla"),
                     attn_impl=attn_impl)
        if attn_impl == "xla":
            xla_engine = eng
        t0 = time.perf_counter()
        _drive(eng, seed=0)                   # cold: compiles + traffic
        cold_s = time.perf_counter() - t0

        engine_s, out_eng = 1e9, None
        for _ in range(3):
            eng.reset()
            t0 = time.perf_counter()
            out_eng = _drive(eng, seed=1)
            engine_s = min(engine_s, time.perf_counter() - t0)

        parity = out_eng == out_ref
        eng_tps = tokens / engine_s
        speedup = legacy_s / engine_s
        verdicts = {
            v.shape: {"energy_ratio": v.energy_ratio,
                      "edp_ratio": v.edp_ratio}
            for v in eng.nvm_verdicts()}

        record = _base_record(
            leg=attn_impl,
            attn_impl=attn_impl,
            engine_s=engine_s,
            engine_cold_s=cold_s,
            legacy_per_tick_s=legacy_s,
            warm_tokens_per_s=eng_tps,
            reference_tokens_per_s=ref_tps,
            speedup=speedup,
            speedup_floor=SPEEDUP_FLOOR,
            greedy_parity=parity,
            nvm_verdicts=verdicts,
        )
        append_bench_record(BENCH_PATH, record)

        emit(f"serve_engine_{attn_impl}", engine_s * 1e6,
             f"ref {ref_tps:.0f} tok/s -> fused {eng_tps:.0f} tok/s = "
             f"{speedup:.1f}x | parity={'ok' if parity else 'MISMATCH'} | "
             f"-> {BENCH_PATH.name}")
        if not parity:
            failures.append(
                f"{attn_impl}: fused engine greedy tokens diverge from "
                "engine_reference")
        if speedup < SPEEDUP_FLOOR:
            failures.append(
                f"{attn_impl}: serve engine speedup {speedup:.1f}x below "
                f"the {SPEEDUP_FLOOR:.0f}x floor")

    # appended last so BENCH_serve.json's ``latest`` carries the SLO
    # percentiles for the bursty workload
    _latency_leg(xla_engine, failures)
    if failures:
        raise AssertionError("; ".join(failures))


if __name__ == "__main__":
    run()
