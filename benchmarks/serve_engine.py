"""Serve-engine speedup: fused device-resident windows vs the seed path.

Runs the same mixed workload (staggered arrivals, uneven prompt/output
lengths, all-greedy for parity) through the fused ``Engine`` — once per
decode-attention implementation (``xla`` jnp path, ``pallas_decode``
blocked kernel with fused KV scatter, interpret mode on CPU) — and
through ``EngineReference`` (the seed per-tick path: per-token prefill,
one host round-trip per tick).  Each leg verifies token-for-token greedy
parity against the reference and appends its OWN record to
``BENCH_serve.json`` with an ``attn_impl`` field, so a future regression
is attributable to the kernel or to the engine.  Floors enforced here
(and in CI): parity must hold and the warm speedup must be >= 10x on
every leg.

The xla-leg record also carries the engine's serve-mode NVM verdicts —
the decode-tick SRAM vs STT/SOT energy/EDP ratios from the measured
traffic (core.crosslayer.analyze_serve), closing the loop to the paper.
"""
from __future__ import annotations

import time
from datetime import datetime, timezone
from pathlib import Path

import jax

from benchmarks.common import append_bench_record, emit
from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serve import (Engine, EngineReference, mixed_requests,
                         run_staggered, staggered_groups)

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_serve.json"

ARCH = "llama3-8b"
SLOTS = 4
MAX_LEN = 64
TICKS_PER_SYNC = 8
N_REQUESTS = 16
PROMPT_LENS = (32, 56)       # serving is prompt-heavy; the seed prefills
MAX_NEW = (4, 10)            # these one decode_step call per prompt token
SPEEDUP_FLOOR = 10.0
ATTN_IMPLS = ("xla", "pallas_decode")


def _workload(seed: int):
    return mixed_requests(N_REQUESTS, seed=seed, vocab=512,
                          prompt_lens=PROMPT_LENS, max_new=MAX_NEW)


def _drive(engine, seed: int):
    return run_staggered(engine, staggered_groups(_workload(seed), SLOTS))


def run():
    cfg = reduced(get_config(ARCH), dtype="float32")
    model = build_model(cfg, max_seq=MAX_LEN)
    params = model.init(jax.random.PRNGKey(0))

    ref = EngineReference(model, params, slots=SLOTS, max_len=MAX_LEN)
    _drive(ref, seed=0)                       # warm the decode jit
    legacy_s = 1e9                            # min-of-2: favors the seed path
    for _ in range(2):
        ref.reset()
        t0 = time.perf_counter()
        out_ref = _drive(ref, seed=1)
        legacy_s = min(legacy_s, time.perf_counter() - t0)
    tokens = sum(len(o) for o in out_ref.values())
    ref_tps = tokens / legacy_s

    failures = []
    for attn_impl in ATTN_IMPLS:
        eng = Engine(model, params, slots=SLOTS, max_len=MAX_LEN,
                     ticks_per_sync=TICKS_PER_SYNC,
                     record_traffic=(attn_impl == "xla"),
                     attn_impl=attn_impl)
        t0 = time.perf_counter()
        _drive(eng, seed=0)                   # cold: compiles + traffic
        cold_s = time.perf_counter() - t0

        engine_s, out_eng = 1e9, None
        for _ in range(3):
            eng.reset()
            t0 = time.perf_counter()
            out_eng = _drive(eng, seed=1)
            engine_s = min(engine_s, time.perf_counter() - t0)

        parity = out_eng == out_ref
        eng_tps = tokens / engine_s
        speedup = legacy_s / engine_s
        verdicts = {
            v.shape: {"energy_ratio": v.energy_ratio,
                      "edp_ratio": v.edp_ratio}
            for v in eng.nvm_verdicts()}

        record = {
            "timestamp": datetime.now(timezone.utc).isoformat(),
            "grid": (f"{N_REQUESTS} reqs x prompts {PROMPT_LENS} x new "
                     f"{MAX_NEW} on {SLOTS} slots, max_len {MAX_LEN}, "
                     f"K={TICKS_PER_SYNC} ({ARCH} reduced)"),
            "attn_impl": attn_impl,
            "engine_s": engine_s,
            "engine_cold_s": cold_s,
            "legacy_per_tick_s": legacy_s,
            "warm_tokens_per_s": eng_tps,
            "reference_tokens_per_s": ref_tps,
            "speedup": speedup,
            "speedup_floor": SPEEDUP_FLOOR,
            "greedy_parity": parity,
            "nvm_verdicts": verdicts,
        }
        append_bench_record(BENCH_PATH, record)

        emit(f"serve_engine_{attn_impl}", engine_s * 1e6,
             f"ref {ref_tps:.0f} tok/s -> fused {eng_tps:.0f} tok/s = "
             f"{speedup:.1f}x | parity={'ok' if parity else 'MISMATCH'} | "
             f"-> {BENCH_PATH.name}")
        if not parity:
            failures.append(
                f"{attn_impl}: fused engine greedy tokens diverge from "
                "engine_reference")
        if speedup < SPEEDUP_FLOOR:
            failures.append(
                f"{attn_impl}: serve engine speedup {speedup:.1f}x below "
                f"the {SPEEDUP_FLOOR:.0f}x floor")
    if failures:
        raise AssertionError("; ".join(failures))


if __name__ == "__main__":
    run()
