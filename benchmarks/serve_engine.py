"""Serve-engine speedup + SLO latency: fused windows vs the seed path.

Runs the same mixed workload (staggered arrivals, uneven prompt/output
lengths, all-greedy for parity) through the fused ``Engine`` — once per
decode-attention implementation (``xla`` jnp path, ``pallas_decode``
blocked kernel with fused KV scatter, interpret mode on CPU) — and
through ``EngineReference`` (the seed per-tick path: per-token prefill,
one host round-trip per tick).  Each leg verifies token-for-token greedy
parity against the reference and appends its OWN record to
``BENCH_serve.json`` with ``leg``/``attn_impl`` fields, so a future
regression is attributable to the kernel or to the engine.  Every timing
loop blocks on the engine's device state before reading the clock
(``clock: "blocking"`` in the records — benchmarks/gate.py ratchets the
per-leg speedups against history).  Floors enforced here (and in CI):
parity must hold and the warm speedup must be >= 10x on every leg.

A final ``poisson_burst`` leg drives the warm xla engine with the real
traffic generator — Poisson arrivals with sinusoidal burst modulation,
lognormal heavy-tailed prompt/output lengths, admission by arrival tick
— and lands TTFT / TPOT / end-to-end p50/p95/p99 percentiles (wall-clock
AND tick-domain, serve/telemetry.py) in the ``latest`` record, plus a
scheduling-independence parity check (bursty arrivals must not change
greedy outputs).

Two paged-KV legs land the DESIGN.md §15 claims in the same file:
``paged`` runs the PagedEngine + Pallas paged kernel on the mixed
workload (parity + an informational wall floor on ordinary traffic),
and ``paged_shared_prefix`` asserts the headline wins on a
shared-prefix template workload — mean tick-TTFT >= 1.5x lower than the
dense engine at equal slots (prefill charged to the tick clock on both,
the paged engine prefills only unshared suffixes), and 2x the slots
served to bitwise completion from a page pool holding exactly the dense
engine's KV rows.  The shared-prefix leg's gated ``speedup`` is the
deterministic tick-domain TTFT ratio, so the gate.py ratchet guards the
prefix-sharing win itself without wall-clock flake.

The xla-leg record also carries the engine's serve-mode NVM verdicts —
the decode-tick SRAM vs STT/SOT energy/EDP ratios from the measured
traffic (core.crosslayer.analyze_serve), closing the loop to the paper.

Per-family legs (``leg="ssm"/"hybrid"/"encdec"``, ISSUE 10) run the
slot-bank families — mamba2, recurrentgemma, whisper — through the same
mixed workload with greedy parity gated at K=1 and K=4, warm tokens/s
floors, and family-tagged NVM verdicts (recurrent records score under
their own write-heavier read/write split).
"""
from __future__ import annotations

import copy
import time
from datetime import datetime, timezone
from pathlib import Path

import jax

from benchmarks.common import append_bench_record, emit
from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serve import (Engine, EngineReference, PagedEngine,
                         latency_summary, mixed_requests, poisson_requests,
                         run_arrivals, run_staggered, shared_prefix_requests,
                         staggered_groups)

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_serve.json"

ARCH = "llama3-8b"
SLOTS = 4
MAX_LEN = 64
TICKS_PER_SYNC = 8
N_REQUESTS = 16
PROMPT_LENS = (32, 56)       # serving is prompt-heavy; the seed prefills
MAX_NEW = (4, 10)            # these one decode_step call per prompt token
SPEEDUP_FLOOR = 10.0
ATTN_IMPLS = ("xla", "pallas_decode")

# paged-KV legs (DESIGN.md §15): radix-tree prefix sharing on a
# shared-prefix template workload.  The template length is deliberately
# off the page grid so every admission wave exercises boundary CoW.
# The TTFT claim runs dense vs paged at EQUAL slots with prefill
# charged to the tick clock on BOTH engines; the capacity claim runs the
# paged engine at 2x the slots from a page pool whose total rows
# (pages incl. the trash page x page_size) EQUAL the dense engine's KV
# rows (slots x max_len).
PAGE_SIZE = 8
NB = MAX_LEN // PAGE_SIZE
N_SHARED = 16
N_TEMPLATES = 2              # 2 hot templates -> most waves share heavily
SHARED_TEMPLATE_LEN = 46     # off the page grid (46 % 8 == 6) forces CoW;
SHARED_SUFFIX = (2, 8)       # 46 + 8 + max_new 10 == MAX_LEN exactly
TTFT_RATIO_FLOOR = 1.5       # paged mean tick-TTFT must beat dense by this
CAPACITY_FACTOR = 2          # slots served at equal KV memory
# the Pallas paged kernel runs in interpret mode on CPU: its wall
# timings are too volatile for the gate's ratchet (observed 5-11x vs
# the reference across back-to-back runs), so the paged legs keep wall
# numbers as INFORMATIONAL ``wall_speedup`` fields with a loose in-bench
# floor, and the gated ``speedup`` metric on the shared-prefix leg is
# the DETERMINISTIC tick-domain TTFT ratio (bit-stable across runs)
PAGED_WALL_FLOOR = 3.0

# per-family legs (ISSUE 10): each slot-bank family (mamba2 recurrent
# conv+SSD state, recurrentgemma RG-LRU + local-attention rings, whisper
# per-row encoder output + decoder KV) serves the same mixed workload
# through Engine vs EngineReference.  Greedy parity at K=1 AND K=4 is
# the gated flag; warm tokens/s carries an absolute floor (recurrent
# prefill is a sequential masked scan, so the speedup floor sits far
# below the dense legs' — the reference pays the same per-token work
# PLUS a host round-trip per token).
FAMILY_ARCHS = (("ssm", "mamba2-1.3b"), ("hybrid", "recurrentgemma-2b"),
                ("encdec", "whisper-tiny"))
FAMILY_K = 4
FAMILY_SPEEDUP_FLOOR = 2.0
FAMILY_TPS_FLOOR = 200.0     # ~1/4 of the slowest measured leg (encdec ~850)

# poisson_burst leg: heavy-tailed lengths under a bursty arrival process
N_TRAFFIC = 32
ARRIVAL_RATE = 0.5           # mean arrivals per decode tick
BURST_AMP = 0.6
BURST_PERIOD = 48.0
TRAFFIC_PROMPTS = (2, 24)
TRAFFIC_NEW = (1, 12)


def _workload(seed: int):
    return mixed_requests(N_REQUESTS, seed=seed, vocab=512,
                          prompt_lens=PROMPT_LENS, max_new=MAX_NEW)


def _drive(engine, seed: int):
    out = run_staggered(engine, staggered_groups(_workload(seed), SLOTS))
    _block(engine)
    return out


def _block(engine):
    """Block on the engine's device state before stopping any timer —
    outputs are host ints already, but this pins the discipline even if
    a future engine keeps results device-side past the drain."""
    jax.block_until_ready(engine.cache)
    state = getattr(engine, "_state", None)
    if state is not None:
        jax.block_until_ready(state)


def _traffic(seed: int):
    return poisson_requests(
        N_TRAFFIC, seed=seed, vocab=512, arrival_rate=ARRIVAL_RATE,
        burst_amp=BURST_AMP, burst_period=BURST_PERIOD,
        prompt_bounds=TRAFFIC_PROMPTS, new_bounds=TRAFFIC_NEW)


def _base_record(**extra):
    rec = {
        "timestamp": datetime.now(timezone.utc).isoformat(),
        "grid": (f"{N_REQUESTS} reqs x prompts {PROMPT_LENS} x new "
                 f"{MAX_NEW} on {SLOTS} slots, max_len {MAX_LEN}, "
                 f"K={TICKS_PER_SYNC} ({ARCH} reduced)"),
    }
    rec.update(extra)
    return rec


def _latency_leg(eng, failures):
    """Bursty-traffic latency percentiles on the warm xla engine."""
    eng.reset()
    reqs = _traffic(seed=2)
    t0 = time.perf_counter()
    out = run_arrivals(eng, reqs)
    _block(eng)
    burst_s = time.perf_counter() - t0
    summary = latency_summary(reqs)

    # scheduling independence: the same prompts all at once must decode
    # to the same greedy tokens the bursty schedule produced
    eng.reset()
    out_flat = run_staggered(eng, [list(_traffic(seed=2))])
    bursty_parity = out == out_flat

    record = _base_record(
        grid=(f"{N_TRAFFIC} poisson reqs, rate {ARRIVAL_RATE}/tick, "
              f"burst amp {BURST_AMP} period {BURST_PERIOD}, prompts "
              f"{TRAFFIC_PROMPTS} new {TRAFFIC_NEW} on {SLOTS} slots, "
              f"K={TICKS_PER_SYNC} ({ARCH} reduced)"),
        leg="poisson_burst",
        attn_impl=eng.attn_impl,
        arrival_rate=ARRIVAL_RATE,
        burst_amp=BURST_AMP,
        burst_period=BURST_PERIOD,
        burst_wall_s=burst_s,
        engine_ticks=eng.ticks,
        latency=summary,
        bursty_parity=bursty_parity,
    )
    append_bench_record(BENCH_PATH, record)
    lat = summary["ticks"]["e2e"]
    emit("serve_latency_poisson", burst_s * 1e6,
         f"ttft p50 {summary['ticks']['ttft']['p50']:.1f}t p99 "
         f"{summary['ticks']['ttft']['p99']:.1f}t | e2e p50 "
         f"{lat['p50']:.1f}t p99 {lat['p99']:.1f}t | parity="
         f"{'ok' if bursty_parity else 'MISMATCH'} -> {BENCH_PATH.name}")
    if not bursty_parity:
        failures.append("poisson_burst: bursty arrival schedule changed "
                        "greedy outputs (scheduling independence broken)")
    if summary["completed"] != N_TRAFFIC or not summary["wall"]:
        failures.append("poisson_burst: latency percentiles empty or "
                        f"incomplete ({summary['completed']}/{N_TRAFFIC})")


def _shared_workload():
    return shared_prefix_requests(
        N_SHARED, seed=3, vocab=512, num_templates=N_TEMPLATES,
        template_len=SHARED_TEMPLATE_LEN, suffix_lens=SHARED_SUFFIX,
        max_new=MAX_NEW)


def _paged_leg(model, params, out_ref, legacy_s, tokens, failures):
    """Paged engine + Pallas paged kernel on the SAME mixed workload as
    the dense legs: parity vs the reference and the warm speedup ratchet
    (leg="paged").  No prefixes are shared here — this pins the paged
    path's correctness and cost on ordinary traffic."""
    eng = PagedEngine(model, params, slots=SLOTS, max_len=MAX_LEN,
                      page_size=PAGE_SIZE, ticks_per_sync=TICKS_PER_SYNC,
                      record_traffic=True, attn_impl="pallas_paged")
    t0 = time.perf_counter()
    _drive(eng, seed=0)
    cold_s = time.perf_counter() - t0

    engine_s, out_eng = 1e9, None
    for _ in range(3):
        eng.reset()
        t0 = time.perf_counter()
        out_eng = _drive(eng, seed=1)
        engine_s = min(engine_s, time.perf_counter() - t0)

    parity = out_eng == out_ref
    wall_speedup = legacy_s / engine_s
    st = eng.paged_stats()
    verdicts = {v.shape: {"energy_ratio": v.energy_ratio,
                          "edp_ratio": v.edp_ratio}
                for v in eng.nvm_verdicts()}
    upf = [r.get("unique_page_fraction") for r in eng.serve_records()
           if "unique_page_fraction" in r]

    record = _base_record(
        leg="paged",
        attn_impl="pallas_paged",
        page_size=PAGE_SIZE,
        num_pages=eng.num_pages,
        engine_s=engine_s,
        engine_cold_s=cold_s,
        legacy_per_tick_s=legacy_s,
        warm_tokens_per_s=tokens / engine_s,
        wall_speedup=wall_speedup,
        wall_speedup_floor=PAGED_WALL_FLOOR,
        greedy_parity=parity,
        paged_stats=st,
        unique_page_fraction=(upf[0] if upf else None),
        nvm_verdicts=verdicts,
    )
    append_bench_record(BENCH_PATH, record)
    emit("serve_engine_paged", engine_s * 1e6,
         f"paged pool {st['pages_hwm']}/{eng.num_pages} pages hwm = "
         f"{wall_speedup:.1f}x vs ref | "
         f"parity={'ok' if parity else 'MISMATCH'}"
         f" | -> {BENCH_PATH.name}")
    if not parity:
        failures.append("paged: paged engine greedy tokens diverge from "
                        "engine_reference on the mixed workload")
    if wall_speedup < PAGED_WALL_FLOOR:
        failures.append(f"paged: wall speedup {wall_speedup:.1f}x below "
                        f"the {PAGED_WALL_FLOOR:.0f}x floor")


def _shared_prefix_leg(model, params, ref, failures):
    """The headline prefix-sharing claims (leg="paged_shared_prefix"):

      * TTFT: dense vs paged at EQUAL slots on the shared-prefix
        workload, prefill charged to the tick clock on both — the paged
        engine prefills only unshared suffixes, so its mean tick-TTFT
        must be >= TTFT_RATIO_FLOOR lower.
      * Capacity: the paged engine serves CAPACITY_FACTOR x the slots
        to completion (bitwise parity) from a page pool holding EXACTLY
        the dense engine's KV rows.
    """
    reqs = _shared_workload()
    groups = lambda rs: staggered_groups(rs, SLOTS)  # noqa: E731

    ref.reset()
    legacy_s, out_ref = 1e9, None
    for _ in range(2):
        ref.reset()
        rr = copy.deepcopy(reqs)
        t0 = time.perf_counter()
        out_ref = run_staggered(ref, groups(rr))
        _block(ref)
        legacy_s = min(legacy_s, time.perf_counter() - t0)

    dense = Engine(model, params, slots=SLOTS, max_len=MAX_LEN,
                   ticks_per_sync=TICKS_PER_SYNC, record_traffic=False,
                   charge_prefill_ticks=True)
    rd = copy.deepcopy(reqs)
    dense_parity = run_staggered(dense, groups(rd)) == out_ref
    ttft_dense = latency_summary(rd)["ticks"]["ttft"]["mean"]

    paged = PagedEngine(model, params, slots=SLOTS, max_len=MAX_LEN,
                        page_size=PAGE_SIZE, ticks_per_sync=TICKS_PER_SYNC,
                        record_traffic=False, charge_prefill_ticks=True,
                        attn_impl="pallas_paged")
    _drive(paged, seed=0)                 # warm the jits on mixed traffic
    paged_s, rp = 1e9, None
    for _ in range(2):
        paged.reset()
        rp = copy.deepcopy(reqs)
        t0 = time.perf_counter()
        out_paged = run_staggered(paged, groups(rp))
        _block(paged)
        paged_s = min(paged_s, time.perf_counter() - t0)
    paged_parity = out_paged == out_ref
    ttft_paged = latency_summary(rp)["ticks"]["ttft"]["mean"]
    ttft_ratio = ttft_dense / ttft_paged if ttft_paged > 0 else float("inf")
    st = paged.paged_stats()
    wall_speedup = legacy_s / paged_s

    # equal-KV-memory capacity: pool rows (incl. trash page) == dense rows
    cap_pages = SLOTS * NB - 1
    cap_rows = (cap_pages + 1) * PAGE_SIZE
    assert cap_rows == SLOTS * MAX_LEN
    big = PagedEngine(model, params, slots=CAPACITY_FACTOR * SLOTS,
                      max_len=MAX_LEN, page_size=PAGE_SIZE,
                      num_pages=cap_pages, ticks_per_sync=TICKS_PER_SYNC,
                      record_traffic=False, attn_impl="pallas_paged")
    rc = copy.deepcopy(reqs)
    # run_staggered raises if anything fails to finish: completion at
    # equal KV memory IS the capacity claim, parity makes it bitwise
    cap_parity = run_staggered(
        big, staggered_groups(rc, CAPACITY_FACTOR * SLOTS)) == out_ref
    cap_st = big.paged_stats()

    record = _base_record(
        grid=(f"{N_SHARED} reqs x {N_TEMPLATES} templates of "
              f"{SHARED_TEMPLATE_LEN} tokens + suffixes {SHARED_SUFFIX} "
              f"x new {MAX_NEW} on {SLOTS} "
              f"slots, max_len {MAX_LEN}, page_size {PAGE_SIZE}, "
              f"K={TICKS_PER_SYNC} ({ARCH} reduced)"),
        leg="paged_shared_prefix",
        attn_impl="pallas_paged",
        page_size=PAGE_SIZE,
        engine_s=paged_s,
        legacy_per_tick_s=legacy_s,
        # the GATED metric: deterministic tick-domain TTFT win (the
        # gate ratchets ``speedup`` per leg; wall time would flake)
        speedup=ttft_ratio,
        speedup_domain="ticks",
        wall_speedup=wall_speedup,
        ttft_dense_ticks=ttft_dense,
        ttft_paged_ticks=ttft_paged,
        ttft_ratio=ttft_ratio,
        ttft_ratio_floor=TTFT_RATIO_FLOOR,
        greedy_parity=paged_parity and dense_parity,
        paged_stats=st,
        capacity={
            "slots": CAPACITY_FACTOR * SLOTS,
            "slots_factor": CAPACITY_FACTOR,
            "num_pages": cap_pages,
            "kv_rows": cap_rows,
            "dense_kv_rows": SLOTS * MAX_LEN,
            "greedy_parity": cap_parity,
            "pages_hwm": cap_st["pages_hwm"],
            "deferred": cap_st["deferred"],
            "evicted_pages": cap_st["evicted_pages"],
        },
    )
    append_bench_record(BENCH_PATH, record)
    emit("serve_engine_paged_shared_prefix", paged_s * 1e6,
         f"ttft {ttft_dense:.1f}t -> {ttft_paged:.1f}t = "
         f"{ttft_ratio:.2f}x (floor {TTFT_RATIO_FLOOR}x) | hit rate "
         f"{st['prefix_hit_rate']:.2f}, CoW {st['cow_copies']} | "
         f"{CAPACITY_FACTOR}x slots at {cap_rows} KV rows parity="
         f"{'ok' if cap_parity else 'MISMATCH'} -> {BENCH_PATH.name}")
    if not (dense_parity and paged_parity):
        failures.append("paged_shared_prefix: greedy tokens diverge from "
                        "engine_reference at equal slots")
    if ttft_ratio < TTFT_RATIO_FLOOR:
        failures.append(
            f"paged_shared_prefix: mean tick-TTFT ratio {ttft_ratio:.2f}x "
            f"below the {TTFT_RATIO_FLOOR}x floor (dense {ttft_dense:.1f}t"
            f" vs paged {ttft_paged:.1f}t)")
    if st["prefix_tokens"] == 0:
        failures.append("paged_shared_prefix: ZERO prefix hits on the "
                        "shared-prefix workload — radix sharing broken")
    if not cap_parity:
        failures.append(
            f"paged_shared_prefix: {CAPACITY_FACTOR}x-slot engine at equal"
            " KV memory diverged from engine_reference")


def _family_legs(failures):
    """One gated leg per slot-bank family (leg="ssm"/"hybrid"/"encdec"):
    parity flags at K=1 and K=FAMILY_K, warm tokens/s + speedup floors,
    and the family-tagged NVM verdicts (recurrent records carry their
    write-heavier read_fraction into analyze_serve)."""
    for fam, arch in FAMILY_ARCHS:
        cfg = reduced(get_config(arch), dtype="float32")
        model = build_model(cfg, max_seq=MAX_LEN)
        params = model.init(jax.random.PRNGKey(0))

        ref = EngineReference(model, params, slots=SLOTS, max_len=MAX_LEN)
        _drive(ref, seed=0)                   # warm the decode jit
        legacy_s = 1e9
        for _ in range(2):
            ref.reset()
            t0 = time.perf_counter()
            out_ref = _drive(ref, seed=1)
            legacy_s = min(legacy_s, time.perf_counter() - t0)
        tokens = sum(len(o) for o in out_ref.values())

        eng = Engine(model, params, slots=SLOTS, max_len=MAX_LEN,
                     ticks_per_sync=FAMILY_K, record_traffic=True)
        t0 = time.perf_counter()
        _drive(eng, seed=0)                   # cold: compiles + traffic
        cold_s = time.perf_counter() - t0
        engine_s, out_eng = 1e9, None
        for _ in range(3):
            eng.reset()
            t0 = time.perf_counter()
            out_eng = _drive(eng, seed=1)
            engine_s = min(engine_s, time.perf_counter() - t0)
        parity_k = out_eng == out_ref

        k1 = Engine(model, params, slots=SLOTS, max_len=MAX_LEN,
                    ticks_per_sync=1, record_traffic=False)
        parity_k1 = _drive(k1, seed=1) == out_ref

        eng_tps = tokens / engine_s
        speedup = legacy_s / engine_s
        verdicts = {v.shape: {"energy_ratio": v.energy_ratio,
                              "edp_ratio": v.edp_ratio}
                    for v in eng.nvm_verdicts()}

        record = _base_record(
            grid=(f"{N_REQUESTS} reqs x prompts {PROMPT_LENS} x new "
                  f"{MAX_NEW} on {SLOTS} slots, max_len {MAX_LEN}, "
                  f"K={FAMILY_K} ({arch} reduced)"),
            leg=fam,
            arch=arch,
            family=fam,
            attn_impl="xla",
            engine_s=engine_s,
            engine_cold_s=cold_s,
            legacy_per_tick_s=legacy_s,
            warm_tokens_per_s=eng_tps,
            warm_tps_floor=FAMILY_TPS_FLOOR,
            speedup=speedup,
            speedup_floor=FAMILY_SPEEDUP_FLOOR,
            greedy_parity=parity_k and parity_k1,
            parity_k1=parity_k1,
            parity_k4=parity_k,
            nvm_verdicts=verdicts,
        )
        append_bench_record(BENCH_PATH, record)
        emit(f"serve_engine_{fam}", engine_s * 1e6,
             f"{arch}: fused {eng_tps:.0f} tok/s = {speedup:.1f}x vs ref "
             f"| parity K1/K{FAMILY_K}="
             f"{'ok' if parity_k1 and parity_k else 'MISMATCH'} | "
             f"-> {BENCH_PATH.name}")
        if not (parity_k and parity_k1):
            failures.append(
                f"{fam}: {arch} greedy tokens diverge from "
                f"engine_reference (K1={parity_k1}, K{FAMILY_K}={parity_k})")
        if speedup < FAMILY_SPEEDUP_FLOOR:
            failures.append(
                f"{fam}: speedup {speedup:.1f}x below the "
                f"{FAMILY_SPEEDUP_FLOOR:.0f}x floor")
        if eng_tps < FAMILY_TPS_FLOOR:
            failures.append(
                f"{fam}: warm {eng_tps:.0f} tok/s below the "
                f"{FAMILY_TPS_FLOOR:.0f} tok/s floor")


def run():
    cfg = reduced(get_config(ARCH), dtype="float32")
    model = build_model(cfg, max_seq=MAX_LEN)
    params = model.init(jax.random.PRNGKey(0))

    ref = EngineReference(model, params, slots=SLOTS, max_len=MAX_LEN)
    _drive(ref, seed=0)                       # warm the decode jit
    legacy_s = 1e9                            # min-of-2: favors the seed path
    for _ in range(2):
        ref.reset()
        t0 = time.perf_counter()
        out_ref = _drive(ref, seed=1)         # _drive blocks before return
        legacy_s = min(legacy_s, time.perf_counter() - t0)
    tokens = sum(len(o) for o in out_ref.values())
    ref_tps = tokens / legacy_s

    failures = []
    xla_engine = None
    for attn_impl in ATTN_IMPLS:
        eng = Engine(model, params, slots=SLOTS, max_len=MAX_LEN,
                     ticks_per_sync=TICKS_PER_SYNC,
                     record_traffic=(attn_impl == "xla"),
                     attn_impl=attn_impl)
        if attn_impl == "xla":
            xla_engine = eng
        t0 = time.perf_counter()
        _drive(eng, seed=0)                   # cold: compiles + traffic
        cold_s = time.perf_counter() - t0

        engine_s, out_eng = 1e9, None
        for _ in range(3):
            eng.reset()
            t0 = time.perf_counter()
            out_eng = _drive(eng, seed=1)
            engine_s = min(engine_s, time.perf_counter() - t0)

        parity = out_eng == out_ref
        eng_tps = tokens / engine_s
        speedup = legacy_s / engine_s
        verdicts = {
            v.shape: {"energy_ratio": v.energy_ratio,
                      "edp_ratio": v.edp_ratio}
            for v in eng.nvm_verdicts()}

        record = _base_record(
            leg=attn_impl,
            attn_impl=attn_impl,
            engine_s=engine_s,
            engine_cold_s=cold_s,
            legacy_per_tick_s=legacy_s,
            warm_tokens_per_s=eng_tps,
            reference_tokens_per_s=ref_tps,
            speedup=speedup,
            speedup_floor=SPEEDUP_FLOOR,
            greedy_parity=parity,
            nvm_verdicts=verdicts,
        )
        append_bench_record(BENCH_PATH, record)

        emit(f"serve_engine_{attn_impl}", engine_s * 1e6,
             f"ref {ref_tps:.0f} tok/s -> fused {eng_tps:.0f} tok/s = "
             f"{speedup:.1f}x | parity={'ok' if parity else 'MISMATCH'} | "
             f"-> {BENCH_PATH.name}")
        if not parity:
            failures.append(
                f"{attn_impl}: fused engine greedy tokens diverge from "
                "engine_reference")
        if speedup < SPEEDUP_FLOOR:
            failures.append(
                f"{attn_impl}: serve engine speedup {speedup:.1f}x below "
                f"the {SPEEDUP_FLOOR:.0f}x floor")

    _paged_leg(model, params, out_ref, legacy_s, tokens, failures)
    _shared_prefix_leg(model, params, ref, failures)
    _family_legs(failures)
    # appended last so BENCH_serve.json's ``latest`` carries the SLO
    # percentiles for the bursty workload
    _latency_leg(xla_engine, failures)
    if failures:
        raise AssertionError("; ".join(failures))


if __name__ == "__main__":
    run()
