"""CI regression ratchet over ``BENCH_*.json`` histories.

The BENCH files carry every record ever appended (``history``) plus the
most recent one (``latest``).  Before this gate the history was
write-only: a slow PR could land a 2x regression and the next PR's
"20x speedup" would be measured against the regressed baseline — drift
instead of a ratchet.  This module turns the history into an explicit
gate (DESIGN.md §14):

  * Records are grouped by (bench file, leg, clock).  ``leg`` is the
    record's ``leg`` field (falling back to ``attn_impl``) so multi-leg
    benches like serve (xla / pallas_decode / poisson_burst) ratchet
    independently; ``clock`` separates post-fix ``blocking`` timings
    from pre-fix ``naive`` records, whose numbers are not comparable
    (the seed ``timed`` never blocked on async JAX dispatch).
  * Within each group the MOST RECENT record is the candidate and the
    best EARLIER record is the baseline; the candidate's metric must be
    within ``--tolerance`` (default 0.35, CI timing noise) of the best:
    ``candidate >= best * (1 - tol)`` for higher-is-better metrics.
  * Groups with no earlier comparable record pass ("no baseline") and
    become the baseline for the next run — speedups ratchet up.

Run:  PYTHONPATH=src python -m benchmarks.gate [--root DIR]
          [--tolerance 0.35] [--bench serve train ...]
Exits non-zero listing every regressed group (exercised on a synthetic
regression in tests/test_bench_gate.py).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from benchmarks.common import CLOCK

# bench file -> (ratchet metric, higher_is_better).  ``speedup`` is the
# fused-engine-vs-reference ratio measured on the SAME machine in the
# same run, so it ratchets meaningfully across heterogeneous CI runners
# where raw seconds would not.
GATES: Dict[str, Tuple[str, bool]] = {
    "BENCH_sweep.json": ("speedup", True),
    "BENCH_cachesim.json": ("speedup", True),
    "BENCH_traffic.json": ("speedup", True),
    "BENCH_serve.json": ("speedup", True),
    "BENCH_train.json": ("speedup", True),
}


def _leg(rec: dict) -> str:
    return str(rec.get("leg") or rec.get("attn_impl") or "")


def _clock(rec: dict) -> str:
    return str(rec.get("clock") or "naive")


def check_file(path: Path, metric: str, higher: bool,
               tolerance: float) -> List[dict]:
    """One result dict per (leg, clock) group found in ``path``."""
    data = json.loads(path.read_text())
    history = data.get("history", [])
    groups: Dict[Tuple[str, str], List[dict]] = {}
    for rec in history:
        if metric not in rec:
            continue   # e.g. the serve latency leg carries no speedup
        groups.setdefault((_leg(rec), _clock(rec)), []).append(rec)
    results = []
    for (leg, clock), recs in sorted(groups.items()):
        if clock != CLOCK:
            # pre-fix timing discipline: the seed ``timed`` never blocked
            # on async dispatch, so these numbers are not comparable with
            # current ones — and the group's candidate is frozen history
            # (every new record is stamped with the current clock), so
            # gating it would fail CI forever on legacy data.  Report,
            # don't gate.
            results.append({
                "bench": path.name, "leg": leg, "clock": clock,
                "metric": metric, "latest": recs[-1][metric],
                "best": None, "ok": True,
                "note": f"legacy clock {clock!r}, not gated"})
            continue
        candidate = recs[-1][metric]
        prior = [r[metric] for r in recs[:-1]]
        best: Optional[float] = None
        if prior:
            best = max(prior) if higher else min(prior)
        if best is None:
            ok, note = True, "no baseline (ratchet starts here)"
        elif higher:
            ok = candidate >= best * (1.0 - tolerance)
            note = f"best {best:.3f} -> latest {candidate:.3f}"
        else:
            ok = candidate <= best * (1.0 + tolerance)
            note = f"best {best:.3f} -> latest {candidate:.3f}"
        results.append({
            "bench": path.name, "leg": leg, "clock": clock,
            "metric": metric, "latest": candidate, "best": best,
            "ok": ok, "note": note})
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", type=Path,
                    default=Path(__file__).resolve().parents[1],
                    help="directory holding the BENCH_*.json files")
    ap.add_argument("--tolerance", type=float, default=0.35,
                    help="allowed fractional drop below the best "
                         "historical value before the gate fails")
    ap.add_argument("--bench", nargs="*", default=None,
                    help="short names to gate (serve train ...); "
                         "default: every known BENCH file present")
    args = ap.parse_args(argv)
    if not 0.0 <= args.tolerance < 1.0:
        ap.error(f"--tolerance must be in [0, 1), got {args.tolerance}")

    failures = []
    checked = 0
    for name, (metric, higher) in sorted(GATES.items()):
        short = name[len("BENCH_"):-len(".json")]
        if args.bench is not None and short not in args.bench:
            continue
        path = args.root / name
        if not path.exists():
            if args.bench is not None:
                print(f"gate: {name} MISSING", file=sys.stderr)
                failures.append(name)
            continue
        for res in check_file(path, metric, higher, args.tolerance):
            checked += 1
            leg = res["leg"] or "-"
            status = "ok  " if res["ok"] else "FAIL"
            print(f"gate: {status} {res['bench']} leg={leg} "
                  f"clock={res['clock']} {res['metric']}: {res['note']}")
            if not res["ok"]:
                failures.append(
                    f"{res['bench']}[{leg}/{res['clock']}] "
                    f"{res['metric']} {res['latest']:.3f} < "
                    f"{(1 - args.tolerance):.2f} x best {res['best']:.3f}")
    if failures:
        print(f"gate: {len(failures)} regression(s): "
              + "; ".join(str(f) for f in failures), file=sys.stderr)
        return 1
    print(f"gate: {checked} group(s) within tolerance "
          f"{args.tolerance:.2f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
