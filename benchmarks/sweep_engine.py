"""Sweep-engine speedup: batched tune_all vs the per-point reference.

Times Algorithm-1 tuning of the default (3 memories x 6 capacities) grid
two ways — one batched jit-compiled sweep (``repro.core.sweep``) vs the
legacy per-point loop (``tuner.tune_reference``, the seed implementation) —
verifies the selected configurations are identical, and appends a
timestamped record to ``BENCH_sweep.json`` at the repo root so the speedup
is tracked across PRs.
"""
from __future__ import annotations

import time
from datetime import datetime, timezone
from pathlib import Path

from benchmarks.common import append_bench_record, emit
from repro.core.tuner import (CAPACITIES_MB, MEMORIES, tune_all,
                              tune_reference)

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_sweep.json"


def _key(p):
    return (p.banks, p.rows, p.access_type)


def run():
    t0 = time.perf_counter()
    tune_all()                       # first call pays jit compilation
    cold_s = time.perf_counter() - t0

    times = []
    for _ in range(5):
        t0 = time.perf_counter()
        tune_all()
        times.append(time.perf_counter() - t0)
    engine_s = min(times)

    # warm the per-point path's jit too, so the recorded comparison is
    # loop-vs-batch rather than cold-compile-vs-warm
    tune_reference("SRAM", 1)
    t0 = time.perf_counter()
    ref = {m: {c: tune_reference(m, c) for c in CAPACITIES_MB}
           for m in MEMORIES}
    legacy_s = time.perf_counter() - t0

    eng = tune_all()
    parity = all(_key(eng[m][c]) == _key(ref[m][c])
                 for m in MEMORIES for c in CAPACITIES_MB)
    speedup = legacy_s / engine_s

    record = {
        "timestamp": datetime.now(timezone.utc).isoformat(),
        "grid": f"{len(MEMORIES)}x{len(CAPACITIES_MB)}",
        "tune_all_engine_s": engine_s,
        "tune_all_engine_cold_s": cold_s,
        "tune_all_legacy_per_point_s": legacy_s,
        "speedup": speedup,
        "selections_identical": parity,
    }
    append_bench_record(BENCH_PATH, record)

    emit("sweep_engine_tune_all", engine_s * 1e6,
         f"legacy {legacy_s*1e3:.0f}ms -> engine {engine_s*1e3:.1f}ms = "
         f"{speedup:.0f}x | parity={'ok' if parity else 'MISMATCH'} | "
         f"-> {BENCH_PATH.name}")
    if not parity:
        raise AssertionError("engine selections diverge from reference")
