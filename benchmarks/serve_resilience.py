"""Chaos harness: fault-injection scenarios over BOTH serve engines.

Every scenario builds a seeded ``FaultPlan`` (serve/chaos.py) or injects
a driver-level fault (malformed submits, a mid-run crash + rebuild),
drives the dense ``Engine`` and the ``PagedEngine`` through it, and then
asserts the DESIGN.md §16 invariants:

  * termination  — ``run()`` returns and every submitted request reaches
    exactly one terminal state (DONE / SHED / TIMED_OUT / FAILED); the
    engines' stall guard advances the tick clock when chaos starves the
    pool, so there is no schedule that deadlocks the loop
  * parity       — requests the faults did not kill finish with greedy
    tokens BITWISE equal to a clean ``EngineReference`` run of the same
    workload (quarantine/preempt/crash resume from the already-emitted
    prefix, and greedy decoding is scheduling-independent); TIMED_OUT
    partial outputs must be strict prefixes of the reference answer
  * conservation — after ``plan.release_held()`` the paged pool's
    refcounts equal tree-held + slot-held references EXACTLY
    (``PagePool.check``), even though chaos stole pages mid-run
  * bounded shed — under an overloaded Poisson/burst arrival schedule
    with deadlines and a queue-depth cap, the engine sheds SOME work
    (admission control is real) but completes at least a floor fraction

Fault sites exercised per engine (>= 6 distinct on BOTH engines):
``submit.malformed`` and ``submit.oversized`` (driver-level soft-fail),
``nan_logits``, ``kv_corrupt``, ``window_stall`` (watchdog retry AND
sticky degrade-to-eager), ``engine.crash`` (rebuild + resubmit of every
non-terminal request, mid-slot ones included); the paged engine adds
``pool_exhaust`` and ``cow_storm``.  A recurrent-family pass
(recurrentgemma, hybrid slot banks) re-runs ``nan_logits`` /
``kv_corrupt`` / ``engine.crash`` to pin that quarantine-and-resume
keeps bitwise parity when the faulted state is positionless bank rows
rather than positioned KV.

The verdict lands in ``BENCH_serve.json`` as a ``leg="chaos"`` record
whose gated ``speedup`` metric is 1.0 when every invariant held and 0.0
otherwise — benchmarks/gate.py's ratchet (tolerance 0.35) then fails CI
on any chaos regression.  The record is appended BEFORE the harness
raises, so a red run still leaves its evidence in the history.

Run: PYTHONPATH=src python -m benchmarks.serve_resilience [--no-reduced]
"""
from __future__ import annotations

import argparse
import time
from collections import Counter
from datetime import datetime, timezone
from pathlib import Path

import jax

from benchmarks.common import append_bench_record, emit
from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serve import (DONE, FAILED, SHED, TIMED_OUT, Engine,
                         EngineReference, Fault, FaultPlan, PagedEngine,
                         Request, ShedPolicy, WindowWatchdog,
                         mixed_requests, poisson_requests, run_arrivals)

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_serve.json"

ARCH = "llama3-8b"
RECURRENT_ARCH = "recurrentgemma-2b"   # hybrid slot-bank chaos coverage
SLOTS = 3
MAX_LEN = 48
K = 4                        # ticks_per_sync: small so faults land mid-flight
PAGE_SIZE = 4
VOCAB = 512
MAX_TICKS = 6000
MIN_FAULT_SITES = 6          # ISSUE floor: distinct sites per engine

# bounded-shed scenario: deliberate overload (arrivals far outpace the
# 3 slots) with deadlines + a queue cap — admission control must shed
# SOME work but still complete at least DONE_FLOOR of the offered load
BURST_RATE = 1.5
BURST_AMP = 0.6
BURST_DEADLINE = 80.0
BURST_QUEUE_DEPTH = 4
SHED_BOUND = 0.8             # <= 80% of requests may be shed/timed out
DONE_FLOOR = 0.2             # >= 20% must finish DONE under overload


def _workload(n: int, seed: int, max_new=(3, 8)):
    return mixed_requests(n, seed=seed, vocab=VOCAB,
                          prompt_lens=(2, 12), max_new=max_new)


def _fresh(eng, *, plan=None, policy=None, watchdog=None):
    """Reset + rebind the per-scenario resilience knobs (reset() keeps
    shed_policy/watchdog/fault_plan, so scenarios restore defaults)."""
    eng.reset()
    eng.fault_plan = plan
    eng.shed_policy = policy if policy is not None else ShedPolicy()
    eng.watchdog = (watchdog if watchdog is not None
                    else WindowWatchdog(backoff_s=0.001))
    return eng


def _drive(eng, reqs, max_ticks=MAX_TICKS):
    for r in reqs:
        eng.submit(r)
    eng.run(max_ticks=max_ticks)
    return reqs


def _states(reqs) -> dict:
    return dict(Counter(r.state for r in reqs))


def _check_terminal(name: str, reqs, failures) -> None:
    stuck = sorted(r.uid for r in reqs if not r.terminal)
    if stuck:
        failures.append(f"{name}: requests {stuck} never reached a "
                        f"terminal state ({_states(reqs)})")


def _check_parity(name: str, reqs, ref_out, failures) -> None:
    """DONE outputs must be bitwise equal to the clean reference run;
    TIMED_OUT partials must be prefixes of it (quarantine/preempt/crash
    resume re-derives the same greedy tokens)."""
    for r in reqs:
        if r.uid not in ref_out:
            continue             # driver-injected malformed request
        want = ref_out[r.uid]
        got = list(r.output)
        if r.state == DONE and got != want:
            failures.append(f"{name}: uid {r.uid} DONE output diverges "
                            f"from reference ({got} != {want})")
        elif r.state in (TIMED_OUT, SHED) and got != want[:len(got)]:
            failures.append(f"{name}: uid {r.uid} {r.state} partial "
                            f"output is not a reference prefix")


def _check_conservation(name: str, eng, plan, failures) -> None:
    """Exact page-refcount conservation: pool refs == tree + slots (+
    nothing, once the plan returns its stolen pages)."""
    if not hasattr(eng, "pool"):
        return                   # dense engine has no page pool
    if plan is not None:
        plan.release_held()
    slot_refs: Counter = Counter()
    for s, r in enumerate(eng.slot_req):
        if r is not None:
            slot_refs.update(eng._slot_pages[s])
    try:
        eng.pool.check(eng.tree.held_refs() + slot_refs)
    except AssertionError as e:
        failures.append(f"{name}: page refcount conservation violated "
                        f"({e})")


# ---- scenarios ----------------------------------------------------------

def _scn_submit_malformed(eng, label, ref_out, n, failures, sites):
    """Driver-level faults: malformed and oversized submits must soft-
    fail as FAILED (with a reason) while the engine keeps serving."""
    name = f"{label}/submit_malformed"
    sites.update(["submit.malformed", "submit.oversized"])
    _fresh(eng)
    bad = [Request(uid=900, prompt=[], max_new_tokens=3),
           Request(uid=901, prompt=[1] * (MAX_LEN + 8), max_new_tokens=3),
           Request(uid=902, prompt=[1, 2], max_new_tokens=0)]
    accepted = [eng.submit(b) for b in bad]
    reqs = _drive(eng, _workload(n, seed=0))
    if any(accepted):
        failures.append(f"{name}: a malformed request was accepted")
    for b in bad:
        if b.state != FAILED or not b.reason:
            failures.append(f"{name}: uid {b.uid} should be FAILED with "
                            f"a reason, got {b.state} ({b.reason!r})")
    if eng.resilience_stats()["failed"] < len(bad):
        failures.append(f"{name}: failed counter did not record the "
                        "malformed submits")
    _check_terminal(name, reqs, failures)
    _check_parity(name, reqs, ref_out, failures)
    _check_conservation(name, eng, None, failures)
    return {"scenario": name, "states": _states(reqs)}


def _scn_fault_plan(eng, label, ref_out, n, failures, sites, *, kind,
                    fault, watchdog=None, policy=None, expect=()):
    """Shared body for FaultPlan scenarios: run, then invariants plus
    per-kind expectations over resilience/paged stats."""
    name = f"{label}/{kind}"
    sites.add(kind)
    plan = FaultPlan([fault] if isinstance(fault, Fault) else fault,
                     seed=11)
    _fresh(eng, plan=plan, watchdog=watchdog, policy=policy)
    reqs = _drive(eng, _workload(n, seed=0))
    if not plan.injected:
        failures.append(f"{name}: plan fired no faults "
                        f"(visits {dict(plan.visits)})")
    rs = eng.resilience_stats()
    st = eng.paged_stats() if hasattr(eng, "paged_stats") else {}
    for key, floor in expect:
        have = int(rs.get(key, st.get(key, 0)))
        if have < floor:
            failures.append(f"{name}: expected {key} >= {floor}, "
                            f"got {have} (stats {rs})")
    _check_terminal(name, reqs, failures)
    _check_parity(name, reqs, ref_out, failures)
    _check_conservation(name, eng, plan, failures)
    return {"scenario": name, "injected": dict(plan.injected),
            "states": _states(reqs), "stats": rs}


def _scn_crash_rebuild(eng, label, ref_out, n, failures, sites):
    """Mid-run crash: run two windows, drop the device state on the
    floor (reset == rebuilt engine: fresh cache/state, empty queue),
    resubmit every non-terminal request — mid-slot ones resume from
    their emitted prefix — and finish with bitwise parity."""
    name = f"{label}/engine.crash"
    sites.add("engine.crash")
    reqs = _workload(n, seed=5, max_new=(6, 12))
    _fresh(eng)
    for r in reqs:
        eng.submit(r)
    eng.step()
    eng.step()                   # crash point: some requests mid-decode
    survivors = [r for r in reqs if not r.terminal]
    in_flight = [r for r in survivors if r.output]
    _fresh(eng)                  # the rebuilt engine
    for r in survivors:
        eng.submit(r)
    eng.run(max_ticks=MAX_TICKS)
    if not survivors:
        failures.append(f"{name}: nothing survived the crash point — "
                        "scenario lost its teeth (shrink K or grow "
                        "max_new)")
    _check_terminal(name, reqs, failures)
    _check_parity(name, reqs, ref_out, failures)
    _check_conservation(name, eng, None, failures)
    return {"scenario": name, "states": _states(reqs),
            "resubmitted": len(survivors), "mid_slot": len(in_flight)}


def _scn_burst_shed(eng, label, n_traffic, failures):
    """Overloaded Poisson/burst arrivals + deadlines + queue cap: the
    run must terminate with every request terminal, shed SOME load, keep
    the shed+timeout rate under SHED_BOUND, and finish >= DONE_FLOOR."""
    name = f"{label}/burst_shed"
    pol = ShedPolicy(max_queue_depth=BURST_QUEUE_DEPTH)
    _fresh(eng, policy=pol)
    reqs = poisson_requests(n_traffic, seed=7, vocab=VOCAB,
                            arrival_rate=BURST_RATE, burst_amp=BURST_AMP,
                            prompt_bounds=(2, 10), new_bounds=(2, 8),
                            deadline_ticks=BURST_DEADLINE)
    run_arrivals(eng, reqs, max_ticks=MAX_TICKS)   # strict: raises on hang
    states = _states(reqs)
    done = states.get(DONE, 0)
    shed = states.get(SHED, 0) + states.get(TIMED_OUT, 0)
    if shed == 0:
        failures.append(f"{name}: overload shed nothing — admission "
                        f"control is not engaging ({states})")
    if shed / len(reqs) > SHED_BOUND:
        failures.append(f"{name}: shed rate {shed}/{len(reqs)} above the "
                        f"{SHED_BOUND:.0%} bound ({states})")
    if done / len(reqs) < DONE_FLOOR:
        failures.append(f"{name}: only {done}/{len(reqs)} completed "
                        f"under overload (floor {DONE_FLOOR:.0%})")
    _check_terminal(name, reqs, failures)
    _check_conservation(name, eng, None, failures)
    return {"scenario": name, "states": states,
            "shed_rate": shed / len(reqs)}


# ---- driver -------------------------------------------------------------

def _reference_outputs(ref, reqs_factory) -> dict:
    """Clean greedy outputs for a workload factory, keyed by uid."""
    ref.reset()
    reqs = reqs_factory()
    for r in reqs:
        ref.submit(r)
    left = ref.run(max_ticks=MAX_TICKS)
    assert left == 0, "reference run did not complete"
    return {r.uid: list(r.output) for r in reqs}


def _run_engine(eng, label, refs, n, n_traffic, failures, scenarios):
    sites: set = set()
    w_retry = WindowWatchdog(max_attempts=3, backoff_s=0.001)
    scenarios.append(_scn_submit_malformed(
        eng, label, refs["mixed"], n, failures, sites))
    scenarios.append(_scn_fault_plan(
        eng, label, refs["mixed"], n, failures, sites,
        kind="nan_logits", fault=Fault("nan_logits", at=1),
        expect=[("quarantined", 1), ("retried", 1)]))
    scenarios.append(_scn_fault_plan(
        eng, label, refs["mixed"], n, failures, sites,
        kind="kv_corrupt", fault=Fault("kv_corrupt", at=1),
        expect=([("quarantined", 1), ("tree_flushes", 1)]
                if hasattr(eng, "pool") else [("quarantined", 1)])))
    scenarios.append(_scn_fault_plan(
        eng, label, refs["mixed"], n, failures, sites,
        kind="window_stall", watchdog=w_retry,
        fault=Fault("window_stall", at=1, count=2),
        expect=[("window_retries", 2)]))
    # same kind, other exit: every attempt stalls -> sticky degrade to
    # the eager window; parity must STILL hold on the fallback path
    deg = _scn_fault_plan(
        eng, label, refs["mixed"], n, failures, sites,
        kind="window_stall", watchdog=w_retry,
        fault=Fault("window_stall", at=1, count=3),
        expect=[("window_fallbacks", 1)])
    deg["scenario"] = f"{label}/window_stall_degrade"
    if not deg["stats"].get("degraded"):
        failures.append(f"{label}/window_stall_degrade: engine did not "
                        "report degraded mode after watchdog exhaustion")
    scenarios.append(deg)
    if hasattr(eng, "pool"):
        scenarios.append(_scn_fault_plan(
            eng, label, refs["mixed"], n, failures, sites,
            kind="pool_exhaust",
            fault=Fault("pool_exhaust", at=0, count=2, hold=2),
            expect=[("deferred", 1)]))
        scenarios.append(_scn_fault_plan(
            eng, label, refs["mixed"], n, failures, sites,
            kind="cow_storm",
            fault=Fault("cow_storm", at=1, count=2, pages=2),
            expect=[("cow_copies", 2)]))
    scenarios.append(_scn_crash_rebuild(
        eng, label, refs["crash"], n, failures, sites))
    scenarios.append(_scn_burst_shed(eng, label, n_traffic, failures))
    if len(sites) < MIN_FAULT_SITES:
        failures.append(f"{label}: only {len(sites)} distinct fault "
                        f"sites exercised ({sorted(sites)}); floor is "
                        f"{MIN_FAULT_SITES}")
    return sorted(sites)


def run(reduced_mode: bool = True):
    n = 6 if reduced_mode else 12
    n_traffic = 24 if reduced_mode else 48
    cfg = reduced(get_config(ARCH), dtype="float32")
    model = build_model(cfg, max_seq=MAX_LEN)
    params = model.init(jax.random.PRNGKey(0))

    ref = EngineReference(model, params, slots=SLOTS, max_len=MAX_LEN)
    refs = {
        "mixed": _reference_outputs(ref, lambda: _workload(n, seed=0)),
        "crash": _reference_outputs(
            ref, lambda: _workload(n, seed=5, max_new=(6, 12))),
    }

    failures: list = []
    scenarios: list = []
    t0 = time.perf_counter()
    dense = Engine(model, params, slots=SLOTS, max_len=MAX_LEN,
                   ticks_per_sync=K, record_traffic=False)
    dense_sites = _run_engine(dense, "dense", refs, n, n_traffic,
                              failures, scenarios)
    paged = PagedEngine(model, params, slots=SLOTS, max_len=MAX_LEN,
                        page_size=PAGE_SIZE, ticks_per_sync=K,
                        record_traffic=False)
    paged_sites = _run_engine(paged, "paged", refs, n, n_traffic,
                              failures, scenarios)

    # recurrent-family chaos (ISSUE 10): faults on positionless slot-bank
    # state must still leave survivors with bitwise reference parity —
    # quarantine/crash recovery replays prompt+output through the masked
    # prefill scan, and _release_slot resets the victim's banks so NaN
    # state cannot leak into the next occupant
    rcfg = reduced(get_config(RECURRENT_ARCH), dtype="float32")
    rmodel = build_model(rcfg, max_seq=MAX_LEN)
    rparams = rmodel.init(jax.random.PRNGKey(0))
    rref = EngineReference(rmodel, rparams, slots=SLOTS, max_len=MAX_LEN)
    rrefs = {
        "mixed": _reference_outputs(rref, lambda: _workload(n, seed=0)),
        "crash": _reference_outputs(
            rref, lambda: _workload(n, seed=5, max_new=(6, 12))),
    }
    rec_sites: set = set()
    rec = Engine(rmodel, rparams, slots=SLOTS, max_len=MAX_LEN,
                 ticks_per_sync=K, record_traffic=False)
    scenarios.append(_scn_fault_plan(
        rec, "recurrent", rrefs["mixed"], n, failures, rec_sites,
        kind="nan_logits", fault=Fault("nan_logits", at=1),
        expect=[("quarantined", 1), ("retried", 1)]))
    scenarios.append(_scn_fault_plan(
        rec, "recurrent", rrefs["mixed"], n, failures, rec_sites,
        kind="kv_corrupt", fault=Fault("kv_corrupt", at=1),
        expect=[("quarantined", 1)]))
    scenarios.append(_scn_crash_rebuild(
        rec, "recurrent", rrefs["crash"], n, failures, rec_sites))
    wall_s = time.perf_counter() - t0

    record = {
        "timestamp": datetime.now(timezone.utc).isoformat(),
        "grid": (f"{len(scenarios)} chaos scenarios x {n} reqs on "
                 f"{SLOTS} slots, max_len {MAX_LEN}, K={K}, page_size "
                 f"{PAGE_SIZE} ({ARCH} reduced)"),
        "leg": "chaos",
        "wall_s": wall_s,
        "fault_sites": {"dense": dense_sites, "paged": paged_sites,
                        "recurrent": sorted(rec_sites)},
        "scenarios": scenarios,
        # the GATED metric: 1.0 = every invariant held, 0.0 = chaos
        # found a violation; gate.py's 0.35 tolerance then fails CI on
        # ANY chaos regression (a boolean wearing the ratchet's schema)
        "speedup": 1.0 if not failures else 0.0,
        "speedup_domain": "invariants",
        "failures": list(failures),
    }
    append_bench_record(BENCH_PATH, record)
    emit("serve_resilience", wall_s * 1e6,
         f"{len(scenarios)} scenarios, sites dense={len(dense_sites)} "
         f"paged={len(paged_sites)} recurrent={len(rec_sites)}, "
         f"invariants={'ok' if not failures else 'VIOLATED'} -> "
         f"{BENCH_PATH.name}")
    if failures:
        raise AssertionError("; ".join(failures))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="smoke-sized chaos sweep (--no-reduced doubles "
                         "the workload)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(reduced_mode=args.reduced)


if __name__ == "__main__":
    main()
