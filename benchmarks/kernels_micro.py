"""Microbenchmarks: Pallas kernels (interpret mode) vs jnp oracles.

Every kernel is checked against its jnp/python oracle and the max
absolute error is ENFORCED against ``ERR_BOUND`` — this module is a CI
gate (`python -m benchmarks.run --only kernels_micro`), not just a
timer.  Results land in ``BENCH_kernels.json`` next to the other BENCH
artifacts so error drift is visible across PRs.
"""
from __future__ import annotations

from datetime import datetime, timezone
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import append_bench_record, run_and_emit
from repro.kernels import ops, ref

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_kernels.json"
ERR_BOUND = 2e-2


def run():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    errors: dict[str, float] = {}
    failures: list[str] = []

    def check(name: str, err: float):
        errors[name] = err
        if not err <= ERR_BOUND:
            failures.append(f"{name}: max|err| {err:.3e} > {ERR_BOUND:.0e}")

    def flash():
        q = jax.random.normal(ks[1], (1, 4, 256, 64))
        k = jax.random.normal(ks[2], (1, 2, 256, 64))
        v = jax.random.normal(ks[3], (1, 2, 256, 64))
        o = ops.flash_attention(q, k, v, bq=128, bk=128)
        r = ref.flash_attention_ref(q, k, v)
        return float(jnp.max(jnp.abs(o - r)))

    check("kernel_flash_attention",
          run_and_emit("kernel_flash_attention", flash,
                       lambda d: f"max|err| vs oracle = {d:.2e}"))

    def decode():
        B, L, H, K, hd = 4, 128, 4, 2, 64
        q = jax.random.normal(ks[1], (B, H, hd))
        k = jax.random.normal(ks[2], (B, L, K, hd))
        v = jax.random.normal(ks[3], (B, L, K, hd))
        pos = jnp.array([0, 17, 63, 127], jnp.int32)
        win = jnp.asarray(24, jnp.int32)
        o = ops.decode_attention(q, k, v, pos, win, logit_cap=30.0, bk=32)
        r = ref.decode_attention_ref(q, k, v, pos, 24, logit_cap=30.0)
        return float(jnp.max(jnp.abs(o - r)))

    check("kernel_decode_attention",
          run_and_emit("kernel_decode_attention", decode,
                       lambda d: f"max|err| vs oracle = {d:.2e}"))

    def decode_fused():
        # Fused KV scatter: the new token's row must land in the cache
        # bit-identically to the jnp .at[].set path, rows past each
        # slot's pos must be untouched, and attention must already see
        # the new row (self-attention term) in the same launch.
        B, L, H, K, hd = 4, 128, 4, 2, 64
        q = jax.random.normal(ks[1], (B, H, hd))
        k = jax.random.normal(ks[2], (B, L, K, hd))
        v = jax.random.normal(ks[3], (B, L, K, hd))
        nk = jax.random.normal(ks[0], (B, K, hd))
        nv = jax.random.normal(ks[1], (B, K, hd))
        pos = jnp.array([0, 17, 63, 127], jnp.int32)
        win = jnp.asarray(0, jnp.int32)
        o, ck, cv = ops.decode_attention_fused(
            q, k, v, nk, nv, pos, win, bk=32)
        rows = jnp.arange(B)
        k2 = k.at[rows, pos].set(nk)
        v2 = v.at[rows, pos].set(nv)
        r = ref.decode_attention_ref(q, k2, v2, pos, 0)
        err = float(jnp.max(jnp.abs(o - r)))
        scatter_ok = bool(jnp.array_equal(ck, k2) & jnp.array_equal(cv, v2))
        return err, scatter_ok

    err, scatter_ok = run_and_emit(
        "kernel_decode_attention_fused", decode_fused,
        lambda d: f"max|err| vs oracle = {d[0]:.2e}, scatter bitwise: {d[1]}")
    check("kernel_decode_attention_fused", err)
    if not scatter_ok:
        failures.append(
            "kernel_decode_attention_fused: fused KV scatter is not "
            "bitwise-identical to the jnp .at[].set path")

    def ssd():
        x = jax.random.normal(ks[1], (1, 4, 256, 32))
        dt = jax.nn.softplus(jax.random.normal(ks[2], (1, 4, 256)))
        A = -jnp.exp(jax.random.normal(ks[3], (4,))) * 0.3
        dtA = dt * A[None, :, None]
        Bm = jax.random.normal(ks[2], (1, 256, 16))
        Cm = jax.random.normal(ks[3], (1, 256, 16))
        y = ops.ssd_scan(x, dt, dtA, Bm, Cm, chunk=64)
        r = ref.ssd_scan_ref(x, dt, dtA, Bm, Cm)
        return float(jnp.max(jnp.abs(y - r)))

    check("kernel_ssd_scan",
          run_and_emit("kernel_ssd_scan", ssd,
                       lambda d: f"max|err| vs oracle = {d:.2e}"))

    def rglru():
        a = jax.nn.sigmoid(jax.random.normal(ks[1], (2, 512, 256)))
        b = jax.random.normal(ks[2], (2, 512, 256)) * 0.1
        y = ops.rglru_scan(a, b, block=128, width_tile=128)
        r = ref.rglru_scan_ref(a, b)
        return float(jnp.max(jnp.abs(y - r)))

    check("kernel_rglru_scan",
          run_and_emit("kernel_rglru_scan", rglru,
                       lambda d: f"max|err| vs oracle = {d:.2e}"))

    def csim():
        rng = np.random.RandomState(0)
        sid = rng.randint(0, 128, 4000)
        tg = rng.zipf(1.4, 4000) % 4000
        h1, m1 = ops.cache_sim(jnp.asarray(sid), jnp.asarray(tg),
                               num_sets=128, ways=8, sets_tile=32)
        h2, m2 = ref.cache_sim_python(sid, tg, num_sets=128, ways=8)
        return (int(h1), int(m1)) == (h2, m2)

    lru_ok = run_and_emit("kernel_cache_sim", csim,
                          lambda ok: f"kernel==python-LRU: {ok}")
    if not lru_ok:
        failures.append("kernel_cache_sim: kernel disagrees with python LRU")

    append_bench_record(BENCH_PATH, {
        "timestamp": datetime.now(timezone.utc).isoformat(),
        "err_bound": ERR_BOUND,
        "max_abs_err": errors,
        "cache_sim_exact": bool(lru_ok),
        "fused_scatter_bitwise": scatter_ok,
        "pass": not failures,
    })
    if failures:
        raise AssertionError("; ".join(failures))
