"""Microbenchmarks: Pallas kernels (interpret mode) vs jnp oracles."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import run_and_emit
from repro.kernels import ops, ref


def run():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)

    def flash():
        q = jax.random.normal(ks[1], (1, 4, 256, 64))
        k = jax.random.normal(ks[2], (1, 2, 256, 64))
        v = jax.random.normal(ks[3], (1, 2, 256, 64))
        o = ops.flash_attention(q, k, v, bq=128, bk=128)
        r = ref.flash_attention_ref(q, k, v)
        return float(jnp.max(jnp.abs(o - r)))

    run_and_emit("kernel_flash_attention", flash,
                 lambda d: f"max|err| vs oracle = {d:.2e}")

    def ssd():
        x = jax.random.normal(ks[1], (1, 4, 256, 32))
        dt = jax.nn.softplus(jax.random.normal(ks[2], (1, 4, 256)))
        A = -jnp.exp(jax.random.normal(ks[3], (4,))) * 0.3
        dtA = dt * A[None, :, None]
        Bm = jax.random.normal(ks[2], (1, 256, 16))
        Cm = jax.random.normal(ks[3], (1, 256, 16))
        y = ops.ssd_scan(x, dt, dtA, Bm, Cm, chunk=64)
        r = ref.ssd_scan_ref(x, dt, dtA, Bm, Cm)
        return float(jnp.max(jnp.abs(y - r)))

    run_and_emit("kernel_ssd_scan", ssd,
                 lambda d: f"max|err| vs oracle = {d:.2e}")

    def rglru():
        a = jax.nn.sigmoid(jax.random.normal(ks[1], (2, 512, 256)))
        b = jax.random.normal(ks[2], (2, 512, 256)) * 0.1
        y = ops.rglru_scan(a, b, block=128, width_tile=128)
        r = ref.rglru_scan_ref(a, b)
        return float(jnp.max(jnp.abs(y - r)))

    run_and_emit("kernel_rglru_scan", rglru,
                 lambda d: f"max|err| vs oracle = {d:.2e}")

    def csim():
        rng = np.random.RandomState(0)
        sid = rng.randint(0, 128, 4000)
        tg = rng.zipf(1.4, 4000) % 4000
        h1, m1 = ops.cache_sim(jnp.asarray(sid), jnp.asarray(tg),
                               num_sets=128, ways=8, sets_tile=32)
        h2, m2 = ref.cache_sim_python(sid, tg, num_sets=128, ways=8)
        return (int(h1), int(m1)) == (h2, m2)

    run_and_emit("kernel_cache_sim", csim,
                 lambda ok: f"kernel==python-LRU: {ok}")
