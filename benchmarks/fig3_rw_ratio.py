"""Fig 3: L2 read/write transaction ratios across the workload set."""
from __future__ import annotations

from benchmarks.common import run_and_emit
from repro.core.profiles import paper_profiles


def run():
    def work():
        return paper_profiles()

    def derive(profs):
        ratios = {p.label: round(p.rw_ratio, 1) for p in profs}
        lo, hi = min(ratios.values()), max(ratios.values())
        in_range = 1.5 <= lo and hi <= 26.5
        return (f"range [{lo},{hi}] (paper: 2..26; in-range={in_range}) | "
                + " ".join(f"{k}={v}" for k, v in ratios.items()))

    run_and_emit("fig3_rw_ratios", work, derive)
