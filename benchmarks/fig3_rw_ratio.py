"""Fig 3: L2 read/write transaction ratios across the workload set.

Two cohorts, both off single batched traffic-engine evaluations: the
paper's 13 profiles (5 DNNs × {I, T} + HPCG) checked against the Fig-3
[2, 26] band, and the modern-config cohort (``traffic.MODERN_COHORT``,
transformers/SSM/enc-dec lowered through the ``LayerStack`` adapter) as
a beyond-paper Fig-3-style row set.
"""
from __future__ import annotations

from benchmarks.common import run_and_emit
from repro.core.profiles import paper_profiles
from repro.core.traffic import modern_profiles


def run():
    def work():
        return paper_profiles(), modern_profiles()

    def derive(out):
        profs, modern = out
        ratios = {p.label: round(p.rw_ratio, 1) for p in profs}
        lo, hi = min(ratios.values()), max(ratios.values())
        in_range = 1.5 <= lo and hi <= 26.5
        mod = {p.label: round(p.rw_ratio, 2) for p in modern}
        return (f"range [{lo},{hi}] (paper: 2..26; in-range={in_range}) | "
                + " ".join(f"{k}={v}" for k, v in ratios.items())
                + " | modern: "
                + " ".join(f"{k}={v}" for k, v in mod.items()))

    run_and_emit("fig3_rw_ratios", work, derive)
