"""Table 1: bitcell parameters — published values + parametric flow check."""
from __future__ import annotations

from benchmarks.common import run_and_emit
from repro.core.bitcell import (SOT, SOT_DEVICE, STT, STT_DEVICE, TABLE1,
                                characterize, fin_sweep)


def run():
    def work():
        stt = characterize(STT_DEVICE, write_fins=4, read_fins=4, sot=False,
                           name="STT-4F")
        sot = characterize(SOT_DEVICE, write_fins=3, read_fins=1, sot=True,
                           name="SOT-3W1R")
        sweep = fin_sweep(STT_DEVICE, sot=False) + fin_sweep(SOT_DEVICE,
                                                             sot=True)
        return stt, sot, sweep

    def derive(out):
        stt, sot, sweep = out
        err_stt = abs(stt.write_latency_ps / STT.write_latency_ps - 1)
        err_sot = abs(sot.write_latency_ps / SOT.write_latency_ps - 1)
        return (f"STT wlat {stt.write_latency_ps:.0f}ps (pub "
                f"{STT.write_latency_ps:.0f}; err {err_stt:.0%}) | "
                f"SOT wlat {sot.write_latency_ps:.0f}ps (pub "
                f"{SOT.write_latency_ps:.0f}; err {err_sot:.0%}) | "
                f"fin sweep {len(sweep)} pts")

    run_and_emit("table1_bitcell", work, derive)
