"""Table 2: EDAP-tuned cache PPA at iso-capacity / iso-area anchors.

All five anchors come out of a single batched sweep over
(3 memories x {3, 7, 10} MB); the iso-area capacities come from one
batched ladder sweep over both NVMs.
"""
from __future__ import annotations

from benchmarks.common import run_and_emit
from repro.core.cache_model import PPA_METRICS as FIELDS
from repro.core.sweep import iso_area_search, sweep
from repro.core.table2 import TABLE2_ANCHORS

TARGETS = {key: tuple(row[f] for f in FIELDS)
           for key, row in TABLE2_ANCHORS.items()}


def run():
    def work():
        caps = tuple(sorted({float(cap) for _, cap in TARGETS}))
        s = sweep(("SRAM", "STT", "SOT"), caps)
        rows = {}
        for (mem, cap), tgt in TARGETS.items():
            p = s.config(mem, float(cap))
            rows[(mem, cap)] = [getattr(p, f) for f in FIELDS]
        sram_area = s.config("SRAM", 3.0).area_mm2
        iso = iso_area_search(("STT", "SOT"), sram_area)
        return rows, iso

    def derive(out):
        import math
        rows, iso = out
        errs = []
        for key, tgt in TARGETS.items():
            errs += [abs(math.log(p / t)) for p, t in zip(rows[key], tgt)]
        mean_err = sum(errs) / len(errs)
        return (f"mean|logerr|={mean_err:.3f} over {len(errs)} vals | "
                f"iso-area caps STT={iso['STT'].capacity_mb:.1f}MB "
                f"SOT={iso['SOT'].capacity_mb:.1f}MB (paper 7/10)")

    run_and_emit("table2_cache_ppa", work, derive)
