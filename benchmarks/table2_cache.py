"""Table 2: EDAP-tuned cache PPA at iso-capacity / iso-area anchors."""
from __future__ import annotations

from benchmarks.common import run_and_emit
from repro.core.tuner import iso_area_capacity, tune

TARGETS = {
    ("SRAM", 3): (2.91, 1.53, 0.35, 0.32, 6442, 5.53),
    ("STT", 3): (2.98, 9.31, 0.81, 0.31, 748, 2.34),
    ("STT", 7): (4.58, 10.06, 0.93, 0.43, 1706, 5.12),
    ("SOT", 3): (3.71, 1.38, 0.49, 0.22, 527, 1.95),
    ("SOT", 10): (6.69, 2.47, 0.51, 0.40, 1434, 5.64),
}
FIELDS = ("read_latency_ns", "write_latency_ns", "read_energy_nj",
          "write_energy_nj", "leakage_mw", "area_mm2")


def run():
    def work():
        rows = {}
        for (mem, cap), tgt in TARGETS.items():
            p = tune(mem, cap)
            rows[(mem, cap)] = [getattr(p, f) for f in FIELDS]
        sram_area = tune("SRAM", 3).area_mm2
        iso = {m: iso_area_capacity(m, sram_area) for m in ("STT", "SOT")}
        return rows, iso

    def derive(out):
        import math
        rows, iso = out
        errs = []
        for key, tgt in TARGETS.items():
            errs += [abs(math.log(p / t)) for p, t in zip(rows[key], tgt)]
        mean_err = sum(errs) / len(errs)
        return (f"mean|logerr|={mean_err:.3f} over {len(errs)} vals | "
                f"iso-area caps STT={iso['STT'].capacity_mb:.1f}MB "
                f"SOT={iso['SOT'].capacity_mb:.1f}MB (paper 7/10)")

    run_and_emit("table2_cache_ppa", work, derive)
