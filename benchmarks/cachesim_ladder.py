"""Cache-simulator ladder speedup: batched engine vs the per-point loop.

Times a full-ladder, multi-workload trace-driven sweep two ways — one
batched Pallas launch (``simulate_ladder``) vs the seed per-point loop
(``simulate_reference``, one launch per (workload, capacity)) — verifies
the hit/miss counts are bit-exact, and appends a timestamped record to
``BENCH_cachesim.json`` at the repo root so the speedup is tracked across
PRs (the trace-level analogue of ``benchmarks/sweep_engine.py``).

The ladder is the whole-octave rungs (power-of-two set counts, so the
seed path gets its best-case tiling everywhere) plus the 3 MB GPU-L2
normalization point spliced in via ``capacity_ladder(include=...)``
(96 sets at 1:16 — tiled 2 x 48 by ``largest_divisor_tile``).
"""
from __future__ import annotations

import time
from datetime import datetime, timezone
from pathlib import Path

import numpy as np

from benchmarks.common import append_bench_record, emit
from repro.core.cachesim import (capacity_lines, simulate_ladder,
                                 simulate_reference, synthetic_traces)
from repro.core.constants import GPU_L2_MB, LINE_BYTES, MB
from repro.core.sweep import capacity_ladder

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_cachesim.json"

# 0.5 .. 64 MB whole octaves plus the 3 MB GPU-L2 normalization point
LADDER_MB = capacity_ladder(steps_per_octave=1, include=(GPU_L2_MB,))
SCALE = 16                                            # 1:16 capacity scale
WAYS = 16
TRACE_LEN = 2048
SEEDS = (0, 1)                                        # two workload traces
FOOTPRINT_MB = 256.0


def _per_point(traces):
    return np.stack([
        np.stack([np.asarray(simulate_reference(
            tr, capacity_lines(c, scale=SCALE), ways=WAYS))
            for c in LADDER_MB])
        for tr in traces])


def run():
    traces = synthetic_traces(
        TRACE_LEN, int(FOOTPRINT_MB * MB) // (LINE_BYTES * SCALE),
        seeds=SEEDS)

    t0 = time.perf_counter()
    engine = simulate_ladder(traces, LADDER_MB, scale=SCALE, ways=WAYS)
    cold_s = time.perf_counter() - t0

    times = []
    for _ in range(3):
        t0 = time.perf_counter()
        engine = simulate_ladder(traces, LADDER_MB, scale=SCALE, ways=WAYS)
        times.append(time.perf_counter() - t0)
    engine_s = min(times)

    _per_point(traces)               # warm the per-point jit caches
    t0 = time.perf_counter()
    ref = _per_point(traces)
    legacy_s = time.perf_counter() - t0

    parity = bool(np.array_equal(engine, ref))
    speedup = legacy_s / engine_s

    record = {
        "timestamp": datetime.now(timezone.utc).isoformat(),
        "grid": (f"{len(SEEDS)} traces x {len(LADDER_MB)} capacities x "
                 f"{TRACE_LEN} accesses (ways={WAYS}, 1:{SCALE})"),
        "ladder_engine_s": engine_s,
        "ladder_engine_cold_s": cold_s,
        "ladder_legacy_per_point_s": legacy_s,
        "speedup": speedup,
        "counts_bit_exact": parity,
    }
    append_bench_record(BENCH_PATH, record)

    emit("cachesim_ladder", engine_s * 1e6,
         f"legacy {legacy_s*1e3:.0f}ms -> engine {engine_s*1e3:.1f}ms = "
         f"{speedup:.0f}x | parity={'ok' if parity else 'MISMATCH'} | "
         f"-> {BENCH_PATH.name}")
    if not parity:
        raise AssertionError("ladder engine counts diverge from reference")
    if speedup < 5.0:
        raise AssertionError(
            f"ladder engine speedup {speedup:.1f}x below the 5x floor")


if __name__ == "__main__":
    run()
