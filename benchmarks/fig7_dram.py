"""Fig 7: DRAM access reduction vs L2 capacity (miss model + simulator).

The simulated curve now comes from the batched ladder engine
(``simulate_ladder``): one Pallas launch covers all four capacities.
"""
from __future__ import annotations

from benchmarks.common import run_and_emit
from repro.core.cachesim import dram_reduction_curve
from repro.core.dram import dram_reduction_pct


def run():
    def work():
        analytic = {c: dram_reduction_pct(c) for c in (3, 6, 7, 10, 12, 24)}
        simulated = dram_reduction_curve((3, 6, 12, 24), trace_len=40_000,
                                         use_kernel=True)
        return analytic, simulated

    def derive(out):
        analytic, sim = out
        worst = max(abs(sim[c] - analytic[c]) for c in sim)
        return (f"analytic 7MB={analytic[7]:.1f}% (paper 14.6) "
                f"10MB={analytic[10]:.1f}% (paper 19.8) "
                f"24MB={analytic[24]:.1f}% | ladder-sim "
                + " ".join(f"{c}MB={v:.1f}%" for c, v in sim.items())
                + f" | max|sim-analytic|={worst:.1f}pts")

    run_and_emit("fig7_dram_reduction", work, derive)
