"""Figs 11-13: normalized energy / latency / EDP vs capacity (scalability).

All (memory x capacity) configurations come from one batched sweep; the
per-workload evaluation then runs off those tuned configs.
"""
from __future__ import annotations

from benchmarks.common import run_and_emit
from repro.core.scaling import workload_scaling


def run():
    def work():
        return workload_scaling()

    def derive(res):
        caps = sorted(res)
        big = caps[-1]
        e = {m: 1 / res[big][m]["total"]["mean"] for m in ("STT", "SOT")}
        d = {m: 1 / res[big][m]["delay"]["mean"] for m in ("STT", "SOT")}
        edp_best = {m: 1 / min(res[c][m]["edp"]["min"] for c in caps)
                    for m in ("STT", "SOT")}
        lat_small = {m: res[caps[0]][m]["delay"]["mean"]
                     for m in ("STT", "SOT")}
        return (
            f"@{big}MB energy {e['STT']:.0f}x/{e['SOT']:.0f}x "
            f"(paper up-to 31.2/36.4) | latency {d['STT']:.1f}x/"
            f"{d['SOT']:.1f}x (paper up-to 2.1/2.6) | EDP best "
            f"{edp_best['STT']:.0f}x/{edp_best['SOT']:.0f}x "
            f"(paper up-to 65/95) | small-cap latency x"
            f"{lat_small['STT']:.1f}/{lat_small['SOT']:.1f} "
            f"(SRAM wins small, paper up-to 3.2/2)")

    run_and_emit("fig11_13_scalability", work, derive)
