"""Fig 6: batch-size impact on AlexNet EDP (iso-capacity)."""
from __future__ import annotations

from benchmarks.common import run_and_emit
from repro.core.iso import batch_sweep


def run():
    def work():
        return (batch_sweep("AlexNet", "training"),
                batch_sweep("AlexNet", "inference"))

    def derive(out):
        tr, inf = out
        def red(sw, m):
            return [round(1 / sw[b].metrics[m]["edp_with_dram"], 2)
                    for b in sorted(sw)]
        t_stt, i_stt = red(tr, "STT"), red(inf, "STT")
        t_sot, i_sot = red(tr, "SOT"), red(inf, "SOT")
        mono_t = all(a <= b + 1e-9 for a, b in zip(t_stt, t_stt[1:]))
        mono_i = all(a >= b - 1e-9 for a, b in zip(i_stt, i_stt[1:]))
        return (f"train STT {t_stt[0]}->{t_stt[-1]}x (paper 2.3->4.6, "
                f"increasing={mono_t}) | inf STT {i_stt[0]}->{i_stt[-1]}x "
                f"(paper 5.4->4.1, decreasing={mono_i}) | "
                f"train SOT {t_sot[0]}->{t_sot[-1]}x (paper 7.2->7.6) | "
                f"inf SOT {i_sot[0]}->{i_sot[-1]}x (paper 7.1->7.3)")

    run_and_emit("fig6_batch_size", work, derive)
