"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see each module for the
paper-target comparison packed into the derived column).
Run: PYTHONPATH=src python -m benchmarks.run [--only fig7]
"""
from __future__ import annotations

import argparse
import sys
import traceback

MODULES = [
    "table1_bitcell",
    "table2_cache",
    "fig3_rw_ratio",
    "fig4_5_isocap",
    "fig6_batch",
    "fig7_dram",
    "fig8_9_isoarea",
    "fig10_ppa",
    "fig11_13_scalability",
    "sweep_engine",
    "cachesim_ladder",
    "traffic_engine",
    "serve_engine",
    "serve_resilience",
    "train_engine",
    "kernels_micro",
    "crosslayer_tpu",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--gate", action="store_true",
                    help="after the selected benchmarks, run the "
                         "benchmarks.gate regression ratchet over the "
                         "BENCH_*.json histories")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failed = []
    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            mod.run()
        except Exception:
            traceback.print_exc()
            failed.append(mod_name)
    if args.gate and not failed:
        from benchmarks.gate import main as gate_main
        if gate_main([]) != 0:
            failed.append("gate")
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
