"""Fig 10: cache PPA scaling 1-32MB, incl. the published crossovers.

``ppa_scaling`` is one batched sweep over the full (memory x capacity)
grid since the sweep-engine refactor — no per-point tuning.
"""
from __future__ import annotations

from benchmarks.common import run_and_emit
from repro.core.scaling import ppa_scaling


def run():
    def work():
        return ppa_scaling()

    def derive(cfgs):
        # crossovers the paper calls out:
        #  * MRAM read latency beats SRAM beyond ~4MB
        #  * SOT read energy beats SRAM at ~7-8MB
        #  * SRAM write latency approaches STT's at 32MB
        sram, stt, sot = cfgs["SRAM"], cfgs["STT"], cfgs["SOT"]
        rl_cross = next((c for c in sorted(sram) if
                         stt[c].read_latency_ns < sram[c].read_latency_ns),
                        None)
        re_cross = next((c for c in sorted(sram) if
                         sot[c].read_energy_nj < sram[c].read_energy_nj),
                        None)
        wl32 = sram[32].write_latency_ns / stt[32].write_latency_ns
        area32 = sram[32].area_mm2 / sot[32].area_mm2
        return (f"STT read-lat crossover @ {rl_cross}MB (paper ~4-8MB) | "
                f"SOT read-energy crossover @ {re_cross}MB (paper ~7MB) | "
                f"SRAM/STT write-lat @32MB = {wl32:.2f} (paper ->~1) | "
                f"SRAM/SOT area @32MB = {area32:.1f}x")

    run_and_emit("fig10_ppa_scaling", work, derive)
