"""Train-engine speedup: fused K-step windows vs the seed per-step loop.

Runs the same token stream (the deterministic counter-hash pipeline)
through ``make_train_window`` (one jitted, state-donating ``lax.scan``
over K full train steps, batches hashed on device) and through the seed
per-step path (``make_train_step`` + host ``Pipeline`` batches, one
dispatch + metrics block per step — the launcher's ``--no-fused``
semantics), verifies bitwise loss-trajectory parity, and appends a record
to ``BENCH_train.json`` at the repo root.  Floors enforced here (and in
CI): parity must hold and the warm steps/s speedup must be >= 5x.

The config is sized so per-step HOST overhead (batch transfer, dispatch,
metrics round-trip) dominates — exactly the cost the fused window
amortizes to one drain per K steps; model compute is identical on both
paths.  The record also carries the window's train-mode NVM verdicts —
per-step SRAM vs STT/SOT energy/EDP ratios from the measured traffic
(core.crosslayer.analyze_train), closing the loop to the paper's
write-heavy training regime.
"""
from __future__ import annotations

import time
from datetime import datetime, timezone
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import append_bench_record, emit
from repro.configs import get_config, reduced
from repro.data import DataConfig, Pipeline
from repro.models import build_model
from repro.optim import AdamW, constant
from repro.train.trainer import (init_state, make_train_step,
                                 make_train_window)

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_train.json"

ARCH = "llama3-8b"
SEQ = 8
BATCH = 2
STEPS_PER_SYNC = 50          # K: fused steps per host drain
PARITY_STEPS = 20            # bitwise loss-trajectory check length
WARM_WINDOWS = 6             # timed fused windows (K steps each)
ATTN_IMPL = "naive"          # tiny seqs: the flash-scan machinery's
SPEEDUP_FLOOR = 5.0          # constant overhead would swamp the signal


def _tiny():
    cfg = reduced(get_config(ARCH), dtype="float32", num_layers=1,
                  d_model=16, d_ff=32, num_heads=1, num_kv_heads=1,
                  head_dim=16, vocab_size=128)
    model = build_model(cfg, max_seq=SEQ)
    opt = AdamW(lr=constant(1e-3))
    dcfg = DataConfig(cfg.vocab_size, SEQ, BATCH)
    return model, opt, dcfg


def run():
    model, opt, dcfg = _tiny()

    # ---- parity: K-step loss trajectory, window vs per-step oracle -----
    step_fn = jax.jit(make_train_step(model, opt, attn_impl=ATTN_IMPL),
                      donate_argnums=(0,))
    state = init_state(model, opt, jax.random.PRNGKey(0))
    data = Pipeline(dcfg)
    oracle = []
    for _ in range(PARITY_STEPS):
        state, m = step_fn(state, jax.tree.map(jnp.asarray, next(data)))
        oracle.append(float(m["loss"]))
    data.close()

    win_p = make_train_window(model, opt, steps_per_sync=PARITY_STEPS,
                              data_cfg=dcfg, record_traffic=False,
                              attn_impl=ATTN_IMPL)
    state = init_state(model, opt, jax.random.PRNGKey(0))
    _, wm = win_p(state)
    fused = np.asarray(wm["loss"]).tolist()
    parity = fused == oracle

    # ---- warm steps/s: per-step loop (launcher --no-fused semantics) ---
    state = init_state(model, opt, jax.random.PRNGKey(0))
    data = Pipeline(dcfg)
    state, m = step_fn(state, jax.tree.map(jnp.asarray, next(data)))
    jax.block_until_ready(m)                       # warm the jit
    n_ref = 3 * STEPS_PER_SYNC
    t0 = time.perf_counter()
    for _ in range(n_ref):
        state, m = step_fn(state, jax.tree.map(jnp.asarray, next(data)))
        jax.block_until_ready(m)                   # metrics block per step
    legacy_s = (time.perf_counter() - t0) / n_ref
    data.close()

    # ---- warm steps/s: fused windows -----------------------------------
    win = make_train_window(model, opt, steps_per_sync=STEPS_PER_SYNC,
                            data_cfg=dcfg, attn_impl=ATTN_IMPL)
    state = init_state(model, opt, jax.random.PRNGKey(0))
    t0 = time.perf_counter()
    state, wm = win(state)                         # cold: compile+traffic
    jax.block_until_ready(wm)
    cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(WARM_WINDOWS):
        state, wm = win(state)
        np.asarray(wm["loss"])                     # ONE drain per window
    engine_s = (time.perf_counter() - t0) / (WARM_WINDOWS * STEPS_PER_SYNC)

    speedup = legacy_s / engine_s
    verdicts = {
        v.shape: {"energy_ratio": v.energy_ratio, "edp_ratio": v.edp_ratio}
        for v in win.nvm_verdicts()}

    record = {
        "timestamp": datetime.now(timezone.utc).isoformat(),
        "grid": (f"{ARCH} tiny (1L d16 v128) b{BATCH} s{SEQ}, "
                 f"K={STEPS_PER_SYNC}, {WARM_WINDOWS} warm windows, "
                 f"parity over {PARITY_STEPS} steps"),
        "engine_step_s": engine_s,
        "engine_cold_s": cold_s,
        "legacy_per_step_s": legacy_s,
        "warm_steps_per_s": 1.0 / engine_s,
        "reference_steps_per_s": 1.0 / legacy_s,
        "speedup": speedup,
        "speedup_floor": SPEEDUP_FLOOR,
        "loss_parity": parity,
        "nvm_verdicts": verdicts,
    }
    append_bench_record(BENCH_PATH, record)

    emit("train_engine", engine_s * 1e6,
         f"ref {1/legacy_s:.0f} steps/s -> fused {1/engine_s:.0f} steps/s "
         f"= {speedup:.1f}x | parity={'ok' if parity else 'MISMATCH'} | "
         f"-> {BENCH_PATH.name}")
    if not parity:
        raise AssertionError(
            "fused window loss trajectory diverges from the per-step "
            f"oracle: {fused} vs {oracle}")
    if speedup < SPEEDUP_FLOOR:
        raise AssertionError(
            f"train engine speedup {speedup:.1f}x below the "
            f"{SPEEDUP_FLOOR:.0f}x floor")


if __name__ == "__main__":
    run()
