"""Figs 8-9: iso-area energy + EDP (with/without DRAM) vs SRAM."""
from __future__ import annotations

from benchmarks.common import run_and_emit
from repro.core.iso import iso_area, iso_area_capacities, summarize
from repro.core.profiles import paper_profiles


def run():
    def work():
        profs = paper_profiles()
        return iso_area(profs), iso_area_capacities()

    def derive(out):
        res, caps = out
        dl = [r for r in res if not r.workload.startswith("HPCG")]
        d = summarize(dl, "dynamic")
        l = summarize(dl, "leakage")
        e0 = summarize(res, "edp")
        e1 = summarize(res, "edp_with_dram")
        return (
            f"caps STT={caps['STT']:.1f}MB SOT={caps['SOT']:.1f}MB "
            f"(paper 7/10) | dyn x{d['STT']['mean']:.1f}/"
            f"{d['SOT']['mean']:.1f} (paper 2.5/1.5) | "
            f"leak 1/{1/l['STT']['mean']:.1f},1/{1/l['SOT']['mean']:.1f} "
            f"(paper 2.2/2.3) | EDP(noDRAM) "
            f"{e0['STT']['mean_reduction_x']:.1f}x/"
            f"{e0['SOT']['mean_reduction_x']:.1f}x (paper ~1.2) | "
            f"EDP(+DRAM) {e1['STT']['mean_reduction_x']:.1f}x/"
            f"{e1['SOT']['mean_reduction_x']:.1f}x (paper 2/2.3)")

    run_and_emit("fig8_9_isoarea", work, derive)
