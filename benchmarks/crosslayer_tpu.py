"""Beyond-paper: cross-layer NVM verdicts for the assigned LM architectures,
fed by the compiled multi-pod dry-run records (TPU mode)."""
from __future__ import annotations

from pathlib import Path

from benchmarks.common import run_and_emit
from repro.core.crosslayer import analyze_dryrun_dir

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def run():
    tag = next((t for t in ("final", "baseline")
                if RESULTS.exists() and list(RESULTS.glob(f"*__{t}.json"))),
               None)
    if tag is None:
        print("crosslayer_tpu,0.0,SKIPPED (run launch/dryrun first)")
        return

    def work():
        return analyze_dryrun_dir(str(RESULTS), tag=tag)

    def derive(cells):
        if not cells:
            return "no cells"
        best = min(cells, key=lambda c: c.edp_ratio["SOT"])
        worst = max(cells, key=lambda c: c.edp_ratio["SOT"])
        import statistics
        mean_sot = statistics.mean(c.edp_ratio["SOT"] for c in cells)
        mean_stt = statistics.mean(c.edp_ratio["STT"] for c in cells)
        return (f"{len(cells)} cells | mean EDP ratio STT={mean_stt:.2f} "
                f"SOT={mean_sot:.2f} | best SOT cell "
                f"{best.arch}x{best.shape} ({best.edp_ratio['SOT']:.2f}) | "
                f"worst {worst.arch}x{worst.shape} "
                f"({worst.edp_ratio['SOT']:.2f})")

    run_and_emit("crosslayer_tpu", work, derive)
