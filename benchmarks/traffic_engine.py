"""Traffic-engine speedup: batched tensor vs the per-point scalar loop.

Times a full (workload × mode × batch-grid) traffic sweep two ways — one
batched jitted engine call (``repro.core.traffic.compute_traffic``) vs
the seed per-point scalar path (``profiles.profile_reference``, one
Python layer-loop per cell) — verifies 1e-6 relative parity on every
cell, checks that a short Adam run of the differentiable claim loss
(``make_claim_loss``) stays at-or-below the frozen coordinate-descent
fit, and appends a timestamped record to ``BENCH_traffic.json`` at the
repo root (the workload-level analogue of ``benchmarks/sweep_engine.py``
/ ``benchmarks/cachesim_ladder.py``).
"""
from __future__ import annotations

import math
import time
from datetime import datetime, timezone
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import append_bench_record, emit
from repro.core.profiles import profile_reference
from repro.core.traffic import (MODES, TRAFFIC, compute_traffic,
                                make_claim_loss, paper_pack)
from repro.core.workloads import HPCG, NETWORKS
from repro.optim import AdamW, constant

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_traffic.json"

BATCHES = tuple(float(2 ** k) for k in range(11))       # 1 .. 1024
SPEEDUP_FLOOR = 10.0
ADAM_STEPS = 40


def _per_point():
    """The seed path over the same grid: one scalar call per cell."""
    out = {}
    for name in NETWORKS:
        for mode in MODES:
            for b in BATCHES:
                out[(name, mode, b)] = profile_reference(name, mode, int(b))
    for name in HPCG:
        out[(name, "hpc", 1.0)] = profile_reference(name, "hpc", 1)
    return out


def _parity(tt, ref, rtol=1e-6):
    worst = 0.0
    for (name, mode, b), p in ref.items():
        q = tt.profile(name, mode, int(b))
        for f in ("l2_reads", "l2_writes", "dram"):
            worst = max(worst, abs(getattr(q, f) / getattr(p, f) - 1.0))
    return worst < rtol, worst


def _calibration_check():
    """Short Adam run from the frozen init; best-seen must not lose."""
    claim_loss, _ = make_claim_loss()
    loss_fn = jax.jit(lambda p: claim_loss({k: jnp.exp(v)
                                            for k, v in p.items()}))
    grad_fn = jax.jit(jax.value_and_grad(
        lambda p: claim_loss({k: jnp.exp(v) for k, v in p.items()})))
    params = {k: jnp.asarray(math.log(v), jnp.float32)
              for k, v in TRAFFIC.items()}
    frozen = float(loss_fn(params))
    opt = AdamW(lr=constant(0.02), weight_decay=0.0, clip_norm=1.0,
                master_weights=False)
    state = opt.init(params)
    best = frozen
    for _ in range(ADAM_STEPS):
        l, g = grad_fn(params)
        best = min(best, float(l))
        params, state, _ = opt.update(g, state, params)
    return frozen, min(best, float(loss_fn(params)))


def run():
    pack = paper_pack()
    grid = (f"{len(pack.names)} workloads x {len(MODES)} modes x "
            f"{len(BATCHES)} batches")

    t0 = time.perf_counter()
    tt = compute_traffic(pack, BATCHES)
    cold_s = time.perf_counter() - t0

    times = []
    for _ in range(5):
        t0 = time.perf_counter()
        tt = compute_traffic(pack, BATCHES)
        times.append(time.perf_counter() - t0)
    engine_s = min(times)

    t0 = time.perf_counter()
    ref = _per_point()
    legacy_s = time.perf_counter() - t0

    parity, worst = _parity(tt, ref)
    speedup = legacy_s / engine_s
    frozen_loss, adam_loss = _calibration_check()

    record = {
        "timestamp": datetime.now(timezone.utc).isoformat(),
        "grid": grid,
        "traffic_engine_s": engine_s,
        "traffic_engine_cold_s": cold_s,
        "traffic_legacy_per_point_s": legacy_s,
        "speedup": speedup,
        "parity_rel_1e6": parity,
        "worst_rel_err": worst,
        "claim_loss_frozen": frozen_loss,
        "claim_loss_adam": adam_loss,
        "adam_beats_frozen": adam_loss <= frozen_loss,
    }
    append_bench_record(BENCH_PATH, record)

    emit("traffic_engine", engine_s * 1e6,
         f"{grid}: legacy {legacy_s*1e3:.1f}ms -> engine "
         f"{engine_s*1e3:.2f}ms = {speedup:.0f}x | "
         f"parity={'ok' if parity else 'MISMATCH'} ({worst:.1e}) | "
         f"claim loss frozen {frozen_loss:.4f} -> adam {adam_loss:.4f} | "
         f"-> {BENCH_PATH.name}")
    if not parity:
        raise AssertionError(
            f"traffic engine diverges from the scalar reference "
            f"(worst rel err {worst:.2e})")
    if speedup < SPEEDUP_FLOOR:
        raise AssertionError(
            f"traffic engine speedup {speedup:.1f}x below the "
            f"{SPEEDUP_FLOOR:.0f}x floor")
    if adam_loss > frozen_loss:
        raise AssertionError(
            f"Adam claim loss {adam_loss:.4f} worse than frozen "
            f"{frozen_loss:.4f}")


if __name__ == "__main__":
    run()
