"""Shared benchmark utilities: timing + CSV row emission."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

ROWS: List[Tuple[str, float, str]] = []


def timed(name: str, fn: Callable, *, repeats: int = 3):
    """Run fn, record (name, us_per_call, derived-summary-string)."""
    fn()  # warmup / build caches
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn()
    us = (time.perf_counter() - t0) / repeats * 1e6
    return out, us


def emit(name: str, us: float, derived: str):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}")


def run_and_emit(name: str, fn: Callable, derive: Callable[[object], str],
                 repeats: int = 1):
    out, us = timed(name, fn, repeats=repeats)
    emit(name, us, derive(out))
    return out
