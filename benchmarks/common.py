"""Shared benchmark utilities: timing, CSV row emission, BENCH records."""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Callable, List, Tuple

ROWS: List[Tuple[str, float, str]] = []


def append_bench_record(path: Path, record: dict) -> None:
    """Append ``record`` to a ``BENCH_*.json`` {latest, history} file."""
    history = []
    if path.exists():
        try:
            history = json.loads(path.read_text()).get("history", [])
        except (json.JSONDecodeError, AttributeError):
            history = []
    history.append(record)
    path.write_text(json.dumps(
        {"latest": record, "history": history}, indent=2) + "\n")


def timed(name: str, fn: Callable, *, repeats: int = 3):
    """Run fn, record (name, us_per_call, derived-summary-string)."""
    fn()  # warmup / build caches
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn()
    us = (time.perf_counter() - t0) / repeats * 1e6
    return out, us


def emit(name: str, us: float, derived: str):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}")


def run_and_emit(name: str, fn: Callable, derive: Callable[[object], str],
                 repeats: int = 1):
    out, us = timed(name, fn, repeats=repeats)
    emit(name, us, derive(out))
    return out
