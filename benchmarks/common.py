"""Shared benchmark utilities: timing, CSV row emission, BENCH records.

Timing discipline (DESIGN.md §14): JAX dispatch is asynchronous, so a
timing loop that reads ``perf_counter`` without blocking on the outputs
measures launch overhead, not the computation — the seed's ``timed``
did exactly that and undercounted every warm jitted benchmark.  ``timed``
now blocks on each iteration's outputs, and every record written through
``append_bench_record`` is stamped ``clock: "blocking"`` so the CI
ratchet (benchmarks/gate.py) never compares post-fix numbers against
pre-fix history.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path
from typing import Callable, List, Tuple

import jax

ROWS: List[Tuple[str, float, str]] = []

# Timing-discipline marker stamped into every BENCH record: "blocking"
# means the timed loop called jax.block_until_ready before reading the
# clock.  Records without the field predate the fix ("naive" clock) and
# are ratcheted separately by benchmarks/gate.py.
CLOCK = "blocking"


def append_bench_record(path: Path, record: dict) -> None:
    """Append ``record`` to a ``BENCH_*.json`` {latest, history} file.

    The write is atomic (tmp file + ``os.replace``), so a killed bench
    run can no longer truncate the file and destroy the history the CI
    ratchet depends on.  If the existing file is malformed it is
    preserved to a ``.corrupt`` sidecar instead of being clobbered, and
    the history restarts from this record.
    """
    record = dict(record)
    record.setdefault("clock", CLOCK)
    history = []
    if path.exists():
        text = path.read_text()
        try:
            loaded = json.loads(text)
            history = loaded.get("history", [])
            if not isinstance(history, list):
                raise ValueError("history is not a list")
        except (json.JSONDecodeError, AttributeError, ValueError):
            path.with_name(path.name + ".corrupt").write_text(text)
            history = []
    history.append(record)
    payload = json.dumps({"latest": record, "history": history},
                         indent=2) + "\n"
    tmp = path.with_name(f"{path.name}.tmp.{os.getpid()}")
    tmp.write_text(payload)
    os.replace(tmp, path)


def timed(name: str, fn: Callable, *, repeats: int = 3):
    """Run fn, record (name, us_per_call, derived-summary-string).

    Blocks on each call's outputs (``jax.block_until_ready``) before
    reading the clock — without this, async dispatch returns as soon as
    the work is enqueued and warm timings collapse toward launch
    overhead (regression-tested in tests/test_bench_gate.py).
    """
    jax.block_until_ready(fn())  # warmup / build caches
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = jax.block_until_ready(fn())
    us = (time.perf_counter() - t0) / repeats * 1e6
    return out, us


def emit(name: str, us: float, derived: str):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}")


def run_and_emit(name: str, fn: Callable, derive: Callable[[object], str],
                 repeats: int = 1):
    out, us = timed(name, fn, repeats=repeats)
    emit(name, us, derive(out))
    return out
