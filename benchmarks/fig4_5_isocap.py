"""Figs 4-5: iso-capacity dynamic/leakage/total energy + EDP vs SRAM."""
from __future__ import annotations

from benchmarks.common import run_and_emit
from repro.core.iso import iso_capacity, summarize
from repro.core.profiles import paper_profiles


def run():
    def work():
        profs = paper_profiles()
        res = iso_capacity(profs)
        dl = [r for r in res if not r.workload.startswith("HPCG")]
        return res, dl

    def derive(out):
        res, dl = out
        d = summarize(dl, "dynamic")
        l = summarize(dl, "leakage")
        t = summarize(dl, "total")
        e = summarize(res, "edp_with_dram")
        return (
            f"dyn x{d['STT']['mean']:.1f}/{d['SOT']['mean']:.1f} "
            f"(paper 2.2/1.3) | "
            f"leak 1/{1/l['STT']['mean']:.1f}x,1/{1/l['SOT']['mean']:.1f}x "
            f"(paper 6.3/10) | "
            f"total {1/t['STT']['mean']:.1f}x/{1/t['SOT']['mean']:.1f}x "
            f"(paper 5.3/8.6) | "
            f"EDP up to {e['STT']['best_reduction_x']:.1f}x/"
            f"{e['SOT']['best_reduction_x']:.1f}x (paper 3.8/4.7)")

    run_and_emit("fig4_5_isocapacity", work, derive)
