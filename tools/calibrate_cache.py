"""Calibrate the NVSim-lite constants against the paper's Table 2 anchors.

The loss — weighted mean |log(pred/target)| over the 30 Table-2 numbers at
the EDAP-tuned configurations — is built by ``repro.core.sweep
.make_calibration_loss`` as one differentiable batched-sweep computation,
so this is plain first-order optimization: Adam on the log of each tunable
constant, gradients via ``jax.grad`` straight through the sweep engine
(the Algorithm-1 selection is piecewise constant, envelope-style).  This
replaces the seed's 4000-iteration random-restart coordinate descent; a
few hundred Adam steps reach the same loss basin in seconds.

Run: PYTHONPATH=src python tools/calibrate_cache.py [--steps N] [--lr LR]
Prints the best CAL dict; the winner is frozen into core/cache_model.py.
"""
import argparse
import math
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core.cache_model import CAL
from repro.core.sweep import make_calibration_loss
from repro.core.table2 import TABLE2_ANCHORS
from repro.core.tuner import tune
from repro.optim import AdamW, constant

FIELDS = dict(rl="read_latency_ns", wl="write_latency_ns",
              re="read_energy_nj", we="write_energy_nj",
              lk="leakage_mw", ar="area_mm2")

TARGETS = {key: {s: row[f] for s, f in FIELDS.items()}
           for key, row in TABLE2_ANCHORS.items()}

# read/write energies drive the paper's dynamic-energy ratios (Fig 4), so
# they get extra weight; area anchors the iso-area capacities.
WEIGHTS = dict(rl=1.2, wl=1.0, re=3.0, we=2.0, lk=1.0, ar=1.5)

TUNABLE = [k for k in CAL if k not in ("wr_sector_bits",)]

# physical bounds, enforced by clipping after each step (log-space params)
BOUNDS = {"wr_flip_rate": (0.2, 1.0), "sram_cell_um2": (0.05, 0.12)}


def _to_cal(params):
    cal = {k: jnp.exp(v) for k, v in params.items()}
    cal["wr_sector_bits"] = float(CAL["wr_sector_bits"])
    return cal


def _clip(params):
    for k, (lo, hi) in BOUNDS.items():
        params[k] = jnp.clip(params[k], math.log(lo), math.log(hi))
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--lr", type=float, default=0.02)
    args = ap.parse_args()

    anchor_loss = make_calibration_loss(TARGETS, WEIGHTS, FIELDS)
    loss_fn = jax.jit(lambda p: anchor_loss(_to_cal(p)))
    grad_fn = jax.jit(jax.value_and_grad(lambda p: anchor_loss(_to_cal(p))))

    params = {k: jnp.asarray(math.log(CAL[k]), jnp.float32) for k in TUNABLE}
    opt = AdamW(lr=constant(args.lr), weight_decay=0.0, clip_norm=1.0,
                master_weights=False)
    state = opt.init(params)

    best, best_l = dict(params), float("inf")
    print(f"start loss {float(loss_fn(params)):.4f}")
    for it in range(args.steps):
        l, g = grad_fn(params)          # one sweep evaluation per step
        if float(l) < best_l:
            best, best_l = dict(params), float(l)
        params, state, _ = opt.update(g, state, params)
        params = _clip(params)
        if it % 50 == 49:
            print(f"iter {it+1}: loss {float(l):.4f} (best {best_l:.4f})")
    final_l = float(loss_fn(params))
    if final_l < best_l:
        best, best_l = dict(params), final_l

    cal = {k: float(v) for k, v in _to_cal(best).items()}
    print("\nCAL = {")
    for k in CAL:
        print(f"    {k!r}: {cal[k]:.6g},")
    print("}")
    print(f"\nfinal loss {best_l:.4f}")
    for (mem, cap), tgt in TARGETS.items():
        p = tune(mem, cap, cal)
        row = "  ".join(f"{k}={getattr(p, f):8.2f}/{tgt[k]:8.2f}"
                        for k, f in FIELDS.items())
        print(f"{mem:5s}{cap:3d}MB {row}")


if __name__ == "__main__":
    main()
