"""Calibrate the NVSim-lite constants against the paper's Table 2 anchors.

Random-restart coordinate descent in log-space over CAL; objective is the
mean |log(pred/target)| over the 30 Table-2 numbers (EDAP-tuned configs).
Run: PYTHONPATH=src python tools/calibrate_cache.py
Prints the best CAL dict; the winner is frozen into core/cache_model.py.
"""
import math
import random
import sys

sys.path.insert(0, "src")

from repro.core.cache_model import CAL
from repro.core.tuner import tune

TARGETS = {
    ("SRAM", 3): dict(rl=2.91, wl=1.53, re=0.35, we=0.32, lk=6442, ar=5.53),
    ("STT", 3): dict(rl=2.98, wl=9.31, re=0.81, we=0.31, lk=748, ar=2.34),
    ("STT", 7): dict(rl=4.58, wl=10.06, re=0.93, we=0.43, lk=1706, ar=5.12),
    ("SOT", 3): dict(rl=3.71, wl=1.38, re=0.49, we=0.22, lk=527, ar=1.95),
    ("SOT", 10): dict(rl=6.69, wl=2.47, re=0.51, we=0.40, lk=1434, ar=5.64),
}

FIELDS = dict(rl="read_latency_ns", wl="write_latency_ns",
              re="read_energy_nj", we="write_energy_nj",
              lk="leakage_mw", ar="area_mm2")

# read/write energies drive the paper's dynamic-energy ratios (Fig 4), so
# they get extra weight; area anchors the iso-area capacities.
WEIGHTS = dict(rl=1.2, wl=1.0, re=3.0, we=2.0, lk=1.0, ar=1.5)

TUNABLE = [k for k in CAL if k not in ("wr_sector_bits",)]


def loss(cal):
    total, n = 0.0, 0
    for (mem, cap), tgt in TARGETS.items():
        p = tune(mem, cap, cal)
        for k, field in FIELDS.items():
            pred = getattr(p, field)
            if pred <= 0 or tgt[k] <= 0:
                return float("inf")
            total += WEIGHTS[k] * abs(math.log(pred / tgt[k]))
            n += 1
    return total / n


def main():
    rng = random.Random(0)
    best = dict(CAL)
    best_l = loss(best)
    print(f"start loss {best_l:.4f}")
    temp = 0.5
    for it in range(4000):
        cand = dict(best)
        nkeys = rng.randint(1, 3)
        for k in rng.sample(TUNABLE, nkeys):
            cand[k] = best[k] * math.exp(rng.gauss(0, temp * 0.4))
        # physical bounds
        cand["wr_flip_rate"] = min(max(cand["wr_flip_rate"], 0.2), 1.0)
        cand["sram_cell_um2"] = min(max(cand["sram_cell_um2"], 0.05), 0.12)
        l = loss(cand)
        if l < best_l:
            best, best_l = cand, l
        if it % 500 == 499:
            temp *= 0.7
            print(f"iter {it+1}: loss {best_l:.4f}")
    print("\nCAL = {")
    for k, v in best.items():
        print(f"    {k!r}: {v:.6g},")
    print("}")
    print(f"\nfinal loss {best_l:.4f}")
    for (mem, cap), tgt in TARGETS.items():
        p = tune(mem, cap, best)
        row = "  ".join(f"{k}={getattr(p, f):8.2f}/{tgt[k]:8.2f}"
                        for k, f in FIELDS.items())
        print(f"{mem:5s}{cap:3d}MB {row}")


if __name__ == "__main__":
    main()
