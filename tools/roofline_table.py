"""Emit the EXPERIMENTS.md roofline table from results/dryrun JSONs.

PYTHONPATH=src python tools/roofline_table.py [tag]
"""
import json
import sys
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def fmt(tag="final", mesh=None):
    rows = []
    for p in sorted(RESULTS.glob(f"*__{tag}.json")):
        r = json.loads(p.read_text())
        if mesh and r["mesh"] != mesh:
            continue
        roof = r["roofline"]
        bound = max(roof["compute_s"], roof["memory_s"], roof["collective_s"])
        mf = r["model_flops_per_device"]
        frac = mf / 197e12 / bound if bound else 0
        mfr = r.get("model_flops_ratio") or 0
        rows.append((
            r["arch"], r["shape"], r["mesh"],
            roof["compute_s"] * 1e3, roof["memory_s"] * 1e3,
            roof["collective_s"] * 1e3, roof["dominant"],
            mfr, frac,
            r["memory"].get("peak_bytes_est", 0) / 2**30,
        ))
    hdr = ("| arch | shape | mesh | compute ms | memory ms | collective ms "
           "| dominant | MF/HLO | roofline frac | peak GiB |")
    sep = "|" + "---|" * 10
    out = [hdr, sep]
    for r in rows:
        out.append(f"| {r[0]} | {r[1]} | {r[2]} | {r[3]:.1f} | {r[4]:.1f} | "
                   f"{r[5]:.1f} | {r[6]} | {r[7]:.2f} | {r[8]:.3f} | "
                   f"{r[9]:.2f} |")
    return "\n".join(out)


if __name__ == "__main__":
    tag = sys.argv[1] if len(sys.argv) > 1 else "final"
    print(fmt(tag))
