"""Calibrate the workload traffic model against the paper's §4 claims.

Random-restart coordinate descent over repro.core.profiles.TRAFFIC knobs.
Claim set (all from the paper text):
  * iso-capacity DL dynamic energy: STT 2.2x, SOT 1.3x (more than SRAM)
  * iso-capacity leakage energy: 6.3x / 10x lower (avg)
  * iso-capacity total energy: 5.3x / 8.6x lower (avg)
  * iso-capacity EDP(+DRAM): up to 3.8x / 4.7x lower
  * iso-area EDP(+DRAM): 2x / 2.3x lower (avg); ~1.2x w/o DRAM
  * Fig 6 (AlexNet train, STT): 2.3x -> 4.6x over batch 4..128
  * all R/W ratios within Fig 3's [~1.5, 26]
Run: PYTHONPATH=src python tools/calibrate_traffic.py
"""
import math
import random
import sys

sys.path.insert(0, "src")

from repro.core import profiles as pr
from repro.core.iso import batch_sweep, iso_area, iso_capacity, summarize


def get_claims():
    profs = pr.paper_profiles()
    dl = [p for p in profs if p.mode != "hpc"]
    res = iso_capacity(profs)
    res_dl = [r for r in res if not r.workload.startswith("HPCG")]
    ia = iso_area(profs)
    out = {}
    s = summarize(res_dl, "dynamic")
    out["dyn_stt"] = (s["STT"]["mean"], 2.2)
    out["dyn_sot"] = (s["SOT"]["mean"], 1.3)
    s = summarize(res_dl, "leakage")
    out["leak_stt"] = (1 / s["STT"]["mean"], 6.3)
    out["leak_sot"] = (1 / s["SOT"]["mean"], 10.0)
    s = summarize(res_dl, "total")
    out["tot_stt"] = (1 / s["STT"]["mean"], 5.3)
    out["tot_sot"] = (1 / s["SOT"]["mean"], 8.6)
    s = summarize(res, "edp_with_dram")
    out["edp_stt"] = (s["STT"]["best_reduction_x"], 3.8)
    out["edp_sot"] = (s["SOT"]["best_reduction_x"], 4.7)
    s = summarize(ia, "edp_with_dram")
    out["ia_edp_stt"] = (s["STT"]["mean_reduction_x"], 2.0)
    out["ia_edp_sot"] = (s["SOT"]["mean_reduction_x"], 2.3)
    s = summarize(ia, "edp")
    out["ia_nodram_stt"] = (s["STT"]["mean_reduction_x"], 1.2)
    bs = batch_sweep("AlexNet", "training", (4, 128))
    out["fig6_lo"] = (1 / bs[4].metrics["STT"]["edp_with_dram"], 2.3)
    out["fig6_hi"] = (1 / bs[128].metrics["STT"]["edp_with_dram"], 4.6)
    # range penalty on R/W ratios
    pen = 0.0
    for p in profs:
        if p.rw_ratio > 26:
            pen += (p.rw_ratio / 26 - 1)
        if p.rw_ratio < 1.5:
            pen += (1.5 / max(p.rw_ratio, 0.1) - 1)
    return out, pen


def loss():
    claims, pen = get_claims()
    total = sum(abs(math.log(p / t)) for p, t in claims.values())
    return total / len(claims) + 0.5 * pen


KNOBS = ["k_im2col", "w_tile", "grad_tile", "fc_w_factor",
         "dram_frac_i", "dram_frac_t"]


def main():
    rng = random.Random(1)
    best = dict(pr.TRAFFIC)
    best_l = loss()
    print(f"start loss {best_l:.4f}")
    temp = 0.5
    for it in range(800):
        cand = dict(best)
        for k in rng.sample(KNOBS, rng.randint(1, 2)):
            cand[k] = best[k] * math.exp(rng.gauss(0, temp * 0.5))
        cand["fc_w_factor"] = min(max(cand["fc_w_factor"], 0.02), 1.0)
        cand["k_im2col"] = min(max(cand["k_im2col"], 0.1), 2.0)
        pr.TRAFFIC.update(cand)
        l = loss()
        if l < best_l:
            best, best_l = cand, l
        else:
            pr.TRAFFIC.update(best)
        if it % 100 == 99:
            temp *= 0.75
            print(f"iter {it+1}: loss {best_l:.4f}")
    pr.TRAFFIC.update(best)
    print("\nTRAFFIC = {")
    for k, v in best.items():
        print(f"    {k!r}: {v:.6g},")
    print("}")
    claims, pen = get_claims()
    print(f"final loss {best_l:.4f}  range-penalty {pen:.3f}")
    for k, (p, t) in claims.items():
        print(f"  {k:14s} pred={p:7.2f} target={t:7.2f}")
    from repro.core.profiles import paper_profiles
    print("R/W:", {p.label: round(p.rw_ratio, 1) for p in paper_profiles()})


if __name__ == "__main__":
    main()
