"""Calibrate the workload traffic model against the paper's §4 claims.

Adam over the differentiable claim loss built by
``repro.core.traffic.make_claim_loss``: the whole traffic → PPA →
energy/EDP pipeline is one jitted function of the six TRAFFIC knobs, so
this is plain first-order optimization — gradients via ``jax.grad``
straight through the batched engine, knobs in log-space, physical bounds
enforced by clipping (mirroring ``tools/calibrate_cache.py``).  The
frozen TRAFFIC dict is the init and the best-seen iterate is kept, so the
final loss can never be worse than the frozen coordinate-descent fit it
replaces (the seed ran 800 random-restart coordinate-descent steps over
the scalar per-point pipeline; a few hundred Adam steps reach the same
basin in seconds).

Claim set (all from the paper text):
  * iso-capacity DL dynamic energy: STT 2.2x, SOT 1.3x (more than SRAM)
  * iso-capacity leakage energy: 6.3x / 10x lower (avg)
  * iso-capacity total energy: 5.3x / 8.6x lower (avg)
  * iso-capacity EDP(+DRAM): up to 3.8x / 4.7x lower
  * iso-area EDP(+DRAM): 2x / 2.3x lower (avg); ~1.2x w/o DRAM
  * Fig 6 (AlexNet train, STT): 2.3x -> 4.6x over batch 4..128
  * all R/W ratios within Fig 3's [~1.5, 26] (range penalty)

Run: PYTHONPATH=src python tools/calibrate_traffic.py [--steps N] [--lr LR]
Prints the best TRAFFIC dict; the winner is frozen into core/traffic.py.
"""
import argparse
import math
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core.traffic import TRAFFIC, make_claim_loss
from repro.optim import AdamW, constant

KNOBS = ("k_im2col", "w_tile", "grad_tile", "fc_w_factor",
         "dram_frac_i", "dram_frac_t")

# physical bounds, enforced by clipping after each step (log-space params)
BOUNDS = {
    "k_im2col": (0.1, 2.0),       # net im2col amplification vs L1 reuse
    "w_tile": (1.0, 1e4),         # >= one sample per weight re-stream
    "grad_tile": (0.5, 1e3),
    "fc_w_factor": (0.02, 1.0),   # coalescing can only reduce streams
    "dram_frac_i": (1e-4, 0.2),   # DRAM:L2 ratios stay cache-hit-dominated
    "dram_frac_t": (1e-4, 0.2),
}


def _clip(params):
    for k, (lo, hi) in BOUNDS.items():
        params[k] = jnp.clip(params[k], math.log(lo), math.log(hi))
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--lr", type=float, default=0.02)
    args = ap.parse_args()

    claim_loss, claims_fn = make_claim_loss()
    loss_fn = jax.jit(lambda p: claim_loss({k: jnp.exp(v)
                                            for k, v in p.items()}))
    grad_fn = jax.jit(jax.value_and_grad(
        lambda p: claim_loss({k: jnp.exp(v) for k, v in p.items()})))

    params = {k: jnp.asarray(math.log(TRAFFIC[k]), jnp.float32)
              for k in KNOBS}
    opt = AdamW(lr=constant(args.lr), weight_decay=0.0, clip_norm=1.0,
                master_weights=False)
    state = opt.init(params)

    best, best_l = dict(params), float(loss_fn(params))
    print(f"start loss {best_l:.4f} (frozen TRAFFIC)")
    for it in range(args.steps):
        l, g = grad_fn(params)          # one engine evaluation per step
        if float(l) < best_l:
            best, best_l = dict(params), float(l)
        params, state, _ = opt.update(g, state, params)
        params = _clip(params)
        if it % 50 == 49:
            print(f"iter {it+1}: loss {float(l):.4f} (best {best_l:.4f})")
    final_l = float(loss_fn(params))
    if final_l < best_l:
        best, best_l = dict(params), final_l

    t = {k: float(jnp.exp(v)) for k, v in best.items()}
    print("\nTRAFFIC = {")
    for k in KNOBS:
        print(f"    {k!r}: {t[k]:.6g},")
    print("}")
    claims, pen = claims_fn(t)
    print(f"final loss {best_l:.4f}  range-penalty {pen:.3f}")
    for k, (p, tgt) in claims.items():
        print(f"  {k:14s} pred={p:7.2f} target={tgt:7.2f}")
    from repro.core.traffic import compute_traffic, paper_pack
    from repro.core.workloads import HPCG, NETWORKS
    tt = compute_traffic(paper_pack(), (4.0, 64.0), t)
    rw = {}
    for n in NETWORKS:
        rw[f"{n}-I"] = round(tt.profile(n, "inference", 4).rw_ratio, 1)
        rw[f"{n}-T"] = round(tt.profile(n, "training", 64).rw_ratio, 1)
    for n in HPCG:
        rw[n] = round(tt.profile(n, "hpc", 1).rw_ratio, 1)
    print("R/W:", rw)


if __name__ == "__main__":
    main()
