"""Batched serving demo: fused continuous batching over a reduced LM.

    PYTHONPATH=src python examples/serve_demo.py --arch qwen2-7b
"""
import argparse

import jax

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serve import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--ticks-per-sync", type=int, default=4)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    model = build_model(cfg, max_seq=args.max_len)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, slots=args.slots, max_len=args.max_len,
                 ticks_per_sync=args.ticks_per_sync, record_traffic=False)

    prompts = [[1, 2, 3], [7, 8], [11, 12, 13, 14], [21], [31, 32], [41]]
    reqs = [Request(uid=i, prompt=p, max_new_tokens=8,
                    temperature=0.0 if i % 2 == 0 else 0.8)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    windows = 0
    while eng._queue or any(s is not None for s in eng.slot_req):
        n = eng.step()
        windows += 1
        print(f"window {windows} (tick {eng.ticks:3d}): "
              f"{n} active sequences")
    for r in reqs:
        print(f"req {r.uid}: prompt={r.prompt} -> output={r.output} "
              f"(done at tick {r.done_tick})")
    print(f"served {len(prompts)} requests on {args.slots} slots in "
          f"{eng.ticks} ticks / {windows} host syncs (continuous batching)")


if __name__ == "__main__":
    main()
