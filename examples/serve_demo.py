"""Batched serving demo: continuous batching over a reduced LM.

    PYTHONPATH=src python examples/serve_demo.py --arch qwen2-7b
"""
import argparse

import jax

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serve import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    model = build_model(cfg, max_seq=args.max_len)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, slots=args.slots, max_len=args.max_len)

    prompts = [[1, 2, 3], [7, 8], [11, 12, 13, 14], [21], [31, 32], [41]]
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=8,
                           temperature=0.0 if i % 2 == 0 else 0.8))
    reqs = list(eng._queue)
    ticks = 0
    while eng._queue or any(eng.slot_req):
        n = eng.step()
        ticks += 1
        if ticks % 5 == 0:
            print(f"tick {ticks:3d}: {n} active sequences")
    for r in reqs:
        print(f"req {r.uid}: prompt={r.prompt} -> output={r.output}")
    print(f"served {len(prompts)} requests on {args.slots} slots "
          f"in {ticks} ticks (continuous batching)")


if __name__ == "__main__":
    main()
