"""Design-space exploration: sweep cache capacity x memory technology and
print the full scalability picture (paper §4.3), plus the TPU cross-layer
verdicts for any dry-run results present.

    PYTHONPATH=src python examples/nvm_sweep.py
"""
from pathlib import Path

from repro.core.scaling import ppa_scaling, workload_scaling

print("=== PPA scaling (paper Fig 10) ===")
cfgs = ppa_scaling()
print(f"{'cap':>4} | " + " | ".join(f"{m:^22}" for m in cfgs))
print(f"{'MB':>4} | " + " | ".join(f"{'rd-ns  wr-ns  mm2':^22}" for _ in cfgs))
for c in sorted(next(iter(cfgs.values()))):
    row = " | ".join(
        f"{cfgs[m][c].read_latency_ns:6.2f} {cfgs[m][c].write_latency_ns:6.2f}"
        f" {cfgs[m][c].area_mm2:7.2f}" for m in cfgs)
    print(f"{c:4.0f} | {row}")

print("\n=== workload-normalized EDP vs SRAM (paper Figs 11-13) ===")
res = workload_scaling()
print(f"{'cap':>4} | {'STT total':>10} {'STT edp':>9} | "
      f"{'SOT total':>10} {'SOT edp':>9}")
for c in sorted(res):
    r = res[c]
    print(f"{c:4.0f} | {r['STT']['total']['mean']:10.3f} "
          f"{r['STT']['edp']['mean']:9.3f} | "
          f"{r['SOT']['total']['mean']:10.3f} {r['SOT']['edp']['mean']:9.3f}")

results = Path(__file__).resolve().parents[1] / "results" / "dryrun"
if results.exists() and list(results.glob("*.json")):
    from repro.core.crosslayer import analyze_dryrun_dir
    cells = []
    for tag in ("final", "baseline"):
        try:
            cells = analyze_dryrun_dir(str(results), tag=tag)
            break
        except FileNotFoundError:
            continue  # no records under this tag; try the next
    print(f"\n=== TPU cross-layer verdicts ({len(cells)} dry-run cells) ===")
    for v in cells[:12]:
        print(f"  {v.arch:24s} {v.shape:12s} {v.mesh:8s} "
              f"EDP STT {v.edp_ratio['STT']:.2f}  SOT {v.edp_ratio['SOT']:.2f}")
else:
    print("\n(no dry-run results yet: run `python -m repro.launch.dryrun`)")
