"""End-to-end training driver: train a reduced LM for a few hundred steps
on CPU with the full production stack — fused K-step train windows with
device-hashed batches (train/trainer.py::make_train_window), AdamW,
checkpointing with auto-resume, straggler monitor.  Pass --no-fused for
the seed per-step loop (host pipeline batches, one dispatch per step).

    PYTHONPATH=src python examples/train_lm.py --arch llama3-8b --steps 200

(Reduced config by default so it runs on this CPU container; pass
--full on a real TPU mesh.)
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.data import DataConfig, Pipeline
from repro.models import build_model
from repro.optim import AdamW, warmup_cosine
from repro.train.checkpoint import CheckpointManager
from repro.train.elastic import StragglerMonitor
from repro.train.trainer import (init_state, make_train_step,
                                 make_train_window,
                                 window_boundary_crossed)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--fused", action=argparse.BooleanOptionalAction,
                    default=True, help="fused K-step train windows")
    ap.add_argument("--steps-per-sync", type=int, default=20)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg, num_layers=4, d_model=128, d_ff=256)
    model = build_model(cfg, max_seq=args.seq)
    opt = AdamW(lr=warmup_cosine(3e-3, 20, args.steps))
    dcfg = DataConfig(cfg.vocab_size, args.seq, args.batch)

    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    state = init_state(model, opt, jax.random.PRNGKey(0))
    start = 0
    if mgr.latest_step() is not None:
        state = mgr.restore(state)
        start = int(mgr.latest_step())
        print(f"resumed from checkpoint at step {start}")

    mon = StragglerMonitor(num_hosts=1)
    last_loss = None
    if args.fused:
        K = args.steps_per_sync
        win = make_train_window(model, opt, steps_per_sync=K,
                                data_cfg=dcfg)
        step, t_last = start, time.time()
        while step < args.steps:
            state, metrics = win(state)
            losses = np.asarray(metrics["loss"])   # one drain per window
            dt = time.time() - t_last
            t_last = time.time()
            mon.record(0, dt / K)
            step += K
            last_loss = float(losses[-1])
            print(f"step {step:4d}  loss {last_loss:.4f}  "
                  f"gnorm {float(np.asarray(metrics['grad_norm'])[-1]):.3f}"
                  f"  {dt / K * 1e3:.1f}ms/step (fused K={K})")
            if window_boundary_crossed(step, K, args.ckpt_every) \
                    or step >= args.steps:
                mgr.save(step, state)
        for v in win.nvm_verdicts():
            print(f"  {v.shape}: energy vs SRAM "
                  f"STT {v.energy_ratio['STT']:.3f} / "
                  f"SOT {v.energy_ratio['SOT']:.3f}")
    else:
        step_fn = jax.jit(make_train_step(model, opt))
        data = Pipeline(dcfg, start_step=start)
        t_last = time.time()
        for i, batch in zip(range(start, args.steps), data):
            state, metrics = step_fn(state, jax.tree.map(np.asarray, batch))
            dt = time.time() - t_last
            t_last = time.time()
            mon.record(0, dt)
            last_loss = float(metrics["loss"])
            if (i + 1) % 20 == 0:
                print(f"step {i+1:4d}  loss {last_loss:.4f}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}  "
                      f"lr {float(metrics['lr']):.2e}  {dt*1e3:.0f}ms")
            if (i + 1) % args.ckpt_every == 0:
                mgr.save(i + 1, state)
        data.close()
    mgr.wait()
    # a restore at/after --steps runs no steps: report that, don't crash
    tail = (f"final loss {last_loss:.4f}" if last_loss is not None
            else f"resumed at {start} >= --steps {args.steps}, nothing run")
    print(f"done; {tail}; checkpoints: {mgr.all_steps()}")


if __name__ == "__main__":
    main()
