"""End-to-end training driver: train a reduced LM for a few hundred steps
on CPU with the full production stack — data pipeline, AdamW, remat,
checkpointing with auto-resume, straggler monitor.

    PYTHONPATH=src python examples/train_lm.py --arch llama3-8b --steps 200

(Reduced config by default so it runs on this CPU container; pass
--full on a real TPU mesh.)
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.data import DataConfig, Pipeline
from repro.models import build_model
from repro.optim import AdamW, warmup_cosine
from repro.train.checkpoint import CheckpointManager
from repro.train.elastic import StragglerMonitor
from repro.train.trainer import init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg, num_layers=4, d_model=128, d_ff=256)
    model = build_model(cfg, max_seq=args.seq)
    opt = AdamW(lr=warmup_cosine(3e-3, 20, args.steps))
    step_fn = jax.jit(make_train_step(model, opt))

    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    state = init_state(model, opt, jax.random.PRNGKey(0))
    start = 0
    if mgr.latest_step() is not None:
        state = mgr.restore(state)
        start = int(mgr.latest_step())
        print(f"resumed from checkpoint at step {start}")

    data = Pipeline(DataConfig(cfg.vocab_size, args.seq, args.batch),
                    start_step=start)
    mon = StragglerMonitor(num_hosts=1)
    t_last = time.time()
    for i, batch in zip(range(start, args.steps), data):
        state, metrics = step_fn(state, jax.tree.map(np.asarray, batch))
        dt = time.time() - t_last
        t_last = time.time()
        mon.record(0, dt)
        if (i + 1) % 20 == 0:
            print(f"step {i+1:4d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"lr {float(metrics['lr']):.2e}  {dt*1e3:.0f}ms")
        if (i + 1) % args.ckpt_every == 0:
            mgr.save(i + 1, state)
    mgr.wait()
    data.close()
    print(f"done; final loss {float(metrics['loss']):.4f}; "
          f"checkpoints: {mgr.all_steps()}")


if __name__ == "__main__":
    main()
