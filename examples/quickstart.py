"""Quickstart: the DeepNVM++ pipeline end-to-end in ~40 lines.

Characterize bitcells -> EDAP-tune caches -> profile a workload -> get the
NVM-vs-SRAM verdict. Runs on CPU in seconds.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import TABLE1, tune
from repro.core.energy import evaluate, relative
from repro.core.iso import iso_area_capacities
from repro.core.profiles import profile

print("=== 1. circuit-level bitcells (paper Table 1) ===")
for name, cell in TABLE1.items():
    print(f"  {name:5s} sense {cell.sense_latency_ps:5.0f}ps  "
          f"write {cell.write_latency_ps:7.0f}ps  "
          f"area {cell.area_rel_sram:.2f}x SRAM")

print("\n=== 2. EDAP-optimal 3MB caches (paper Table 2 / Algorithm 1) ===")
cfgs = {m: tune(m, 3) for m in TABLE1}
for m, p in cfgs.items():
    print(f"  {m:5s} read {p.read_latency_ns:4.2f}ns/{p.read_energy_nj:.2f}nJ"
          f"  write {p.write_latency_ns:5.2f}ns/{p.write_energy_nj:.2f}nJ"
          f"  leak {p.leakage_mw:5.0f}mW  area {p.area_mm2:.2f}mm^2"
          f"  [banks={p.banks} rows={p.rows} {p.access_type}]")

print("\n=== 3. workload memory behavior (paper §3.3, analytic nvprof) ===")
p = profile("ResNet-18", "training", 64)
print(f"  {p.label}: {p.l2_reads/1e6:.1f}M reads, {p.l2_writes/1e6:.1f}M "
      f"writes (R/W = {p.rw_ratio:.1f}), {p.dram/1e3:.0f}K DRAM txns")

print("\n=== 4. the verdict: NVM vs SRAM for this workload ===")
base = evaluate(p, cfgs["SRAM"])
for m in ("STT", "SOT"):
    rel = relative(base, evaluate(p, cfgs[m]))
    print(f"  {m}: {1/rel['total']:.1f}x less energy, "
          f"{1/rel['edp_with_dram']:.1f}x lower EDP than SRAM")

print("\n=== 5. iso-area: how much bigger can the NVM cache be? ===")
print("  ", iso_area_capacities(), "(paper: STT 7MB, SOT 10MB)")
