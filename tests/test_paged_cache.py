"""Paged KV cache stack: pool/radix-tree invariants (hypothesis), CoW
isolation, paged kernel vs oracle, fused sampling parity, and bitwise
greedy parity of ``PagedEngine`` against ``EngineReference`` on the
standard workloads (DESIGN.md §15).

The load-bearing invariant is the same one the dense engine rests on:
with correct page isolation a request's greedy output depends only on
its own prompt — so sharing prefix pages, CoW'ing boundaries, evicting
tree leaves, or deferring admission must never change a single token.
"""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.kernels import ops, ref
from repro.models import build_model
from repro.serve import (EngineReference, PagedEngine, PagePool,
                         PagePoolExhausted, RadixTree,
                         Request, mixed_requests, pages_for, run_staggered,
                         shared_prefix_requests, staggered_groups)

MAX_LEN = 48
SLOTS = 3
PS = 8


@pytest.fixture(scope="module")
def mp():
    cfg = reduced(get_config("llama3-8b"), dtype="float32")
    model = build_model(cfg, max_seq=MAX_LEN)
    return model, model.init(jax.random.PRNGKey(0))


def _ref_outputs(mp, reqs, group=SLOTS, eos_id=7):
    model, params = mp
    eng = EngineReference(model, params, slots=SLOTS, max_len=MAX_LEN,
                          eos_id=eos_id)
    return run_staggered(eng, staggered_groups(copy.deepcopy(reqs), group))


def _paged(mp, eos_id=7, **kw):
    model, params = mp
    kw.setdefault("record_traffic", False)
    return PagedEngine(model, params, slots=SLOTS, max_len=MAX_LEN,
                       page_size=PS, eos_id=eos_id, **kw)


# --- host-side pool + tree properties ---------------------------------------


def test_pages_for():
    assert pages_for(0, 8) == 0
    assert pages_for(1, 8) == 1
    assert pages_for(8, 8) == 1
    assert pages_for(9, 8) == 2


def test_pool_alloc_release_cycle():
    pool = PagePool(4, 8)
    a = pool.alloc(3)
    assert sorted(a) == [0, 1, 2] and pool.free_pages == 1
    with pytest.raises(PagePoolExhausted, match="requested 2.*1 free"):
        pool.alloc(2)                     # short -> raise, nothing claimed
    assert pool.free_pages == 1
    pool.share(a[0])
    pool.release(a[0])
    assert pool.free_pages == 1           # still one ref on page 0
    for p in a:
        pool.release(p)
    assert pool.free_pages == 4 and pool.hwm == 3
    with pytest.raises(ValueError, match="dead page"):
        pool.release(a[0])
    pool.check()


def test_tree_match_insert_cow_boundary_coverage():
    pool = PagePool(16, 4)
    tree = RadixTree(pool)
    pages = pool.alloc(3)                 # covers 10 tokens at ps=4
    tree.insert([1, 2, 3, 4, 5, 6, 7, 8, 9, 10], pages)
    for p in pages:                       # tree refs alone keep them live
        pool.release(p)
    # full match mid-edge: 6 tokens -> ceil(6/4)=2 pages, the second is
    # the partially-covered boundary page the engine must CoW
    m, shared = tree.match([1, 2, 3, 4, 5, 6])
    assert m == 6 and shared == pages[:2]
    # divergence after 4 tokens -> exactly the full page is reusable
    m, shared = tree.match([1, 2, 3, 4, 99, 98])
    assert m == 4 and shared == pages[:1]
    m, shared = tree.match([42])
    assert (m, shared) == (0, [])
    pool.check(tree.held_refs())


# (hypothesis property tests live in tests/test_paged_properties.py,
# following the *_properties.py convention so this file runs without the
# optional dependency)


# --- paged decode kernel vs oracle ------------------------------------------


def _rand_paged(seed, B=3, nb=4, ps=8, K=2, G=2, hd=16, share=True):
    rng = np.random.default_rng(seed)
    P = B * nb + 1                        # + TRASH
    k = jnp.asarray(rng.normal(size=(P, ps, K, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(P, ps, K, hd)), jnp.float32)
    pt = np.arange(B * nb).reshape(B, nb).astype(np.int32)
    if share:                             # rows 1+ share row 0's first page
        pt[1:, 0] = pt[0, 0]
    q = jnp.asarray(rng.normal(size=(B, K * G, hd)), jnp.float32)
    pos = jnp.asarray(rng.integers(0, nb * ps, size=B), jnp.int32)
    return q, k, v, jnp.asarray(pt), pos


@pytest.mark.parametrize("window", [0, 11])
def test_paged_kernel_matches_oracle(window):
    q, k, v, pt, pos = _rand_paged(0)
    out = ops.paged_decode_attention(q, k, v, pt, pos, window)
    want = ref.paged_decode_attention_ref(q, k, v, pt, pos, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_paged_fused_scatter_bitwise_and_attends_new_kv():
    q, k, v, pt, pos = _rand_paged(1, share=False)
    rng = np.random.default_rng(2)
    nk = jnp.asarray(rng.normal(size=(3, 2, 16)), jnp.float32)
    nv = jnp.asarray(rng.normal(size=(3, 2, 16)), jnp.float32)
    o, k2, v2 = ops.paged_decode_attention_fused(q, k, v, nk, nv, pt, pos, 0)
    ps = 8
    ek, ev = np.array(k), np.array(v)
    for b in range(3):
        page = int(pt[b, int(pos[b]) // ps])
        ek[page, int(pos[b]) % ps] = np.asarray(nk[b])
        ev[page, int(pos[b]) % ps] = np.asarray(nv[b])
    np.testing.assert_array_equal(np.asarray(k2), ek)
    np.testing.assert_array_equal(np.asarray(v2), ev)
    want = ref.paged_decode_attention_ref(q, jnp.asarray(ek), jnp.asarray(ev),
                                          pt, pos)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_paged_kernel_ignores_pages_beyond_pos():
    """DMA clamping: garbage in pages past a row's depth cannot leak."""
    q, k, v, pt, pos = _rand_paged(3, share=False)
    pos = jnp.asarray([2, 9, 17], jnp.int32)     # well inside the table
    base = ops.paged_decode_attention(q, k, v, pt, pos, 0)
    k2 = k.at[np.asarray(pt)[:, 3]].set(1e9)     # poison last mapped pages
    v2 = v.at[np.asarray(pt)[:, 3]].set(1e9)
    poisoned = ops.paged_decode_attention(q, k2, v2, pt, pos, 0)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(poisoned))


# --- fused sampling ----------------------------------------------------------


def test_fused_sample_greedy_bitwise_argmax_with_cross_block_ties():
    rng = np.random.default_rng(0)
    logits = rng.normal(size=(5, 512)).astype(np.float32)
    logits[1, 100] = logits[1, 300] = 50.0       # tie across blocks
    logits[2, 0] = logits[2, 511] = 50.0         # tie at both edges
    lg = jnp.asarray(logits)
    temps = jnp.zeros(5, jnp.float32)
    key = jax.random.PRNGKey(42)
    got = ops.fused_sample(lg, temps, key, bv=128)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(jnp.argmax(lg, axis=-1)))


def test_fused_sample_temperature_deterministic_and_in_range():
    rng = np.random.default_rng(1)
    lg = jnp.asarray(rng.normal(size=(4, 256)), jnp.float32)
    temps = jnp.asarray([0.0, 0.7, 1.3, 0.0], jnp.float32)
    k1, k2 = jax.random.PRNGKey(0), jax.random.PRNGKey(9)
    a = np.asarray(ops.fused_sample(lg, temps, k1))
    b = np.asarray(ops.fused_sample(lg, temps, k1))
    c = np.asarray(ops.fused_sample(lg, temps, k2))
    np.testing.assert_array_equal(a, b)          # same key -> same draw
    assert ((a >= 0) & (a < 256)).all()
    # greedy rows ignore the key entirely
    argm = np.asarray(jnp.argmax(lg, axis=-1))
    assert a[0] == c[0] == argm[0] and a[3] == c[3] == argm[3]


def test_fused_sample_tracks_softmax_distribution():
    """Gumbel-max frequencies approach softmax(logits/T) probabilities."""
    lg = jnp.asarray(np.tile([[2.0, 1.0, 0.0, -1e9]], (256, 1)), jnp.float32)
    temps = jnp.full(256, 1.0, jnp.float32)
    counts = np.zeros(4)
    for s in range(8):
        toks = np.asarray(ops.fused_sample(lg, temps, jax.random.PRNGKey(s)))
        counts += np.bincount(toks, minlength=4)
    freq = counts / counts.sum()
    want = np.asarray(jax.nn.softmax(jnp.asarray([2.0, 1.0, 0.0, -1e9])))
    np.testing.assert_allclose(freq, want, atol=0.05)


# --- engine parity ----------------------------------------------------------


@pytest.mark.parametrize("attn_impl", ["xla", "pallas_paged"])
@pytest.mark.parametrize("k", [1, 4])
def test_paged_engine_bitwise_parity_mixed_staggered_eos(mp, attn_impl, k):
    reqs = mixed_requests(8, seed=11, vocab=512, prompt_lens=(2, 12),
                          max_new=(2, 9))
    want = _ref_outputs(mp, reqs, group=2)
    eng = _paged(mp, ticks_per_sync=k, attn_impl=attn_impl)
    got = run_staggered(eng, staggered_groups(copy.deepcopy(reqs), 2))
    assert got == want
    eng.pool.check(eng.tree.held_refs())   # all slots free -> tree-only refs


@pytest.mark.parametrize("attn_impl", ["xla", "pallas_paged"])
def test_paged_engine_bitwise_parity_shared_prefix_cow(mp, attn_impl):
    # template_len 26 % 8 = 2 -> every reuse CoWs a boundary page
    reqs = shared_prefix_requests(9, seed=4, vocab=512, num_templates=2,
                                  template_len=26, suffix_lens=(2, 6),
                                  max_new=(2, 8))
    want = _ref_outputs(mp, reqs, group=SLOTS)
    eng = _paged(mp, ticks_per_sync=4, attn_impl=attn_impl)
    got = run_staggered(eng, staggered_groups(copy.deepcopy(reqs), SLOTS))
    assert got == want
    st = eng.paged_stats()
    assert st["cow_copies"] > 0 and st["prefix_tokens"] > 0
    eng.pool.check(eng.tree.held_refs())


def test_cow_isolation_owner_keeps_decoding_into_boundary_page(mp):
    """A long-running owner writes decode KV into its boundary page AFTER
    the tree registered it; a sharer CoWs that page.  Both outputs must
    equal their solo runs bit-for-bit."""
    template = list(range(100, 126))              # 26 tokens, 26 % 8 != 0
    a = Request(uid=0, prompt=template + [7, 9], max_new_tokens=14)
    b = Request(uid=1, prompt=template + [3, 5], max_new_tokens=6)
    solo = {}
    for r in (a, b):
        solo.update(_ref_outputs(mp, [r], group=1))
    eng = _paged(mp, ticks_per_sync=2)
    eng.submit(copy.deepcopy(a))
    eng.step()                                    # owner decoding already
    got = run_staggered(eng, [[copy.deepcopy(b)]])
    assert got[1] == solo[1]
    assert eng.paged_stats()["cow_copies"] >= 1


def test_tight_pool_defers_and_stays_bitwise(mp):
    reqs = shared_prefix_requests(8, seed=5, vocab=512, num_templates=2,
                                  template_len=26, suffix_lens=(2, 6),
                                  max_new=(2, 8))
    want = _ref_outputs(mp, reqs)
    nb = MAX_LEN // PS
    eng = _paged(mp, ticks_per_sync=2, num_pages=2 * nb + 2)
    got = run_staggered(eng, staggered_groups(copy.deepcopy(reqs), SLOTS))
    assert got == want
    st = eng.paged_stats()
    assert st["deferred"] > 0                     # pressure actually hit
    assert st["pages_hwm"] <= 2 * nb + 2
    eng.pool.check(eng.tree.held_refs())


def test_eviction_under_pressure_recycles_tree_pages(mp):
    """Distinct prompts with no sharing: once the pool fills with dead
    requests' tree-pinned pages, admission must LRU-evict leaves instead
    of deferring forever."""
    reqs = mixed_requests(10, seed=2, vocab=512, prompt_lens=(9, 14),
                          max_new=(2, 4))
    want = _ref_outputs(mp, reqs, group=1)
    nb = MAX_LEN // PS
    eng = _paged(mp, ticks_per_sync=2, num_pages=2 * nb)
    got = run_staggered(eng, staggered_groups(copy.deepcopy(reqs), 1))
    assert got == want
    assert eng.paged_stats()["evicted_pages"] > 0
    eng.pool.check(eng.tree.held_refs())


def test_paged_engine_fused_sampling_greedy_parity(mp):
    reqs = mixed_requests(6, seed=9, vocab=512, prompt_lens=(2, 10),
                          max_new=(2, 7))
    want = _ref_outputs(mp, reqs)
    eng = _paged(mp, ticks_per_sync=4, attn_impl="pallas_paged",
                 sample_impl="pallas")
    got = run_staggered(eng, staggered_groups(copy.deepcopy(reqs), SLOTS))
    assert got == want


def test_charge_prefill_ticks_rewards_prefix_sharing(mp):
    """With prefill charged to the tick clock, the paged engine's mean
    TTFT on a shared-prefix workload beats the dense engine's by the
    margin prefix sharing buys (the bench asserts >= 1.5x; here we pin
    the direction and that outputs stay bitwise-identical)."""
    from repro.serve import Engine, latency_summary
    model, params = mp
    reqs = shared_prefix_requests(9, seed=6, vocab=512, num_templates=2,
                                  template_len=26, suffix_lens=(2, 6),
                                  max_new=(3, 8))
    want = _ref_outputs(mp, reqs)
    dense = Engine(model, params, slots=SLOTS, max_len=MAX_LEN, eos_id=7,
                   ticks_per_sync=2, record_traffic=False,
                   charge_prefill_ticks=True)
    rd = copy.deepcopy(reqs)
    assert run_staggered(dense, staggered_groups(rd, SLOTS)) == want
    paged = _paged(mp, ticks_per_sync=2, charge_prefill_ticks=True)
    rp = copy.deepcopy(reqs)
    assert run_staggered(paged, staggered_groups(rp, SLOTS)) == want
    ttft_d = latency_summary(rd)["ticks"]["ttft"]["mean"]
    ttft_p = latency_summary(rp)["ticks"]["ttft"]["mean"]
    assert ttft_p < ttft_d


# --- serve-mode NVM verdict plumbing ----------------------------------------


def _decode_rec(**extra):
    roof = {"flops_per_device": 1e9, "bytes_per_device": 1e8,
            "collective_bytes": 0.0, "compute_s": 1e-4, "memory_s": 8e-4,
            "collective_s": 0.0}
    return {"arch": "a", "mesh": "1dev", "kind": "decode",
            "shape": "serve_decode_b3_l48", "ticks": 10,
            "roofline": roof, **extra}


def test_unique_page_fraction_scales_verdict_traffic():
    from repro.core.crosslayer import analyze_serve
    full = analyze_serve([_decode_rec()])[0]
    half = analyze_serve([_decode_rec(unique_page_fraction=0.5)])[0]
    assert half.reads == pytest.approx(full.reads * 0.5)
    assert half.writes == pytest.approx(full.writes * 0.5)
    assert half.step_s < full.step_s      # memory-bound window shrinks
    with pytest.raises(ValueError, match="unique_page_fraction"):
        analyze_serve([_decode_rec(unique_page_fraction=0.0)])


def test_paged_serve_records_carry_measured_fraction(mp):
    model, params = mp
    eng = PagedEngine(model, params, slots=SLOTS, max_len=MAX_LEN,
                      page_size=PS, eos_id=7, ticks_per_sync=2)
    reqs = shared_prefix_requests(6, seed=3, vocab=512, num_templates=1,
                                  template_len=26, suffix_lens=(2, 5),
                                  max_new=(3, 6))
    run_staggered(eng, staggered_groups(reqs, SLOTS))
    recs = eng.serve_records()
    dec = [r for r in recs if r["kind"] == "decode"]
    assert dec and 0.0 < dec[0]["unique_page_fraction"] < 1.0
    verdicts = eng.nvm_verdicts()
    assert verdicts and all(v.reads > 0 for v in verdicts)


# --- constructor validation --------------------------------------------------


def test_paged_engine_validation(mp):
    model, params = mp
    with pytest.raises(ValueError, match="multiple of page_size"):
        PagedEngine(model, params, slots=2, max_len=50, page_size=8)
    with pytest.raises(ValueError, match="full-length"):
        PagedEngine(model, params, slots=2, max_len=48, page_size=8,
                    num_pages=3)
    with pytest.raises(ValueError, match="attn_impl"):
        PagedEngine(model, params, slots=2, max_len=48, page_size=8,
                    attn_impl="pallas_decode")
    with pytest.raises(ValueError, match="sample_impl"):
        PagedEngine(model, params, slots=2, max_len=48, page_size=8,
                    sample_impl="bogus")
