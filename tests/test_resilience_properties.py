"""Hypothesis property test for the chaos + resilience machinery: random
``FaultPlan`` faults interleaved with admissions, steps, and preemptions
on a tight-pool ``PagedEngine`` must preserve, at EVERY step,

  * exact page-refcount conservation: pool refs == tree-held + slot-held
    + plan-held (stolen) references — no leak, no double-free (both are
    ``PagePool.check`` failures),
  * progress: the bounded run loop always terminates, and
  * terminal-state discipline: every submitted request ends in exactly
    one terminal state, with ``done`` true iff that state is DONE.
"""
from collections import Counter

import jax
import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serve import (DONE, TERMINAL_STATES, Fault, FaultPlan,
                         PagedEngine, Request, ShedPolicy,
                         WindowWatchdog, mixed_requests)

MAX_LEN = 24
SLOTS = 2
NUM_PAGES = 8          # tight: concurrent long requests contend for pages

FAULT_KINDS = ("nan_logits", "kv_corrupt", "pool_exhaust", "cow_storm",
               "window_stall")


@pytest.fixture(scope="module")
def engine():
    cfg = reduced(get_config("llama3-8b"), dtype="float32")
    model = build_model(cfg, max_seq=MAX_LEN)
    params = model.init(jax.random.PRNGKey(0))
    return PagedEngine(model, params, slots=SLOTS, max_len=MAX_LEN,
                       page_size=4, num_pages=NUM_PAGES, ticks_per_sync=2,
                       record_traffic=False)


def _conserved(eng, plan):
    slot_refs: Counter = Counter()
    for s, r in enumerate(eng.slot_req):
        if r is not None:
            slot_refs.update(eng._slot_pages[s])
    eng.pool.check(eng.tree.held_refs() + slot_refs + plan.held_refs())


@given(data=st.data())
@settings(max_examples=8, deadline=None)
def test_chaos_interleaving_conserves_refs_and_terminates(engine, data):
    faults = [
        Fault(kind=data.draw(st.sampled_from(FAULT_KINDS)),
              at=data.draw(st.integers(0, 4)),
              count=data.draw(st.integers(1, 2)),
              pages=data.draw(st.integers(0, 3)),
              hold=data.draw(st.integers(0, 2)))
        for _ in range(data.draw(st.integers(1, 3)))
    ]
    plan = FaultPlan(faults, seed=data.draw(st.integers(0, 99)))
    engine.reset()
    engine.fault_plan = plan
    engine.shed_policy = ShedPolicy(max_defers=4, max_retries=2)
    engine.watchdog = WindowWatchdog(max_attempts=2, backoff_s=0.0)

    n = data.draw(st.integers(2, 5))
    reqs = mixed_requests(n, seed=data.draw(st.integers(0, 99)), vocab=512,
                          prompt_lens=(2, 8), max_new=(2, 8))
    deadline = data.draw(st.sampled_from([None, 20.0]))
    for r in reqs:
        r.deadline = deadline
        engine.submit(r)
        _conserved(engine, plan)

    for _ in range(data.draw(st.integers(1, 6))):
        op = data.draw(st.sampled_from(["step", "step", "preempt"]))
        if op == "step":
            engine.step()
        else:
            occupied = [s for s, r in enumerate(engine.slot_req)
                        if r is not None]
            if occupied:
                engine.preempt_slot(data.draw(st.sampled_from(occupied)))
        _conserved(engine, plan)

    left = engine.run(max_ticks=600)
    assert left == 0, f"run() left {left} requests unfinished"
    _conserved(engine, plan)

    for r in reqs:
        assert r.state in TERMINAL_STATES, (r.uid, r.state)
        assert r.done == (r.state == DONE)
        assert r.done_tick is not None and r.done_time is not None
    # at rest, with chaos's stolen pages returned, every page reference
    # is attributable to the tree alone (slots drained) — and a full
    # clear proves nothing leaked
    plan.release_held()
    _conserved(engine, plan)
    engine.tree.clear()
    engine.pool.check(Counter())
    assert engine.pool.free_pages == engine.pool.num_pages
