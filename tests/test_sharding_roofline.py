"""Sharding rule engine + HLO analyzer + roofline + crosslayer + cachesim."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.launch.hlo_analysis import analyze_hlo, parse_hlo
from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, Roofline
from repro.sharding.rules import default_rules, spec_for

MESH16 = {"data": 16, "model": 16}
MESH512 = {"pod": 2, "data": 16, "model": 16}


def test_spec_divisibility_basic():
    rules = default_rules()
    spec = spec_for(("batch", "seq", None), (256, 4096, 512), MESH16, rules)
    assert spec[0] == "data"
    # kv_heads=8 can't take model(16); kv_seq picks it up
    spec = spec_for(("batch", "kv_seq", "kv_heads", "head_dim"),
                    (128, 32768, 8, 128), MESH16, rules)
    assert spec[1] == "model" and (len(spec) < 3 or spec[2] is None)
    # kv_heads=16 wins over kv_seq (higher priority)
    spec = spec_for(("batch", "kv_seq", "kv_heads", "head_dim"),
                    (128, 32768, 16, 128), MESH16, rules)
    assert spec[2] == "model" and spec[1] is None


def test_spec_multipod_batch():
    rules = default_rules(multi_pod=True)
    spec = spec_for(("batch", "seq"), (256, 4096), MESH512, rules)
    assert spec[0] == ("pod", "data")


def test_spec_experts_fallback():
    rules = default_rules()
    # 40 experts don't divide 16 -> expert_ffn gets model
    spec = spec_for(("experts", "ffn_in", "expert_ffn"), (40, 1536, 512),
                    MESH16, rules)
    assert spec[0] is None and spec[2] == "model"
    # 64 experts divide -> EP
    spec = spec_for(("experts", "ffn_in", "expert_ffn"), (64, 2048, 1408),
                    MESH16, rules)
    assert spec[0] == "model"


@given(dims=st.lists(st.sampled_from([1, 2, 3, 8, 16, 40, 64, 128, 256]),
                     min_size=1, max_size=4),
       names=st.lists(st.sampled_from(["batch", "heads", "ffn", "vocab",
                                       "kv_seq", "experts", None]),
                      min_size=1, max_size=4))
@settings(max_examples=100, deadline=None)
def test_spec_never_violates_divisibility(dims, names):
    n = min(len(dims), len(names))
    dims, names = tuple(dims[:n]), tuple(names[:n])
    rules = default_rules()
    spec = spec_for(names, dims, MESH16, rules)
    used = []
    for dim, ax in zip(dims, tuple(spec) + (None,) * (n - len(spec))):
        if ax is None:
            continue
        axes = (ax,) if isinstance(ax, str) else ax
        size = int(np.prod([MESH16[a] for a in axes]))
        assert dim % size == 0
        used += list(axes)
    assert len(used) == len(set(used))  # each mesh axis used at most once


# --- HLO analyzer --------------------------------------------------------------


_FAKE_HLO = """
%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %dot.1 = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%dot.1), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%add
  %t = (s32[], f32[8,16]) tuple(%i, %ar)
  ROOT %r = (s32[], f32[8,16]) tuple(%i, %ar)
}
%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  ROOT %c = pred[] constant(true)
}
ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16]{1,0} parameter(0)
  %t0 = (s32[], f32[8,16]) tuple(%a, %a)
  %w0 = (s32[], f32[8,16]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%w0), index=1
}
"""


def test_hlo_while_multiplier_flops_and_collectives():
    stats = analyze_hlo(_FAKE_HLO)
    # dot: 2 * 8*16 * 16 = 4096 flops, x10 trips
    assert stats.flops == pytest.approx(4096 * 10)
    # all-reduce payload 8*16*4 bytes, ring 2(n-1)/n with n=4, x10
    want = 8 * 16 * 4 * 2 * 3 / 4 * 10
    assert stats.collective_link_bytes == pytest.approx(want)
    assert stats.collective_counts["all-reduce"] == 10


def test_hlo_analyzer_on_real_compiled_scan():
    L, M = 7, 32

    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=L)
        return y

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((M, M), jnp.float32),
        jax.ShapeDtypeStruct((M, M), jnp.float32)).compile()
    stats = analyze_hlo(compiled.as_text())
    want = 2 * M * M * M * L
    assert abs(stats.flops / want - 1) < 0.01


def test_roofline_terms():
    r = Roofline(flops_per_device=PEAK_FLOPS, bytes_per_device=HBM_BW,
                 collective_bytes=2 * ICI_BW, collectives={},
                 collective_counts={}, temp_bytes=0, arg_bytes=0)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(1.0)
    assert r.collective_s == pytest.approx(2.0)
    assert r.dominant == "collective"
    assert r.model_flops_util(PEAK_FLOPS) == pytest.approx(0.5)


# --- crosslayer -----------------------------------------------------------------


def test_crosslayer_verdict():
    from repro.core.crosslayer import analyze_record
    rec = {"arch": "x", "shape": "train_4k", "mesh": "16x16",
           "roofline": {"bytes_per_device": 1e12, "compute_s": 1.0,
                        "memory_s": 1.2, "collective_s": 0.3}}
    v = analyze_record(rec)
    assert v.reads > v.writes > 0
    for m in ("STT", "SOT"):
        assert 0 < v.energy_ratio[m] < 10
        assert 0 < v.edp_ratio[m] < 10


# --- cache simulator vs analytic miss model --------------------------------------


def test_simulated_miss_curve_matches_analytic():
    from repro.core.cachesim import dram_reduction_curve
    from repro.core.dram import dram_reduction_pct
    sim = dram_reduction_curve((3, 7, 10), trace_len=150_000, seed=3)
    assert abs(sim[7] - dram_reduction_pct(7)) < 6.0
    assert abs(sim[10] - dram_reduction_pct(10)) < 7.0
    assert sim[7] < sim[10]
