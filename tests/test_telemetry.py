"""Percentile math, latency summaries, and the Chrome-trace exporter.

Model-free: requests are hand-stamped so every expected TTFT/TPOT/E2E
value is computable by hand (the engine-integration side lives in
tests/test_serve_engine.py).
"""
import json

import numpy as np
import pytest

from repro.serve import Request
from repro.serve.telemetry import (Tracer, latency_summary, percentile,
                                   request_latency, summarize,
                                   validate_chrome_trace)


# --- percentile math --------------------------------------------------------


def test_percentile_hand_computed():
    # linear interpolation on [1, 2, 3, 4]: p50 sits halfway between the
    # 2nd and 3rd order statistics
    assert percentile([4, 1, 3, 2], 50) == 2.5
    assert percentile([4, 1, 3, 2], 0) == 1.0
    assert percentile([4, 1, 3, 2], 100) == 4.0
    # p25 of [0, 10]: rank 0.25 -> 2.5
    assert percentile([10, 0], 25) == 2.5
    # 1..100: rank 99 * 0.99 = 98.01 -> 99 + 0.01 * (100 - 99)
    assert percentile(list(range(1, 101)), 99) == pytest.approx(99.01)
    assert percentile([7.0], 99) == 7.0


def test_percentile_matches_numpy_linear():
    rng = np.random.default_rng(0)
    xs = rng.lognormal(0.0, 1.0, size=137)
    for q in (0, 10, 50, 95, 99, 100):
        assert percentile(xs, q) == pytest.approx(
            float(np.percentile(xs, q)), rel=1e-12)


def test_percentile_validation():
    with pytest.raises(ValueError, match="empty"):
        percentile([], 50)
    with pytest.raises(ValueError, match="outside"):
        percentile([1.0], 101)


def test_summarize_keys():
    s = summarize([1.0, 2.0, 3.0])
    assert set(s) == {"p50", "p95", "p99", "mean", "max"}
    assert s["p50"] == 2.0 and s["mean"] == 2.0 and s["max"] == 3.0
    assert summarize([]) == {}


# --- per-request latency ----------------------------------------------------


def _stamped(uid=0, *, arrival=None, submit_tick=0, submit_time=10.0,
             admit_tick=2, admit_time=10.5, done_tick=6, done_time=11.3,
             n_tokens=5):
    r = Request(uid=uid, prompt=[1, 2], max_new_tokens=n_tokens,
                output=list(range(n_tokens)), arrival=arrival)
    r.submit_tick, r.submit_time = submit_tick, submit_time
    r._mark_admitted(admit_tick, admit_time)
    r._mark_done(done_tick, done_time)
    return r


def test_request_latency_hand_computed():
    lat = request_latency(_stamped())
    assert lat["wall"]["ttft_s"] == pytest.approx(0.5)
    assert lat["wall"]["e2e_s"] == pytest.approx(1.3)
    # 5 tokens, done - first_token = 0.8 s over 4 decode tokens
    assert lat["wall"]["tpot_s"] == pytest.approx(0.2)
    assert lat["ticks"]["ttft"] == 2
    assert lat["ticks"]["e2e"] == 6
    assert lat["ticks"]["tpot"] == pytest.approx(1.0)


def test_request_latency_uses_arrival_when_set():
    lat = request_latency(_stamped(arrival=1.5))
    # tick-domain latencies charge the admission delay from arrival
    assert lat["ticks"]["ttft"] == pytest.approx(0.5)
    assert lat["ticks"]["e2e"] == pytest.approx(4.5)
    # wall-clock still measures from the submit stamp
    assert lat["wall"]["ttft_s"] == pytest.approx(0.5)


def test_request_latency_single_token_has_no_tpot():
    lat = request_latency(_stamped(n_tokens=1, done_tick=2, done_time=10.5))
    assert "tpot_s" not in lat["wall"] and "tpot" not in lat["ticks"]
    assert lat["ticks"]["e2e"] == 2


def test_request_latency_none_for_unfinished():
    r = Request(uid=0, prompt=[1], max_new_tokens=2)
    assert request_latency(r) is None


def test_latency_summary_counts_and_percentiles():
    reqs = [_stamped(uid=i, done_time=11.0 + i) for i in range(4)]
    reqs.append(Request(uid=9, prompt=[1], max_new_tokens=2))  # unfinished
    s = latency_summary(reqs)
    assert s["n"] == 5 and s["completed"] == 4
    assert s["tokens"] == 20
    # e2e wall times are 1, 2, 3, 4 s
    assert s["wall"]["e2e_s"]["p50"] == pytest.approx(2.5)
    assert s["wall"]["e2e_s"]["max"] == pytest.approx(4.0)
    assert s["ticks"]["ttft"]["p50"] == 2


def test_latency_summary_empty():
    s = latency_summary([])
    assert s["n"] == 0 and s["completed"] == 0
    assert s["wall"] == {} and s["ticks"] == {}


# --- chrome trace export ----------------------------------------------------


def test_tracer_exports_valid_chrome_trace(tmp_path):
    tr = Tracer(name="t")
    tr.span("prefill P=8", "prefill", 100.0, 100.5, args={"tick": 0})
    tr.span("decode_window", "decode", 100.5, 101.0, args={"K": 4})
    tr.counter("active_slots", {"active": 3}, 100.5)
    trace = tr.to_chrome_trace()
    validate_chrome_trace(trace)
    xs = [e for e in trace["traceEvents"] if e["ph"] == "X"]
    assert [e["name"] for e in xs] == ["prefill P=8", "decode_window"]
    # rebased to the first event, microseconds
    assert xs[0]["ts"] == 0.0
    assert xs[0]["dur"] == pytest.approx(0.5e6)
    assert xs[1]["ts"] == pytest.approx(0.5e6)
    cs = [e for e in trace["traceEvents"] if e["ph"] == "C"]
    assert cs[0]["args"] == {"active": 3}
    # save() round-trips through json and re-validates
    path = tr.save(tmp_path / "trace.json")
    validate_chrome_trace(json.loads(path.read_text()))


def test_tracer_rejects_negative_span():
    tr = Tracer()
    with pytest.raises(ValueError, match="end"):
        tr.span("x", "c", 2.0, 1.0)


def test_validate_chrome_trace_rejects_malformed():
    with pytest.raises(ValueError, match="traceEvents"):
        validate_chrome_trace({"events": []})
    with pytest.raises(ValueError, match="phase"):
        validate_chrome_trace({"traceEvents": [{"ph": "Z"}]})
    with pytest.raises(ValueError, match="missing"):
        validate_chrome_trace({"traceEvents": [{"ph": "X", "name": "a"}]})
    with pytest.raises(ValueError, match="non-negative"):
        validate_chrome_trace({"traceEvents": [
            {"ph": "X", "name": "a", "ts": -1.0, "dur": 1.0,
             "pid": 0, "tid": 0}]})


# --- nested begin/end spans + instants (paged-engine tracing) ---------------


def test_tracer_nested_spans_and_instants(tmp_path):
    tr = Tracer(name="t")
    tr.begin("admit", "serve", 10.0, args={"n": 2})
    tr.begin("prefill_chunk S=8", "serve", 10.1)
    tr.instant("cow_copy", "serve", 10.15, args={"pairs": 1})
    tr.end(10.3)
    tr.instant("page_gather", "serve", 10.35, args={"upf": 0.5})
    tr.end(10.4, args={"pages_in_use": 7})
    trace = tr.to_chrome_trace()
    validate_chrome_trace(trace)
    bs = [e for e in trace["traceEvents"] if e["ph"] == "B"]
    es = [e for e in trace["traceEvents"] if e["ph"] == "E"]
    ins = [e for e in trace["traceEvents"] if e["ph"] == "i"]
    assert [e["name"] for e in bs] == ["admit", "prefill_chunk S=8"]
    assert len(es) == 2
    # E events close innermost-first: prefill end (10.3) precedes admit
    # end (10.4) in call order, and end() args ride on the E event
    assert es[0]["ts"] < es[1]["ts"]
    assert es[1]["args"] == {"pages_in_use": 7}
    assert [e["name"] for e in ins] == ["cow_copy", "page_gather"]
    assert all(e["s"] == "t" for e in ins)
    path = tr.save(tmp_path / "nested.json")
    validate_chrome_trace(json.loads(path.read_text()))


def test_tracer_begin_end_misuse_rejected():
    tr = Tracer()
    with pytest.raises(ValueError, match="without"):
        tr.end(1.0)
    tr.begin("a", "c", 2.0)
    with pytest.raises(ValueError, match="< begin"):
        tr.end(1.0)          # end earlier than its begin: span stays open
    with pytest.raises(ValueError, match="unclosed"):
        tr.to_chrome_trace()  # "a" still open
    tr.end(3.0)
    validate_chrome_trace(tr.to_chrome_trace())


def test_validate_chrome_trace_rejects_unbalanced_spans():
    base = {"name": "a", "cat": "c", "ts": 0.0, "pid": 0, "tid": 0}
    with pytest.raises(ValueError, match="E"):
        validate_chrome_trace(
            {"traceEvents": [{**base, "ph": "E"}]})
    with pytest.raises(ValueError, match="unbalanced|unclosed"):
        validate_chrome_trace(
            {"traceEvents": [{**base, "ph": "B"}]})
