"""Runtime: optimizer, trainer, data pipeline, checkpoint, elastic, serve,
gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduced, smoke_shape
from repro.data import DataConfig, Pipeline, batch_for_step
from repro.models import build_model, make_inputs
from repro.optim import AdamW, constant, warmup_cosine
from repro.optim.compress import (apply_error_feedback, compressed_psum,
                                  dequantize, init_error_state, quantize)
from repro.serve import Engine, Request
from repro.train.checkpoint import CheckpointManager
from repro.train.elastic import ElasticEvent, StragglerMonitor, choose_mesh, \
    plan_recovery
from repro.train.trainer import init_state, make_train_step


# --- optimizer ----------------------------------------------------------------


def test_adamw_minimizes_quadratic():
    opt = AdamW(lr=constant(0.1), weight_decay=0.0, master_weights=True)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, m = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2
    assert float(m["grad_norm"]) >= 0


def test_grad_clipping():
    opt = AdamW(lr=constant(0.1), clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    _, _, m = opt.update({"w": jnp.full(3, 100.0)}, state, params)
    assert float(m["grad_norm"]) > 1.0  # reported pre-clip


def test_warmup_cosine_shape():
    lr = warmup_cosine(1e-3, 10, 100)
    assert float(lr(5)) < float(lr(10))
    assert float(lr(10)) >= float(lr(50)) >= float(lr(100))


# --- trainer ------------------------------------------------------------------


def test_train_step_reduces_loss():
    cfg = reduced(get_config("llama3-8b"))
    model = build_model(cfg, max_seq=64)
    opt = AdamW(lr=constant(3e-3), weight_decay=0.0)
    step = jax.jit(make_train_step(model, opt))
    state = init_state(model, opt, jax.random.PRNGKey(0))
    batch = make_inputs(cfg, smoke_shape("train"))
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]
    assert int(state["step"]) == 8


def test_grad_accumulation_equivalence():
    cfg = reduced(get_config("qwen2-7b"))
    model = build_model(cfg, max_seq=64)
    opt = AdamW(lr=constant(1e-3), weight_decay=0.0, clip_norm=0.0)
    batch = make_inputs(cfg, smoke_shape("train"))
    s1 = init_state(model, opt, jax.random.PRNGKey(0))
    s2 = jax.tree.map(lambda x: x, s1)
    _, m1 = jax.jit(make_train_step(model, opt, microbatches=1))(s1, batch)
    _, m2 = jax.jit(make_train_step(model, opt, microbatches=2))(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=5e-3)
    np.testing.assert_allclose(float(m1["grad_norm"]),
                               float(m2["grad_norm"]), rtol=5e-2)


# --- data ---------------------------------------------------------------------


def test_data_determinism_and_restart():
    cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=8)
    a = batch_for_step(cfg, 5)
    b = batch_for_step(cfg, 5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    p = Pipeline(cfg, start_step=0)
    first = next(p)
    p.close()
    np.testing.assert_array_equal(first["tokens"],
                                  batch_for_step(cfg, 0)["tokens"])
    p2 = Pipeline(cfg, start_step=3)
    resumed = next(p2)
    p2.close()
    np.testing.assert_array_equal(resumed["tokens"],
                                  batch_for_step(cfg, 3)["tokens"])


def test_data_host_sharding_disjoint():
    c0 = DataConfig(512, 32, 8, num_hosts=2, host_id=0)
    c1 = DataConfig(512, 32, 8, num_hosts=2, host_id=1)
    assert c0.host_batch == 4
    a, b = batch_for_step(c0, 0), batch_for_step(c1, 0)
    assert not np.array_equal(a["tokens"], b["tokens"])
    assert a["labels"].shape == (4, 32)


def test_data_labels_are_shifted_tokens():
    cfg = DataConfig(512, 16, 2)
    b = batch_for_step(cfg, 0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# --- checkpoint ---------------------------------------------------------------


def test_checkpoint_roundtrip_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "step": jnp.int32(7)}
    for s in (1, 2, 3):
        mgr.save(s, state, blocking=True)
    assert mgr.all_steps() == [2, 3]  # retention
    like = jax.tree.map(lambda x: jnp.zeros_like(x), state)
    restored = mgr.restore(like)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    assert int(restored["step"]) == 7


def test_checkpoint_reshard_on_restore(tmp_path):
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.arange(8.0)}
    mgr.save(10, state, blocking=True)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data"))}
    restored = mgr.restore({"w": jnp.zeros(8)}, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(8.0))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.zeros(4)}, blocking=True)
    with pytest.raises(ValueError):
        mgr.restore({"w": jnp.zeros(5)})


# --- elastic ------------------------------------------------------------------


@given(n=st.integers(min_value=1, max_value=4096))
@settings(max_examples=50, deadline=None)
def test_choose_mesh_divides(n):
    c = choose_mesh(n)
    assert n % (c.model_parallelism * c.pods) == 0
    assert c.model_parallelism >= 1 and c.pods >= 1


def test_straggler_monitor_flags_slow_host():
    mon = StragglerMonitor(num_hosts=4, threshold=1.5, patience=3)
    reports = []
    for step in range(8):
        for h in range(4):
            mon.record(h, 1.0 if h != 2 else 3.0)
        reports.append(mon.stragglers())
    # one report per `patience` strikes, then the counter resets — a
    # sustained straggler is reported once per episode, not every call
    assert reports == [[], [], [2], [], [], [2], [], []]


def test_plan_recovery_downscale():
    choice, action = plan_recovery(
        ElasticEvent("failure", hosts=[3], new_device_count=224))
    assert 224 % (choice.model_parallelism * choice.pods) == 0
    assert action == "evict+remesh"


# --- gradient compression -------------------------------------------------------


def test_quantize_roundtrip_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (256,))
    q, s = quantize(x)
    err = jnp.abs(dequantize(q, s) - x).max()
    assert float(err) <= float(s) * 0.5 + 1e-9


def test_error_feedback_unbiased_over_steps():
    rng = jax.random.PRNGKey(0)
    g = {"w": jax.random.normal(rng, (128,))}
    err = init_error_state(g)
    total = jnp.zeros(128)
    steps = 50
    for _ in range(steps):
        comp, err = apply_error_feedback(g, err)
        total = total + comp["w"]
    np.testing.assert_allclose(np.asarray(total / steps),
                               np.asarray(g["w"]), atol=2e-3)


def test_compressed_psum_shard_map_sums():
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    n = jax.local_device_count()
    mesh = jax.make_mesh((n,), ("dp",))
    x = jax.random.normal(jax.random.PRNGKey(1), (n, 64))

    @jax.jit
    def run(x):
        def f(xs):  # xs: (1, 64) local shard
            return compressed_psum({"g": xs}, "dp")["g"]
        return shard_map(f, mesh=mesh, in_specs=P("dp"), out_specs=P("dp")
                         )(x)

    out = run(x)                      # (n, 64): every row = compressed SUM
    want = x.sum(axis=0)              # (the seed silently divided by n)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(want),
                               atol=n * (float(jnp.abs(x).max()) / 127
                                         + 1e-6))


# --- serving --------------------------------------------------------------------


def test_engine_greedy_matches_manual_decode():
    cfg = reduced(get_config("llama3-8b"), dtype="float32")
    model = build_model(cfg, max_seq=32)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, slots=2, max_len=32)
    req = Request(uid=1, prompt=[5, 7, 11], max_new_tokens=4)
    eng.submit(req)
    eng.run()
    assert req.done and len(req.output) == 4
    # manual greedy rollout
    cache = model.init_cache(2, 32)
    seq = [5, 7, 11]
    pos = 0
    out = []
    for _ in range(4 + len(seq) - 1):
        tok = seq[pos] if pos < len(seq) else out[-1]
        lg, cache = model.decode_step(
            params, cache, {"tokens": jnp.full((2, 1), tok, jnp.int32)}, pos)
        pos += 1
        if pos >= len(seq):
            out.append(int(jnp.argmax(lg[0, -1])))
    assert req.output == out[:4]


def test_engine_continuous_batching_frees_slots():
    cfg = reduced(get_config("llama3-8b"))
    model = build_model(cfg, max_seq=16)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, slots=1, max_len=16)
    eng.submit(Request(uid=1, prompt=[1], max_new_tokens=2))
    eng.submit(Request(uid=2, prompt=[2], max_new_tokens=2))
    eng.run()
    assert all(r is None for r in eng.slot_req)
