"""Oracle-parity and property tests for the trace-driven cache simulator.

The contract (DESIGN.md §3): the Pallas kernels (interpret mode, so CI
runs them without a TPU) are bit-exact against two independent LRU
oracles — the array-state numpy oracle and the OrderedDict python one —
and the batched ladder engine is bit-exact against the retained
per-point path over the default iso-area capacity ladder.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.cachesim import (capacity_lines, largest_divisor_tile,
                                 simulate_ladder, simulate_reference,
                                 synthetic_traces, trace_dram_scale)
from repro.core.dram import dram_scale
from repro.core.sweep import capacity_ladder
from repro.kernels import ops, ref


def _zipf_trace(n, footprint, seed=0, theta=1.3):
    rng = np.random.RandomState(seed)
    return (rng.zipf(theta, n) % footprint).astype(np.int64)


# --- per-point kernel vs oracles (incl. num_sets=1 / ways=1 edges) ----------


@pytest.mark.parametrize("nsets,ways,tile,n", [
    (1, 1, 1, 400),       # single direct-mapped line
    (1, 16, 1, 400),      # one set, full associativity
    (8, 1, 8, 600),       # direct-mapped, several sets
    (32, 4, 8, 800),
    (64, 8, 64, 800),
    (81, 16, 27, 600),    # odd set count, non-power-of-two tile
])
def test_cache_sim_matches_both_oracles(nsets, ways, tile, n):
    sid = _zipf_trace(n, 10 * nsets, seed=nsets + ways) % nsets
    tags = _zipf_trace(n, 700, seed=nsets)
    h1, m1 = ops.cache_sim(jnp.asarray(sid), jnp.asarray(tags),
                           num_sets=nsets, ways=ways, sets_tile=tile)
    h2, m2 = ref.cache_sim_numpy(sid, tags, num_sets=nsets, ways=ways)
    h3, m3 = ref.cache_sim_python(sid, tags, num_sets=nsets, ways=ways)
    assert (int(h1), int(m1)) == (h2, m2) == (h3, m3)
    assert int(h1) + int(m1) == n


@pytest.mark.parametrize("ways,num_sets,tile", [
    (4, (1, 3, 7, 20, 33), 8),    # partial tiles, odd rungs
    (1, (1, 2, 5), 4),            # ways=1 ladder
    (16, (1,), 1),                # single fully-associative rung
])
def test_ladder_kernel_matches_numpy_oracle(ways, num_sets, tile):
    traces = np.stack([_zipf_trace(600, 500, seed=s) for s in (0, 1)])
    got = ops.cache_sim_ladder(jnp.asarray(traces, jnp.int32),
                               num_sets=num_sets, ways=ways, sets_tile=tile)
    want = ref.cache_sim_ladder_numpy(traces, num_sets, ways=ways)
    np.testing.assert_array_equal(np.asarray(got), want)
    assert (np.asarray(got).sum(axis=2) == traces.shape[1]).all()


# --- batched engine vs the retained per-point path --------------------------


def test_simulate_ladder_bit_exact_vs_reference_on_default_ladder():
    ladder = capacity_ladder()            # the iso-area search ladder
    traces = synthetic_traces(500, 4096, seeds=(0, 1))
    engine = simulate_ladder(traces, ladder, scale=256, ways=16)
    per_point = np.stack([
        np.stack([np.asarray(simulate_reference(
            tr, capacity_lines(c, scale=256), ways=16)) for c in ladder])
        for tr in traces])
    np.testing.assert_array_equal(engine, per_point)
    oracle = simulate_ladder(traces, ladder, scale=256, ways=16,
                             use_kernel=False)
    np.testing.assert_array_equal(engine, oracle)


def test_capacity_ladder_include_splices_sorted():
    ladder = capacity_ladder(include=(3.0,))
    assert 3.0 in ladder
    assert list(ladder) == sorted(ladder)
    assert len(set(ladder)) == len(ladder)
    # idempotent for capacities already on the ladder
    assert capacity_ladder(include=(0.5,)) == capacity_ladder()


# --- tile-selection regression ----------------------------------------------


def test_largest_divisor_tile_not_degenerate():
    # seed halving loop gave tile=1 for 81 and tile=4 for 100
    assert largest_divisor_tile(81, 64) == 27
    assert largest_divisor_tile(100, 64) == 50
    assert largest_divisor_tile(61, 64) == 61   # prime but <= cap
    assert largest_divisor_tile(4096, 64) == 64
    assert largest_divisor_tile(1, 64) == 1
    assert largest_divisor_tile(30, 7) == 6


def test_simulate_ladder_rejects_line_ids_wider_than_int32():
    # int32 wrap would alias tag -1 with the kernel's EMPTY sentinel and
    # count phantom hits on cold ways — must refuse, not silently cast
    trace = np.array([2 ** 32 - 2, 123, 456, 789], np.int64)
    with pytest.raises(ValueError, match="int32"):
        simulate_ladder(trace, (3.0,), scale=4096)
    with pytest.raises(ValueError, match="int32"):
        simulate_ladder(np.array([-1, 5]), (3.0,), scale=4096)


def test_simulate_reference_odd_set_count_matches_oracle():
    ways = 4
    num_sets = 81
    trace = _zipf_trace(700, 3000, seed=9)
    got = simulate_reference(trace, num_sets * ways, ways=ways)
    want = ref.cache_sim_numpy(trace % num_sets, trace // num_sets,
                               num_sets=num_sets, ways=ways)
    assert got == want


# --- cross-validation against the analytic miss model -----------------------


def test_trace_dram_scale_matches_analytic_model():
    scales = trace_dram_scale([6.0, 12.0], trace_len=30_000,
                              use_kernel=False)
    for c in (6.0, 12.0):
        assert abs(scales[c] - dram_scale(c)) < 0.05


def test_iso_area_trace_mode_close_to_analytic():
    from repro.core.iso import iso_area
    from repro.core.profiles import paper_profiles
    profiles = paper_profiles()[:2]
    kw = dict(trace_len=20_000, use_kernel=False)
    analytic = iso_area(profiles)
    traced = iso_area(profiles, dram_model="trace", trace_kwargs=kw)
    for ra, rt in zip(analytic, traced):
        for m in ("STT", "SOT"):
            a = ra.metrics[m]["edp_with_dram"]
            t = rt.metrics[m]["edp_with_dram"]
            assert abs(a - t) / a < 0.25
    with pytest.raises(ValueError):
        iso_area(profiles, dram_model="bogus")


# Property-based suites live in tests/test_cachesim_properties.py behind
# the repo's standard `pytest.importorskip("hypothesis")` guard, so this
# oracle-parity module always runs even without the dev extras.
