"""Core NVM stack: bitcells, cache model, tuner, workloads, profiles."""
import math

import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.core.bitcell import (SOT, SOT_DEVICE, STT, STT_DEVICE,
                                characterize, fin_sweep, switching_time_ns)
from repro.core.cache_model import (ACCESS_TYPES, BANKS, ROWS, design_grid,
                                    evaluate_config)
from repro.core.profiles import TRAFFIC, paper_profiles, profile
from repro.core.tuner import iso_area_capacity, tune, tune_all
from repro.core.workloads import HPCG, NETWORKS

from repro.core.table2 import TABLE2_ANCHORS as TABLE2


# --- bitcell ---------------------------------------------------------------


def test_table1_published_values():
    assert STT.write_latency_set_ps == 8400
    assert SOT.write_latency_set_ps == 313
    assert STT.area_rel_sram == 0.34 and SOT.area_rel_sram == 0.29
    assert SOT.sense_energy_pj < STT.sense_energy_pj


def test_characterization_reproduces_table1():
    stt = characterize(STT_DEVICE, write_fins=4, read_fins=4, sot=False)
    sot = characterize(SOT_DEVICE, write_fins=3, read_fins=1, sot=True)
    assert abs(stt.write_latency_ps / STT.write_latency_ps - 1) < 0.15
    assert abs(sot.write_latency_ps / SOT.write_latency_ps - 1) < 0.15
    assert abs(stt.sense_latency_ps / STT.sense_latency_ps - 1) < 0.15
    # write energy within 2x (driver overheads are first-order modeled)
    assert 0.5 < stt.write_energy_pj / STT.write_energy_pj < 2.0
    assert 0.5 < sot.write_energy_pj / SOT.write_energy_pj < 2.0


def test_fin_sweep_tradeoff():
    cells = fin_sweep(STT_DEVICE, sot=False)
    lats = [c.write_latency_ps for c in cells]
    areas = [c.area_rel_sram for c in cells]
    assert lats == sorted(lats, reverse=True)   # more fins -> faster
    assert areas == sorted(areas)               # ...and bigger


def test_switching_time_diverges_at_ic0():
    assert switching_time_ns(STT_DEVICE, STT_DEVICE.ic0_ua) == float("inf")
    assert switching_time_ns(STT_DEVICE, 4 * STT_DEVICE.ic0_ua) < 3.0


# --- cache model vs Table 2 -------------------------------------------------


@pytest.mark.parametrize("key", list(TABLE2))
def test_table2_anchor(key):
    mem, cap = key
    ppa = tune(mem, cap)
    for field, target in TABLE2[key].items():
        pred = getattr(ppa, field)
        err = abs(math.log(pred / target))
        assert err < 0.45, (key, field, pred, target)


def test_table2_mean_error_small():
    errs = []
    for (mem, cap), tgt in TABLE2.items():
        ppa = tune(mem, cap)
        errs += [abs(math.log(getattr(ppa, f) / t)) for f, t in tgt.items()]
    assert sum(errs) / len(errs) < 0.15


def test_iso_area_capacity_gain():
    sram = tune("SRAM", 3)
    stt = iso_area_capacity("STT", sram.area_mm2)
    sot = iso_area_capacity("SOT", sram.area_mm2)
    # paper: 2.3x / 3.3x capacity at iso-area
    assert 1.8 <= stt.capacity_mb / 3 <= 3.2
    assert 2.6 <= sot.capacity_mb / 3 <= 4.4
    assert sot.capacity_mb > stt.capacity_mb


def test_tuner_picks_edap_minimum_among_candidates():
    """Algorithm 1 selects the EDAP-best among per-objective argmin
    candidates — close to, but not necessarily equal to, the global grid
    minimum (faithful to the published pseudocode)."""
    grid = design_grid("STT", 4)
    best = tune("STT", 4)
    gmin = min(p.edap for p in grid)
    assert gmin <= best.edap <= 1.15 * gmin


@given(cap=st.sampled_from([1, 2, 3, 4, 8, 16, 32]),
       mem=st.sampled_from(["SRAM", "STT", "SOT"]))
@settings(max_examples=20, deadline=None)
def test_cache_physics_properties(cap, mem):
    ppa = tune(mem, cap)
    assert ppa.area_mm2 > 0 and ppa.leakage_mw > 0
    assert ppa.read_latency_ns > 0 and ppa.write_latency_ns > 0
    bigger = tune(mem, cap * 2) if cap < 32 else None
    if bigger:
        assert bigger.area_mm2 > ppa.area_mm2
        assert bigger.leakage_mw > ppa.leakage_mw


def test_mram_denser_and_lower_leak_than_sram():
    for cap in (2, 8, 32):
        s, t, o = tune("SRAM", cap), tune("STT", cap), tune("SOT", cap)
        assert t.area_mm2 < s.area_mm2 and o.area_mm2 < s.area_mm2
        assert t.leakage_mw < s.leakage_mw and o.leakage_mw < s.leakage_mw


def test_tune_all_shape():
    out = tune_all()
    assert set(out) == {"SRAM", "STT", "SOT"}
    assert all(len(v) == 6 for v in out.values())


# --- workloads / profiles ----------------------------------------------------


TABLE3 = {"AlexNet": (61e6, 724e6), "GoogLeNet": (7e6, 1.43e9),
          "VGG-16": (138e6, 15.5e9), "ResNet-18": (11.8e6, 2.0e9),
          "SqueezeNet": (1.2e6, 837e6)}


@pytest.mark.parametrize("name", list(TABLE3))
def test_table3_totals(name):
    net = NETWORKS[name]
    w_t, m_t = TABLE3[name]
    assert abs(net.total_weights / w_t - 1) < 0.1, net.total_weights
    assert abs(net.total_macs / m_t - 1) < 0.15, net.total_macs


def test_table3_layer_counts():
    assert NETWORKS["AlexNet"].conv_layers == 5
    assert NETWORKS["AlexNet"].fc_layers == 3
    assert NETWORKS["VGG-16"].conv_layers == 13
    assert NETWORKS["GoogLeNet"].conv_layers == 57
    assert NETWORKS["SqueezeNet"].fc_layers == 0


def test_rw_ratios_in_fig3_range():
    for p in paper_profiles():
        assert 1.4 <= p.rw_ratio <= 26.5, (p.label, p.rw_ratio)


def test_batch_trends():
    tr = [profile("AlexNet", "training", b).rw_ratio for b in (4, 16, 64)]
    inf = [profile("AlexNet", "inference", b).rw_ratio for b in (4, 16, 64)]
    assert tr[0] < tr[-1], "training should get MORE read-dominant"
    assert inf[0] > inf[-1], "inference should get LESS read-dominant"


@given(batch=st.integers(min_value=1, max_value=256))
@settings(max_examples=20, deadline=None)
def test_profile_positive(batch):
    p = profile("ResNet-18", "training", batch)
    assert p.l2_reads > 0 and p.l2_writes > 0 and p.dram >= 0


def test_hpcg_pooled_read_energy_share():
    # paper: reads are 96% of HPCG dynamic energy with SRAM energies
    profs = [profile(n, "hpc", 1) for n in HPCG]
    r = sum(p.l2_reads for p in profs)
    w = sum(p.l2_writes for p in profs)
    share = r * 0.35 / (r * 0.35 + w * 0.32)
    assert share > 0.9
