"""Hypothesis property tests for the trace-driven cache simulator:
access conservation, LRU inclusion monotonicity, capacity-ladder hit-rate
monotonicity, and the documented analytic-vs-trace tolerance."""
import numpy as np
import pytest

import jax.numpy as jnp

pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.core.cachesim import (ANALYTIC_TOL_PCT, dram_reduction_curve,
                                 simulate_ladder, synthetic_trace)
from repro.core.dram import dram_reduction_pct
from repro.kernels import ops, ref


def _zipf_trace(n, footprint, seed=0, theta=1.3):
    rng = np.random.RandomState(seed)
    return (rng.zipf(theta, n) % footprint).astype(np.int64)


@given(seed=st.integers(0, 10**6))
@settings(max_examples=10, deadline=None)
def test_kernel_conserves_accesses_and_matches_oracle(seed):
    n, nsets, ways = 300, 32, 4
    sid = _zipf_trace(n, 10 * nsets, seed=seed) % nsets
    tags = _zipf_trace(n, 400, seed=seed + 1)
    h, m = ops.cache_sim(jnp.asarray(sid), jnp.asarray(tags),
                         num_sets=nsets, ways=ways, sets_tile=8)
    assert int(h) + int(m) == n
    assert (int(h), int(m)) == ref.cache_sim_numpy(sid, tags,
                                                   num_sets=nsets, ways=ways)


@given(seed=st.integers(0, 10**6))
@settings(max_examples=10, deadline=None)
def test_more_ways_never_more_misses(seed):
    """LRU stack inclusion: same set mapping, more ways => subset misses."""
    nsets = 16
    trace = _zipf_trace(400, 2048, seed=seed)
    misses = [ref.cache_sim_numpy(trace % nsets, trace // nsets,
                                  num_sets=nsets, ways=w)[1]
              for w in (1, 2, 4, 8)]
    assert sorted(misses, reverse=True) == misses


@given(seed=st.integers(0, 10**6))
@settings(max_examples=8, deadline=None)
def test_hit_rate_monotone_up_the_capacity_ladder(seed):
    trace = synthetic_trace(1500, 8192, seed=seed)
    counts = simulate_ladder(trace, (0.5, 1, 2, 4, 8, 16), scale=64,
                             ways=8, use_kernel=False)
    hits = counts[0, :, 0]
    assert (counts.sum(axis=2) == 1500).all()
    # set-count growth is not a strict LRU inclusion, so allow a sliver
    # of conflict noise (<= 0.5% of the trace) between adjacent rungs
    slack = 1500 * 0.005
    assert all(b >= a - slack for a, b in zip(hits, hits[1:]))


@given(seed=st.integers(0, 50))
@settings(max_examples=4, deadline=None)
def test_simulated_curve_within_documented_analytic_tolerance(seed):
    sim = dram_reduction_curve((3, 7, 10), trace_len=25_000,
                               use_kernel=False, seed=seed)
    for c in (7, 10):
        assert abs(sim[c] - dram_reduction_pct(c)) < ANALYTIC_TOL_PCT
