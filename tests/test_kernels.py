"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models import attention


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,K,Sq,Skv,hd,causal,window,cap,bq,bk",
    [
        (1, 4, 2, 128, 128, 64, True, 0, 0.0, 64, 64),
        (2, 4, 4, 64, 64, 32, True, 0, 0.0, 32, 32),
        (1, 6, 2, 128, 128, 64, True, 48, 0.0, 64, 64),     # local window
        (1, 4, 1, 64, 64, 128, True, 0, 50.0, 32, 32),      # softcap + MQA
        (1, 2, 2, 64, 128, 64, False, 0, 0.0, 64, 64),      # cross attn
    ])
def test_flash_attention_sweep(dtype, B, H, K, Sq, Skv, hd, causal, window,
                               cap, bq, bk):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, H, Sq, hd), dtype)
    k = _rand(ks[1], (B, K, Skv, hd), dtype)
    v = _rand(ks[2], (B, K, Skv, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              logit_cap=cap, bq=bq, bk=bk)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   logit_cap=cap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_nondivisible_blocks_raise():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    k = _rand(ks[1], (1, 2, 128, 32), jnp.float32)
    v = _rand(ks[2], (1, 2, 128, 32), jnp.float32)
    q_bad = _rand(ks[0], (1, 2, 100, 32), jnp.float32)
    with pytest.raises(ValueError, match=r"divisible blocks.*Sq=100.*bq=64"):
        ops.flash_attention(q_bad, k, v, bq=64, bk=64)
    q = _rand(ks[0], (1, 2, 128, 32), jnp.float32)
    with pytest.raises(ValueError, match=r"Skv=128 % bk=48"):
        ops.flash_attention(q, k, v, bq=64, bk=48)


# ---------------- decode attention (serve hot path) ----------------


def _decode_setup(key, B, H, K, L, hd, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    q = _rand(ks[0], (B, H, hd), dtype)
    k = _rand(ks[1], (B, L, K, hd), dtype)
    v = _rand(ks[2], (B, L, K, hd), dtype)
    nk = _rand(ks[3], (B, K, hd), dtype)
    nv = _rand(ks[4], (B, K, hd), dtype)
    # positions span the edge cases: empty prefix, mid-block, block
    # boundary, last row of the cache
    pos = (jnp.arange(B, dtype=jnp.int32) * (L // 2 + 3)) % L
    pos = pos.at[0].set(0).at[-1].set(L - 1)
    return q, k, v, nk, nv, pos


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,K,L,hd,window,cap,bk", [
    (4, 4, 2, 128, 64, 0, 0.0, 32),       # GQA, global, multi-block
    (3, 4, 4, 64, 32, 0, 0.0, 64),        # MHA, single block
    (2, 4, 1, 128, 64, 24, 0.0, 32),      # MQA + local window
    (4, 6, 2, 96, 32, 8, 50.0, 32),       # softcap + window, odd L
    (5, 2, 2, 128, 64, 200, 30.0, 128),   # window > L == global
])
def test_decode_attention_sweep(dtype, B, H, K, L, hd, window, cap, bk):
    q, k, v, _, _, pos = _decode_setup(jax.random.PRNGKey(4), B, H, K, L,
                                       hd, dtype)
    out = ops.decode_attention(q, k, v, pos, jnp.int32(window),
                               logit_cap=cap, bk=bk)
    want = ref.decode_attention_ref(q, k, v, pos, window, logit_cap=cap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bk", [32, 128])
def test_decode_attention_fused_scatter(dtype, bk):
    """Fused variant: output sees the new row; the cache write-back is
    bitwise the jnp ``.at[rows, pos].set`` scatter (so rows past any
    live slot's pos are untouched — the DESIGN.md §13 invariant)."""
    B, H, K, L, hd = 4, 4, 2, 128, 64
    q, k, v, nk, nv, pos = _decode_setup(jax.random.PRNGKey(5), B, H, K, L,
                                         hd, dtype)
    o, ck, cv = ops.decode_attention_fused(q, k, v, nk, nv, pos,
                                           jnp.int32(0), bk=bk)
    rows = jnp.arange(B)
    k2 = k.at[rows, pos].set(nk)
    v2 = v.at[rows, pos].set(nv)
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(k2))
    np.testing.assert_array_equal(np.asarray(cv), np.asarray(v2))
    # and the attention output already reflects the scattered row
    o2 = ops.decode_attention(q, k2, v2, pos, jnp.int32(0), bk=bk)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o2, np.float32),
                               rtol=tol, atol=tol)


# Window-semantics contract, pinned across BOTH decode implementations
# (the Pallas kernel and the jnp path it replaces) with one shared
# parametrization — the serve engine may run either.


def _decode(impl, q, k, v, pos, window, cap=0.0):
    if impl == "pallas":
        w = jnp.asarray(0 if window is None else window, jnp.int32)
        return ops.decode_attention(q, k, v, pos, w, logit_cap=cap, bk=32)
    return attention.decode_attention(q[:, None], k, v, pos=pos,
                                      window=window, logit_cap=cap)[:, 0]


DECODE_IMPLS = ["pallas", "jnp"]


def _close(a, b):
    # traced-vs-static take different XLA programs; bitwise equality is
    # not guaranteed across compilations, so compare at f32-tight tol
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("impl", DECODE_IMPLS)
@pytest.mark.parametrize("wval", [0, -5])
def test_decode_traced_nonpositive_window_is_global(impl, wval):
    """A traced per-layer scalar window <= 0 is the global escape hatch:
    alt local/global stacks scan one int32 per layer through the same
    compiled decode step."""
    q, k, v, _, _, pos = _decode_setup(jax.random.PRNGKey(6), 4, 4, 2, 64, 32)
    traced = jax.jit(
        lambda w: _decode(impl, q, k, v, pos, w))(jnp.int32(wval))
    _close(traced, _decode(impl, q, k, v, pos, 0))


@pytest.mark.parametrize("impl", DECODE_IMPLS)
def test_decode_window_none_equals_zero(impl):
    q, k, v, _, _, pos = _decode_setup(jax.random.PRNGKey(7), 3, 4, 2, 64, 32)
    _close(_decode(impl, q, k, v, pos, None),
           _decode(impl, q, k, v, pos, 0))


@pytest.mark.parametrize("impl", DECODE_IMPLS)
@pytest.mark.parametrize("window", [0, 16])
def test_decode_traced_window_matches_static(impl, window):
    q, k, v, _, _, pos = _decode_setup(jax.random.PRNGKey(8), 4, 4, 2, 64, 32)
    traced = jax.jit(
        lambda w: _decode(impl, q, k, v, pos, w))(jnp.int32(window))
    _close(traced, _decode(impl, q, k, v, pos, window))


@pytest.mark.parametrize("window", [0, 12])
def test_decode_pallas_matches_jnp_path(window):
    """The two engine-selectable implementations agree on the same
    inputs (the parity the serve engine's attn_impl flag rests on)."""
    q, k, v, _, _, pos = _decode_setup(jax.random.PRNGKey(11), 4, 4, 2,
                                       64, 32)
    _close(_decode("pallas", q, k, v, pos, window, cap=30.0),
           _decode("jnp", q, k, v, pos, window, cap=30.0))


@pytest.mark.parametrize("impl", DECODE_IMPLS)
def test_decode_pos_mask_slot_isolation(impl):
    """The pos mask is the slot-isolation boundary: garbage past a row's
    own position — and ANY change to other slots' rows — must leave the
    row's output bit-identical."""
    B, H, K, L, hd = 4, 4, 2, 64, 32
    q, k, v, _, _, pos = _decode_setup(jax.random.PRNGKey(9), B, H, K, L, hd)
    base = _decode(impl, q, k, v, pos, 0)

    # 1) huge-magnitude garbage in rows past each slot's pos
    k_idx = jnp.arange(L)
    past = (k_idx[None, :] > pos[:, None])[..., None, None]
    kg = jnp.where(past, 1e9, k)
    vg = jnp.where(past, -1e9, v)
    np.testing.assert_array_equal(
        np.asarray(_decode(impl, q, kg, vg, pos, 0)), np.asarray(base))

    # 2) rewriting slot 0's entire cache row + pos leaves slots 1..B-1
    # bit-identical (per-row independence)
    k3 = k.at[0].set(_rand(jax.random.PRNGKey(10), (L, K, hd), k.dtype))
    pos3 = pos.at[0].set(L - 1)
    other = _decode(impl, q, k3, v, pos3, 0)
    np.testing.assert_array_equal(np.asarray(other[1:]),
                                  np.asarray(base[1:]))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,S,P,N,chunk", [
    (1, 2, 64, 16, 8, 16),
    (2, 4, 128, 32, 16, 32),
    (1, 1, 64, 64, 32, 64),   # single chunk
])
def test_ssd_scan_sweep(dtype, B, H, S, P, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    x = _rand(ks[0], (B, H, S, P), dtype)
    dt = jax.nn.softplus(_rand(ks[1], (B, H, S), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,))) * 0.3
    dtA = (dt * A[None, :, None]).astype(jnp.float32)
    Bm = _rand(ks[2], (B, S, N), dtype)
    Cm = _rand(ks[3], (B, S, N), dtype)
    out = ops.ssd_scan(x, dt.astype(dtype), dtA.astype(dtype), Bm, Cm,
                       chunk=chunk)
    want = ref.ssd_scan_ref(x, dt.astype(dtype), dtA.astype(dtype), Bm, Cm)
    tol = 5e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,R,block,wt", [
    (1, 128, 128, 32, 64),
    (2, 256, 256, 64, 128),
    (1, 64, 512, 64, 512),
])
def test_rglru_scan_sweep(dtype, B, S, R, block, wt):
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    a = jax.nn.sigmoid(_rand(ks[0], (B, S, R), jnp.float32)).astype(dtype)
    b = (_rand(ks[1], (B, S, R), jnp.float32) * 0.1).astype(dtype)
    out = ops.rglru_scan(a, b, block=block, width_tile=wt)
    want = ref.rglru_scan_ref(a, b)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("nsets,ways,tile,n", [
    (32, 4, 8, 1500),
    (64, 8, 64, 1500),
    (16, 16, 16, 800),
])
def test_cache_sim_sweep(nsets, ways, tile, n):
    rng = np.random.RandomState(nsets)
    sid = rng.randint(0, nsets, n)
    tags = rng.zipf(1.4, n) % 500
    h1, m1 = ops.cache_sim(jnp.asarray(sid), jnp.asarray(tags),
                           num_sets=nsets, ways=ways, sets_tile=tile)
    h2, m2 = ref.cache_sim_ref(jnp.asarray(sid), jnp.asarray(tags),
                               num_sets=nsets, ways=ways)
    h3, m3 = ref.cache_sim_python(sid, tags, num_sets=nsets, ways=ways)
    assert (int(h1), int(m1)) == (int(h2), int(m2)) == (h3, m3)
    assert int(h1) + int(m1) == n


def test_cache_sim_bigger_cache_fewer_misses():
    rng = np.random.RandomState(7)
    trace = rng.zipf(1.3, 4000) % 2048
    misses = []
    for nsets in (16, 64, 256):
        sid = jnp.asarray(trace % nsets, jnp.int32)
        tg = jnp.asarray(trace // nsets, jnp.int32)
        _, m = ref.cache_sim_ref(sid, tg, num_sets=nsets, ways=4)
        misses.append(int(m))
    assert misses[0] >= misses[1] >= misses[2]
