"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,K,Sq,Skv,hd,causal,window,cap,bq,bk",
    [
        (1, 4, 2, 128, 128, 64, True, 0, 0.0, 64, 64),
        (2, 4, 4, 64, 64, 32, True, 0, 0.0, 32, 32),
        (1, 6, 2, 128, 128, 64, True, 48, 0.0, 64, 64),     # local window
        (1, 4, 1, 64, 64, 128, True, 0, 50.0, 32, 32),      # softcap + MQA
        (1, 2, 2, 64, 128, 64, False, 0, 0.0, 64, 64),      # cross attn
    ])
def test_flash_attention_sweep(dtype, B, H, K, Sq, Skv, hd, causal, window,
                               cap, bq, bk):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, H, Sq, hd), dtype)
    k = _rand(ks[1], (B, K, Skv, hd), dtype)
    v = _rand(ks[2], (B, K, Skv, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              logit_cap=cap, bq=bq, bk=bk)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   logit_cap=cap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,S,P,N,chunk", [
    (1, 2, 64, 16, 8, 16),
    (2, 4, 128, 32, 16, 32),
    (1, 1, 64, 64, 32, 64),   # single chunk
])
def test_ssd_scan_sweep(dtype, B, H, S, P, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    x = _rand(ks[0], (B, H, S, P), dtype)
    dt = jax.nn.softplus(_rand(ks[1], (B, H, S), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,))) * 0.3
    dtA = (dt * A[None, :, None]).astype(jnp.float32)
    Bm = _rand(ks[2], (B, S, N), dtype)
    Cm = _rand(ks[3], (B, S, N), dtype)
    out = ops.ssd_scan(x, dt.astype(dtype), dtA.astype(dtype), Bm, Cm,
                       chunk=chunk)
    want = ref.ssd_scan_ref(x, dt.astype(dtype), dtA.astype(dtype), Bm, Cm)
    tol = 5e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,R,block,wt", [
    (1, 128, 128, 32, 64),
    (2, 256, 256, 64, 128),
    (1, 64, 512, 64, 512),
])
def test_rglru_scan_sweep(dtype, B, S, R, block, wt):
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    a = jax.nn.sigmoid(_rand(ks[0], (B, S, R), jnp.float32)).astype(dtype)
    b = (_rand(ks[1], (B, S, R), jnp.float32) * 0.1).astype(dtype)
    out = ops.rglru_scan(a, b, block=block, width_tile=wt)
    want = ref.rglru_scan_ref(a, b)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("nsets,ways,tile,n", [
    (32, 4, 8, 1500),
    (64, 8, 64, 1500),
    (16, 16, 16, 800),
])
def test_cache_sim_sweep(nsets, ways, tile, n):
    rng = np.random.RandomState(nsets)
    sid = rng.randint(0, nsets, n)
    tags = rng.zipf(1.4, n) % 500
    h1, m1 = ops.cache_sim(jnp.asarray(sid), jnp.asarray(tags),
                           num_sets=nsets, ways=ways, sets_tile=tile)
    h2, m2 = ref.cache_sim_ref(jnp.asarray(sid), jnp.asarray(tags),
                               num_sets=nsets, ways=ways)
    h3, m3 = ref.cache_sim_python(sid, tags, num_sets=nsets, ways=ways)
    assert (int(h1), int(m1)) == (int(h2), int(m2)) == (h3, m3)
    assert int(h1) + int(m1) == n


def test_cache_sim_bigger_cache_fewer_misses():
    rng = np.random.RandomState(7)
    trace = rng.zipf(1.3, 4000) % 2048
    misses = []
    for nsets in (16, 64, 256):
        sid = jnp.asarray(trace % nsets, jnp.int32)
        tg = jnp.asarray(trace // nsets, jnp.int32)
        _, m = ref.cache_sim_ref(sid, tg, num_sets=nsets, ways=4)
        misses.append(int(m))
    assert misses[0] >= misses[1] >= misses[2]
