"""Slot-isolation property for recurrent state banks (ISSUE 10).

The KV mirror of this property rests on position-guarded reads; the
recurrent/ring banks have no positions, so isolation rests entirely on
the engine's row-masked merges and resets (``StateBank``).  The property
driven here: under arbitrary interleavings of admit / decode / preempt /
quarantine(poison) ops,

  * an occupied slot's guarded bank rows are BITWISE unchanged by any
    other slot's prefill (admission never leaks across rows),
  * a free slot's guarded bank rows always sit at the bank's reset value
    (release/preempt/quarantine scrub exactly one row; inactive rows
    never advance inside a decode window),
  * every request still finishes with greedy outputs bitwise equal to an
    undisturbed ``EngineReference`` run of the same prompts.

A seeded deterministic sweep always runs; the hypothesis-driven version
(shrinking over op lists) runs when hypothesis is installed, matching
the repo's property-suite convention.
"""
import functools

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serve import Engine, EngineReference, Request, ShedPolicy

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:              # tier-1 containers may lack hypothesis
    HAVE_HYPOTHESIS = False

MAX_LEN = 32
SLOTS = 3
MAX_TICKS = 4000
ARCHS = ("mamba2-1.3b", "recurrentgemma-2b")


@functools.lru_cache(maxsize=None)
def _mp(arch):
    cfg = reduced(get_config(arch), dtype="float32")
    model = build_model(cfg, max_seq=MAX_LEN)
    return model, model.init(jax.random.PRNGKey(0))


def _bank_rows(eng, s):
    """Bitwise snapshot of slot ``s``'s guarded bank rows."""
    return {n: np.take(np.asarray(eng.cache[n]), s,
                       axis=eng._banks[n].batch_axis)
            for n in sorted(eng._guarded)}


def _assert_rows(a, b, msg):
    for n in a:
        np.testing.assert_array_equal(a[n], b[n], err_msg=f"{msg}: bank {n}")


def _assert_reset(eng, s, msg):
    rows = _bank_rows(eng, s)
    for n, row in rows.items():
        want = np.full_like(row, eng._bank_reset[n])
        np.testing.assert_array_equal(
            row, want, err_msg=f"{msg}: bank {n} not at reset value")


def _apply_ops(arch, ops):
    """Drive one op interleaving, asserting bank isolation at every step;
    returns after checking final greedy parity vs a clean reference."""
    model, params = _mp(arch)
    eng = Engine(model, params, slots=SLOTS, max_len=MAX_LEN,
                 ticks_per_sync=2, record_traffic=False,
                 shed_policy=ShedPolicy(max_retries=100))
    submitted = []
    uid, tok = 0, 1
    for op in ops:
        kind = op[0]
        occupied = {s: r for s, r in enumerate(eng.slot_req)
                    if r is not None}
        before = {s: _bank_rows(eng, s) for s in occupied}
        free_before = [s for s in range(SLOTS) if s not in occupied]
        if kind == "admit":
            _, plen, mnew = op
            prompt = [(tok + i) % 500 + 1 for i in range(plen)]
            tok += plen
            r = Request(uid=uid, prompt=prompt, max_new_tokens=mnew)
            uid += 1
            submitted.append((r, prompt, mnew))
            eng.submit(r)
            eng._admit()
            for s, r0 in occupied.items():
                if eng.slot_req[s] is r0:
                    _assert_rows(before[s], _bank_rows(eng, s),
                                 f"admit leaked into occupied slot {s}")
        elif kind == "preempt":
            s = op[1] % SLOTS
            if eng.slot_req[s] is None:
                continue
            eng.preempt_slot(s)
            for o, r0 in occupied.items():
                if o != s and eng.slot_req[o] is r0:
                    _assert_rows(before[o], _bank_rows(eng, o),
                                 f"preempt({s}) disturbed slot {o}")
            _assert_reset(eng, s, f"preempt({s})")
        elif kind == "poison":
            s = op[1] % SLOTS
            if eng.slot_req[s] is not None:
                eng._poison_host[s] = True
            eng.step()           # NaN logits -> quarantine + requeue
        else:                    # "step"
            eng.step()
        # inactive rows never advance and releases scrub exactly one
        # row, so a still-free slot is always bitwise at its reset value
        for s in free_before:
            if eng.slot_req[s] is None:
                _assert_reset(eng, s, f"{kind}: free slot {s} drifted")
    assert eng.run(max_ticks=MAX_TICKS) == 0
    for s in range(SLOTS):
        _assert_reset(eng, s, "post-run slot")

    ref = EngineReference(model, params, slots=SLOTS, max_len=MAX_LEN)
    clones = {}
    for r, prompt, mnew in submitted:
        rr = Request(uid=r.uid, prompt=list(prompt), max_new_tokens=mnew)
        clones[r.uid] = rr
        ref.submit(rr)
    assert ref.run(max_ticks=MAX_TICKS) == 0
    for r, _, _ in submitted:
        assert list(r.output) == list(clones[r.uid].output), \
            f"uid {r.uid} diverged from the undisturbed reference"


def _ops_from_rng(rng, n_ops):
    ops = []
    for _ in range(n_ops):
        k = int(rng.integers(6))
        if k <= 2:               # bias toward admit so slots stay busy
            ops.append(("admit", int(rng.integers(2, 8)),
                        int(rng.integers(2, 6))))
        elif k == 3:
            ops.append(("step",))
        elif k == 4:
            ops.append(("preempt", int(rng.integers(SLOTS))))
        else:
            ops.append(("poison", int(rng.integers(SLOTS))))
    return ops


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("seed", [0, 1])
def test_recurrent_bank_isolation_seeded(arch, seed):
    rng = np.random.default_rng(seed)
    _apply_ops(arch, _ops_from_rng(rng, 10))


if HAVE_HYPOTHESIS:
    _OP = st.one_of(
        st.tuples(st.just("admit"), st.integers(2, 7), st.integers(2, 5)),
        st.tuples(st.just("step")),
        st.tuples(st.just("preempt"), st.integers(0, SLOTS - 1)),
        st.tuples(st.just("poison"), st.integers(0, SLOTS - 1)),
    )

    @settings(max_examples=8, deadline=None)
    @given(ops=st.lists(_OP, min_size=3, max_size=10))
    @pytest.mark.parametrize("arch", ARCHS)
    def test_recurrent_bank_isolation_property(arch, ops):
        _apply_ops(arch, ops)
