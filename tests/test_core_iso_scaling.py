"""Iso-capacity / iso-area / scalability analyses vs the paper's claims.

Bands are deliberately generous (the traffic model is calibrated, not
measured) but tight enough that a broken pipeline fails.
"""
import pytest

from repro.core.dram import dram_reduction_pct, dram_scale
from repro.core.iso import (batch_sweep, iso_area, iso_area_capacities,
                            iso_capacity, summarize)
from repro.core.profiles import paper_profiles
from repro.core.scaling import ppa_scaling, workload_scaling


@pytest.fixture(scope="module")
def profs():
    return paper_profiles()


@pytest.fixture(scope="module")
def isocap(profs):
    return iso_capacity(profs)


@pytest.fixture(scope="module")
def isoarea(profs):
    return iso_area(profs)


def _dl(results):
    return [r for r in results if not r.workload.startswith("HPCG")]


def test_isocap_dynamic_energy_overhead(isocap):
    s = summarize(_dl(isocap), "dynamic")
    assert 1.5 <= s["STT"]["mean"] <= 2.9      # paper 2.2x
    assert 0.8 <= s["SOT"]["mean"] <= 1.7      # paper 1.3x
    assert s["STT"]["mean"] > s["SOT"]["mean"]


def test_isocap_leakage_reduction(isocap):
    s = summarize(_dl(isocap), "leakage")
    assert 4.0 <= 1 / s["STT"]["mean"] <= 9.0   # paper 6.3x
    assert 6.0 <= 1 / s["SOT"]["mean"] <= 14.0  # paper 10x
    assert s["SOT"]["mean"] < s["STT"]["mean"]


def test_isocap_total_energy_reduction(isocap):
    s = summarize(_dl(isocap), "total")
    assert 3.5 <= 1 / s["STT"]["mean"] <= 7.5   # paper 5.3x
    assert 5.5 <= 1 / s["SOT"]["mean"] <= 12.0  # paper 8.6x


def test_isocap_edp_reduction(isocap):
    s = summarize(isocap, "edp_with_dram")
    assert 2.5 <= s["STT"]["best_reduction_x"] <= 8.0   # paper up to 3.8x
    assert 3.5 <= s["SOT"]["best_reduction_x"] <= 10.0  # paper up to 4.7x
    assert (s["SOT"]["best_reduction_x"] > s["STT"]["best_reduction_x"])


def test_isoarea_capacities():
    caps = iso_area_capacities()
    assert 6.0 <= caps["STT"] <= 9.5    # paper 7MB
    assert 8.5 <= caps["SOT"] <= 13.0   # paper 10MB


def test_isoarea_edp(isoarea):
    no_dram = summarize(isoarea, "edp")
    with_dram = summarize(isoarea, "edp_with_dram")
    assert 0.9 <= no_dram["STT"]["mean_reduction_x"] <= 2.2   # paper ~1.2
    assert 1.2 <= with_dram["STT"]["mean_reduction_x"] <= 3.0  # paper 2x
    assert 1.6 <= with_dram["SOT"]["mean_reduction_x"] <= 3.6  # paper 2.3x
    # DRAM savings must IMPROVE the iso-area verdict
    assert (with_dram["STT"]["mean_reduction_x"]
            > no_dram["STT"]["mean_reduction_x"])


def test_fig6_batch_directions():
    tr = batch_sweep("AlexNet", "training")
    inf = batch_sweep("AlexNet", "inference")
    t = [1 / tr[b].metrics["STT"]["edp_with_dram"] for b in sorted(tr)]
    i = [1 / inf[b].metrics["STT"]["edp_with_dram"] for b in sorted(inf)]
    assert t[0] < t[-1], "training EDP reduction grows with batch (paper)"
    assert i[0] > i[-1], "inference EDP reduction shrinks with batch (paper)"
    assert 2.0 <= t[0] <= 5.5 and 3.5 <= t[-1] <= 6.0  # paper 2.3 -> 4.6


def test_fig7_dram_model_exact():
    assert abs(dram_reduction_pct(7) - 14.6) < 1.0
    assert abs(dram_reduction_pct(10) - 19.8) < 1.5
    assert dram_scale(3) == 1.0
    assert dram_scale(24) < dram_scale(12) < dram_scale(6) < 1.0


def test_scalability_ppa_trends():
    cfgs = ppa_scaling()
    # area gap grows with capacity
    r1 = cfgs["SRAM"][1].area_mm2 / cfgs["SOT"][1].area_mm2
    r32 = cfgs["SRAM"][32].area_mm2 / cfgs["SOT"][32].area_mm2
    assert r32 > r1
    # SRAM leakage explodes with capacity vs MRAM
    l1 = cfgs["SRAM"][1].leakage_mw / cfgs["STT"][1].leakage_mw
    l32 = cfgs["SRAM"][32].leakage_mw / cfgs["STT"][32].leakage_mw
    assert l32 > l1 > 1.0


def test_scalability_workload_trends(profs):
    res = workload_scaling(profs, capacities=(1, 4, 16, 32))
    # NVM energy advantage grows with capacity; EDP large at 32MB
    e1 = res[1]["SOT"]["total"]["mean"]
    e32 = res[32]["SOT"]["total"]["mean"]
    assert e32 < e1
    edp32 = 1 / res[32]["SOT"]["edp"]["min"]
    assert edp32 > 10.0  # paper: up to 95x (order-of-magnitude claim)
