"""Resilience layer (serve/resilience.py + engine integration): watchdog
retry/fallback semantics, queue backpressure, deadlines (queued and
mid-decode), tight-pool defer/shed behavior, preemption resume, health-
check retry budgets, and crash-rebuild resume — all with the bitwise
contract: whatever survives chaos must produce exactly the tokens an
undisturbed run would have (greedy decoding is schedule-independent and
requeued work resumes from ``prompt + output``).
"""
import jax
import pytest

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serve import (DONE, FAILED, SHED, TIMED_OUT, Engine,
                         EngineReference, Fault, FaultPlan, PagedEngine,
                         Request, ShedPolicy, WatchdogError,
                         WindowWatchdog, mixed_requests)

MAX_LEN = 48
SLOTS = 3


@pytest.fixture(scope="module")
def mp():
    cfg = reduced(get_config("llama3-8b"), dtype="float32")
    model = build_model(cfg, max_seq=MAX_LEN)
    return model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def dense(mp):
    model, params = mp
    return Engine(model, params, slots=SLOTS, max_len=MAX_LEN,
                  ticks_per_sync=2, record_traffic=False)


@pytest.fixture(scope="module")
def paged(mp):
    model, params = mp
    return PagedEngine(model, params, slots=SLOTS, max_len=MAX_LEN,
                      page_size=4, ticks_per_sync=2, record_traffic=False)


@pytest.fixture(scope="module")
def tight(mp):
    """Page pool that fits ONE in-flight request: 8 pages of 4 tokens
    on 2 slots, so a 20-token reservation starves the second slot."""
    model, params = mp
    return PagedEngine(model, params, slots=2, max_len=32, page_size=4,
                      num_pages=8, ticks_per_sync=2, record_traffic=False)


@pytest.fixture(scope="module")
def ref(mp):
    model, params = mp
    return EngineReference(model, params, slots=SLOTS, max_len=MAX_LEN)


def _fresh(eng, *, plan=None, policy=None, watchdog=None):
    eng.reset()
    eng.fault_plan = plan
    eng.shed_policy = policy if policy is not None else ShedPolicy()
    eng.watchdog = (watchdog if watchdog is not None
                    else WindowWatchdog(backoff_s=0.001))
    return eng


def _alone(ref, prompt, max_new):
    """Clean single-request reference output."""
    ref.reset()
    r = Request(uid=0, prompt=list(prompt), max_new_tokens=max_new)
    ref.submit(r)
    assert ref.run() == 0
    return list(r.output)


def _conserved(eng):
    from collections import Counter
    slot_refs = Counter()
    for s, r in enumerate(eng.slot_req):
        if r is not None:
            slot_refs.update(eng._slot_pages[s])
    eng.pool.check(eng.tree.held_refs() + slot_refs)


# --- WindowWatchdog units ---------------------------------------------------


def test_watchdog_retries_then_succeeds():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return "ok"

    retries = []
    wd = WindowWatchdog(max_attempts=3, backoff_s=0.0)
    assert wd.call(flaky, on_retry=lambda a, e: retries.append(a)) == "ok"
    assert len(calls) == 3 and len(retries) == 2


def test_watchdog_exhaustion_uses_fallback_then_raises_without():
    def broken():
        raise RuntimeError("permanent")

    wd = WindowWatchdog(max_attempts=2, backoff_s=0.0)
    assert wd.call(broken, fallback=lambda: "degraded") == "degraded"
    with pytest.raises(WatchdogError) as ei:
        wd.call(broken, label="win")
    assert "permanent" in str(ei.value.__cause__)


def test_watchdog_timeout_abandons_hung_attempt():
    import time as _t

    def hung():
        _t.sleep(5.0)
        return "never"

    wd = WindowWatchdog(max_attempts=1, backoff_s=0.0, timeout_s=0.05)
    t0 = _t.perf_counter()
    assert wd.call(hung, fallback=lambda: "degraded") == "degraded"
    assert _t.perf_counter() - t0 < 2.0


def test_fault_validation_rejects_unknown_kinds():
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault("meteor_strike")
    with pytest.raises(ValueError, match="at >= 0"):
        Fault("nan_logits", at=-1)


# --- shed policy: backpressure + deadlines ----------------------------------


def test_queue_depth_backpressure_sheds(dense):
    _fresh(dense, policy=ShedPolicy(max_queue_depth=2))
    reqs = [Request(uid=i, prompt=[3 + i, 5], max_new_tokens=3)
            for i in range(4)]
    accepted = [dense.submit(r) for r in reqs]
    assert accepted == [True, True, False, False]
    assert reqs[2].state == SHED and "queue depth" in reqs[2].reason
    dense.run()
    assert reqs[0].state == DONE and reqs[1].state == DONE
    assert dense.resilience_stats()["shed"] == 2


@pytest.mark.parametrize("engine_fixture", ["dense", "paged"])
def test_expired_queued_deadline_times_out(engine_fixture, request):
    eng = _fresh(request.getfixturevalue(engine_fixture))
    r = Request(uid=0, prompt=[5, 7], max_new_tokens=4, deadline=-1.0)
    assert eng.submit(r)          # queued fine; expiry is checked at admit
    eng.run()
    assert r.state == TIMED_OUT and r.output == []
    assert "expired in queue" in r.reason


def test_mid_decode_deadline_keeps_prefix(dense, ref):
    alone = _alone(ref, [5, 7, 11, 13], 20)
    _fresh(dense)
    r = Request(uid=0, prompt=[5, 7, 11, 13], max_new_tokens=20,
                deadline=5.0)
    dense.submit(r)
    dense.run()
    assert r.state == TIMED_OUT and "mid-decode" in r.reason
    assert 0 < len(r.output) < len(alone)
    assert r.output == alone[:len(r.output)]


# --- tight pool: defer, deadline, max_defers shed ---------------------------


def test_tight_pool_defers_then_deadline_resolves(tight, ref):
    """One request's reservation starves the pool; the second defers
    (no head-of-line deadlock) and its deadline resolves it while the
    first finishes untouched, bitwise."""
    alone = _alone(ref, list(range(2, 12)), 10)
    _fresh(tight)
    a = Request(uid=0, prompt=list(range(2, 12)), max_new_tokens=10)
    b = Request(uid=1, prompt=list(range(3, 13)), max_new_tokens=10,
                deadline=4.0)
    tight.submit(a)
    tight.submit(b)
    tight.run()
    assert a.state == DONE and list(a.output) == alone
    assert b.state in (TIMED_OUT, SHED)
    assert tight.paged_stats()["deferred"] > 0
    _conserved(tight)


def test_tight_pool_max_defers_sheds_with_shortfall_reason(tight):
    _fresh(tight, policy=ShedPolicy(max_defers=2))
    a = Request(uid=0, prompt=list(range(2, 12)), max_new_tokens=10)
    b = Request(uid=1, prompt=list(range(3, 13)), max_new_tokens=10)
    tight.submit(a)
    tight.submit(b)
    tight.run()
    assert a.state == DONE
    assert b.state == SHED
    assert "page pool exhausted" in b.reason and "pages" in b.reason
    _conserved(tight)


# --- preemption: resume is bitwise ------------------------------------------


@pytest.mark.parametrize("engine_fixture", ["dense", "paged"])
def test_preempt_mid_decode_resumes_bitwise(engine_fixture, request, ref):
    eng = _fresh(request.getfixturevalue(engine_fixture))
    alone = _alone(ref, [5, 7, 11, 13], 16)
    r = Request(uid=0, prompt=[5, 7, 11, 13], max_new_tokens=16)
    eng.submit(r)
    eng.step()
    slot = next(s for s, q in enumerate(eng.slot_req) if q is r)
    assert 0 < len(r.output) < 16     # genuinely mid-decode
    eng.preempt_slot(slot)
    assert eng.slot_req[slot] is None and r.preemptions == 1
    eng.run()
    assert r.state == DONE and list(r.output) == alone
    if hasattr(eng, "pool"):
        # the stashed prefix must be re-matched, not re-prefilled
        assert eng.paged_stats()["prefix_tokens"] > 0
        _conserved(eng)


def test_preempt_empty_slot_raises(dense):
    _fresh(dense)
    with pytest.raises(ValueError, match="not occupied"):
        dense.preempt_slot(0)


# --- health-check retry budget ----------------------------------------------


def test_quarantine_retry_budget_exhaustion_fails(dense):
    """Every window poisons slot 0: with max_retries=1 the request is
    quarantined, retried once, quarantined again, and FAILED — never an
    infinite requeue loop."""
    plan = FaultPlan([Fault("nan_logits", at=0, count=8, slot=0)], seed=0)
    _fresh(dense, plan=plan, policy=ShedPolicy(max_retries=1))
    r = Request(uid=0, prompt=[5, 7, 11], max_new_tokens=8)
    dense.submit(r)
    dense.run()
    assert r.state == FAILED
    assert "health check" in r.reason and "retry budget" in r.reason
    rs = dense.resilience_stats()
    assert rs["quarantined"] == 2 and rs["retried"] == 1
    assert rs["failed"] == 1


# --- crash + rebuild --------------------------------------------------------


def test_crash_rebuild_resumes_bitwise(dense, ref):
    """Mid-run crash: device state is lost (reset == rebuilt engine),
    every non-terminal request — including mid-slot ones with partial
    output — is resubmitted and finishes with reference parity."""
    reqs = mixed_requests(5, seed=4, vocab=512, prompt_lens=(2, 9),
                          max_new=(6, 12))
    want = {}
    for r in reqs:
        want[r.uid] = _alone(ref, r.prompt, r.max_new_tokens)
    _fresh(dense)
    for r in reqs:
        dense.submit(r)
    dense.step()
    dense.step()
    survivors = [r for r in reqs if not r.terminal]
    assert survivors                  # the crash interrupted real work
    assert any(r.output for r in survivors)      # some mid-slot
    _fresh(dense)                     # the crash: everything device-side gone
    for r in survivors:
        dense.submit(r)
    assert dense.run() == 0
    for r in reqs:
        assert r.state == DONE and list(r.output) == want[r.uid]
