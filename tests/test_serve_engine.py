"""Fused serve engine: slot isolation, greedy parity vs the reference
per-tick path, sampling/termination semantics, and serve-mode NVM records.

The load-bearing invariant: with correct slot isolation a request's greedy
output depends only on its own prompt, so outputs must be identical under
any arrival pattern, any ticks_per_sync, and under ``EngineReference``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serve import (Engine, EngineReference, Request, Tracer,
                         latency_summary, mixed_requests, poisson_requests,
                         run_arrivals, run_staggered, staggered_groups,
                         validate_chrome_trace)

MAX_LEN = 48
SLOTS = 3


@pytest.fixture(scope="module")
def mp():
    cfg = reduced(get_config("llama3-8b"), dtype="float32")
    model = build_model(cfg, max_seq=MAX_LEN)
    return model, model.init(jax.random.PRNGKey(0))


def _workload(n=7, seed=0, **kw):
    kw.setdefault("prompt_lens", (2, 9))
    kw.setdefault("max_new", (2, 8))
    return mixed_requests(n, seed=seed, vocab=512, **kw)


# --- per-row position vectors (the model-side contract) ---------------------


def test_vector_cache_pos_matches_per_row_scalar_decode(mp):
    model, params = mp
    B = 3
    key = jax.random.PRNGKey(1)
    cache = model.init_cache(B, 16)
    cache = {k: jax.random.normal(key, v.shape, v.dtype) * 0.1
             for k, v in cache.items()}
    pos = jnp.asarray([2, 5, 9], jnp.int32)
    toks = jnp.asarray([[7], [11], [13]], jnp.int32)
    lg_vec, cache_vec = model.decode_step(params, cache, {"tokens": toks},
                                          pos)
    for b in range(B):
        row_cache = {k: v[:, b:b + 1] for k, v in cache.items()}
        lg_row, row_new = model.decode_step(
            params, row_cache, {"tokens": toks[b:b + 1]}, int(pos[b]))
        np.testing.assert_allclose(np.asarray(lg_vec[b]),
                                   np.asarray(lg_row[0]),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(cache_vec["k"][:, b]),
                                   np.asarray(row_new["k"][:, 0]),
                                   atol=1e-5, rtol=1e-5)


def test_unsupported_families_fail_fast_with_structured_error(mp):
    """PagedEngine is KV-decoder-only by design and must say so at
    construction time via UnsupportedFamilyError; the slot-bank engines
    (Engine / EngineReference) accept every family."""
    from repro.serve import PagedEngine, UnsupportedFamilyError
    ssm = reduced(get_config("mamba2-1.3b"), dtype="float32")
    ssm_model = build_model(ssm, max_seq=16)
    with pytest.raises(UnsupportedFamilyError,
                       match="KV-decoder-only") as ei:
        PagedEngine(ssm_model, None, slots=1, max_len=16)
    assert ei.value.family == "ssm"
    assert "ssm" not in ei.value.supported
    assert {"dense", "moe", "vlm"} <= set(ei.value.supported)
    assert isinstance(ei.value, ValueError)   # old excepts keep working
    # the slot-bank engines accept recurrent families now ...
    eng = Engine(ssm_model, None, slots=1, max_len=16,
                 record_traffic=False)
    assert eng._guarded
    ref = EngineReference(ssm_model, None, slots=1, max_len=16)
    assert ref._guarded
    # ... but the fused-KV pallas decode kernel stays stacked-KV-only
    with pytest.raises(ValueError, match="pallas_decode"):
        Engine(ssm_model, None, slots=1, max_len=16,
               attn_impl="pallas_decode", record_traffic=False)
    enc = reduced(get_config("whisper-tiny"), dtype="float32")
    enc_model = build_model(enc, max_seq=16)
    with pytest.raises(UnsupportedFamilyError, match="encdec"):
        PagedEngine(enc_model, None, slots=1, max_len=16)


# --- slot isolation (the seed _prefill broadcast-corruption bug) ------------


@pytest.mark.parametrize("make", [
    lambda m, p: Engine(m, p, slots=SLOTS, max_len=MAX_LEN,
                        ticks_per_sync=2, record_traffic=False),
    lambda m, p: Engine(m, p, slots=SLOTS, max_len=MAX_LEN,
                        ticks_per_sync=2, record_traffic=False,
                        attn_impl="pallas_decode"),
    lambda m, p: EngineReference(m, p, slots=SLOTS, max_len=MAX_LEN),
], ids=["fused", "fused-pallas", "reference"])
def test_prefill_does_not_touch_other_slots(mp, make):
    """Prefill B while A is mid-decode: A's cache rows and final output
    must be exactly what they would have been with A running alone."""
    model, params = mp
    req_a = Request(uid=0, prompt=[5, 7, 11, 13], max_new_tokens=10)
    req_alone = Request(uid=0, prompt=list(req_a.prompt), max_new_tokens=10)
    alone = Engine(model, params, slots=SLOTS, max_len=MAX_LEN,
                   ticks_per_sync=2, record_traffic=False)
    alone.submit(req_alone)
    alone.run()
    alone_out = list(req_alone.output)

    eng = make(model, params)
    eng.submit(req_a)
    eng.step()                      # A admitted into slot 0, decoding
    assert eng.slot_req[0] is req_a and not req_a.done
    rows_before = {k: np.array(np.asarray(v)[:, 0])
                   for k, v in eng.cache.items()}
    eng.submit(Request(uid=1, prompt=[101, 102, 103], max_new_tokens=4))
    eng._admit()                    # B prefills into slot 1
    rows_after = {k: np.array(np.asarray(v)[:, 0])
                  for k, v in eng.cache.items()}
    for k in rows_before:
        np.testing.assert_array_equal(rows_before[k], rows_after[k])
    eng.run()
    assert req_a.done
    assert list(req_a.output) == alone_out


def test_seed_broadcast_bug_shape_is_gone(mp):
    """The seed wrote jnp.full((slots, 1), token) per prefill token — every
    slot's cache row changed.  Directly assert the fused prefill leaves
    non-admitted rows bit-identical even with garbage in them."""
    model, params = mp
    eng = Engine(model, params, slots=SLOTS, max_len=MAX_LEN,
                 ticks_per_sync=1, record_traffic=False)
    key = jax.random.PRNGKey(3)
    eng.cache = {k: jax.random.normal(key, v.shape, v.dtype)
                 for k, v in eng.cache.items()}
    before = {k: np.array(np.asarray(v)) for k, v in eng.cache.items()}
    eng.submit(Request(uid=0, prompt=[9, 8, 7], max_new_tokens=2))
    eng._admit()
    after = {k: np.asarray(v) for k, v in eng.cache.items()}
    for k in before:
        # slot 0 changed where the prompt landed ...
        assert not np.array_equal(before[k][:, 0, :4], after[k][:, 0, :4])
        # ... every other slot is untouched
        np.testing.assert_array_equal(before[k][:, 1:], after[k][:, 1:])


# --- greedy parity over mixed workloads -------------------------------------


def test_mixed_workload_greedy_parity_vs_reference(mp):
    """Staggered arrivals, uneven prompt/output lengths, eos exits: fused
    outputs == reference outputs, token for token, at K=1 and K=4."""
    model, params = mp
    # probe the same workload eos-free and pick a token generated at
    # index >= 1: with slot isolation the prefix is schedule-independent,
    # so the eos run must truncate that request exactly there
    ref = EngineReference(model, params, slots=SLOTS, max_len=MAX_LEN)
    probe_out = run_staggered(ref, staggered_groups(_workload(seed=5), 2))
    eos = next(t for o in probe_out.values() for t in o[1:])

    ref = EngineReference(model, params, slots=SLOTS, max_len=MAX_LEN,
                          eos_id=eos)
    out_ref = run_staggered(ref, staggered_groups(_workload(seed=5), 2))
    assert any(o[-1] == eos and len(o) > 1 for o in out_ref.values()), \
        "workload must exercise an eos exit"
    for K in (1, 4):
        eng = Engine(model, params, slots=SLOTS, max_len=MAX_LEN,
                     eos_id=eos, ticks_per_sync=K, record_traffic=False)
        out = run_staggered(eng, staggered_groups(_workload(seed=5), 2))
        assert out == out_ref, f"K={K} diverged from reference"


def test_mixed_workload_greedy_parity_pallas_engine(mp):
    """The Pallas decode kernel (fused KV scatter, interpret mode on CPU)
    behind attn_impl='pallas_decode': greedy outputs must match the
    reference per-tick engine token for token over staggered arrivals,
    uneven lengths, and eos exits, at K=1 and K=4."""
    model, params = mp
    ref = EngineReference(model, params, slots=SLOTS, max_len=MAX_LEN)
    probe_out = run_staggered(ref, staggered_groups(_workload(seed=5), 2))
    eos = next(t for o in probe_out.values() for t in o[1:])

    ref = EngineReference(model, params, slots=SLOTS, max_len=MAX_LEN,
                          eos_id=eos)
    out_ref = run_staggered(ref, staggered_groups(_workload(seed=5), 2))
    assert any(o[-1] == eos and len(o) > 1 for o in out_ref.values())
    for K in (1, 4):
        eng = Engine(model, params, slots=SLOTS, max_len=MAX_LEN,
                     eos_id=eos, ticks_per_sync=K, record_traffic=False,
                     attn_impl="pallas_decode")
        out = run_staggered(eng, staggered_groups(_workload(seed=5), 2))
        assert out == out_ref, f"pallas K={K} diverged from reference"


def test_attn_impl_validated_and_recorded(mp):
    model, params = mp
    with pytest.raises(ValueError, match="attn_impl"):
        Engine(model, params, slots=1, max_len=8, attn_impl="triton")
    eng = Engine(model, params, slots=2, max_len=16, ticks_per_sync=2,
                 record_traffic=True, attn_impl="pallas_decode")
    eng.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=3))
    eng.run()
    decode = next(r for r in eng.serve_records() if r["kind"] == "decode")
    assert decode["attn_impl"] == "pallas_decode"


def test_outputs_are_schedule_independent(mp):
    """Same requests, different arrival pattern -> identical outputs."""
    model, params = mp
    eng = Engine(model, params, slots=SLOTS, max_len=MAX_LEN,
                 ticks_per_sync=3, record_traffic=False)
    out_a = run_staggered(eng, staggered_groups(_workload(seed=6), 1))
    eng.reset()
    out_b = run_staggered(eng, [list(_workload(seed=6))])
    assert out_a == out_b


# --- sampling ---------------------------------------------------------------


def test_temperature_zero_matches_manual_argmax(mp):
    model, params = mp
    prompt = [5, 7, 11]
    m = 5
    req = Request(uid=0, prompt=list(prompt), max_new_tokens=m)
    eng = Engine(model, params, slots=SLOTS, max_len=MAX_LEN,
                 ticks_per_sync=2, record_traffic=False)
    eng.submit(req)
    eng.run()
    # manual greedy rollout through the scalar decode path
    cache = model.init_cache(1, MAX_LEN)
    seq, out = list(prompt), []
    for pos in range(len(prompt) + m - 1):
        tok = seq[pos] if pos < len(seq) else out[-1]
        lg, cache = model.decode_step(
            params, cache, {"tokens": jnp.full((1, 1), tok, jnp.int32)}, pos)
        if pos >= len(seq) - 1:
            out.append(int(jnp.argmax(lg[0, -1])))
    assert req.output == out


def test_temperature_sampling_reproducible_and_seeded(mp):
    model, params = mp
    def go(seed):
        eng = Engine(model, params, slots=SLOTS, max_len=MAX_LEN, seed=seed,
                     ticks_per_sync=2, record_traffic=False)
        reqs = _workload(5, seed=7, temperature=0.9, temperature_every=1)
        return run_staggered(eng, staggered_groups(reqs, 2))
    a, b, c = go(0), go(0), go(1)
    assert a == b, "same seed must reproduce temperature>0 outputs"
    assert a != c, "different seed should change temperature>0 outputs"
    assert all(0 <= t < 512 for o in a.values() for t in o)


# --- termination ------------------------------------------------------------


def test_max_new_tokens_exit_and_tick(mp):
    model, params = mp
    for m in (1, 4):
        req = Request(uid=0, prompt=[3, 4, 5], max_new_tokens=m)
        eng = Engine(model, params, slots=SLOTS, max_len=MAX_LEN,
                     ticks_per_sync=1, record_traffic=False)
        eng.submit(req)
        eng.run()
        assert req.done and len(req.output) == m
        # t0 emits at the admission tick (which the first decode tick
        # shares, as in the seed step()), then m-1 decode ticks
        assert req.done_tick == (m - 2 if m > 1 else 0)
        assert eng.slot_req == [None] * SLOTS


def test_max_len_exit_caps_output(mp):
    model, params = mp
    short = 8
    prompt = [2, 3, 4, 5, 6]
    req = Request(uid=0, prompt=prompt, max_new_tokens=50)
    eng = Engine(model, params, slots=2, max_len=short,
                 ticks_per_sync=2, record_traffic=False)
    eng.submit(req)
    eng.run()
    # prefill fills len(prompt) positions; decode can write the remaining
    # max_len - len(prompt) positions, each emitting one token, plus t0
    assert req.done and len(req.output) == short - len(prompt) + 1


def test_eos_and_slot_free_tick_parity_vs_reference(mp):
    model, params = mp
    ref = EngineReference(model, params, slots=SLOTS, max_len=MAX_LEN)
    probe_out = run_staggered(
        ref, staggered_groups(_workload(6, seed=9, max_new=(3, 10)), 2))
    eos = next(t for o in probe_out.values() for t in o[1:])

    def ticks_of(engine_cls, **kw):
        reqs = _workload(6, seed=9, max_new=(3, 10))
        eng = engine_cls(model, params, slots=SLOTS, max_len=MAX_LEN,
                         eos_id=eos, **kw)
        out = run_staggered(eng, staggered_groups(reqs, 2))
        return out, {r.uid: r.done_tick for r in reqs}

    out_ref, ticks_ref = ticks_of(EngineReference)
    out_fused, ticks_fused = ticks_of(
        Engine, ticks_per_sync=1, record_traffic=False)
    assert out_fused == out_ref
    assert ticks_fused == ticks_ref, \
        "K=1 slot-free ticks must match the per-tick reference"
    # eos path exercised: some request stopped early on the eos token
    assert any(o[-1] == eos and len(o) > 1 for o in out_ref.values())


def test_tick_stamp_parity_vs_reference(mp):
    """Request docstring tick semantics, enforced: admit/first-token/done
    ticks from the fused K=1 engine match the per-tick reference exactly,
    including max_new_tokens=1 requests that terminate at prefill."""
    model, params = mp

    def stamps_of(engine_cls, **kw):
        # max_new=(1, 6) forces prefill-terminated requests into the mix
        reqs = poisson_requests(8, seed=11, vocab=512, arrival_rate=0.4,
                                burst_amp=0.5, prompt_bounds=(2, 9),
                                new_bounds=(1, 6))
        eng = engine_cls(model, params, slots=SLOTS, max_len=MAX_LEN, **kw)
        out = run_arrivals(eng, reqs)
        return out, {r.uid: (r.admit_tick, r.first_token_tick, r.done_tick)
                     for r in reqs}

    out_ref, ref = stamps_of(EngineReference)
    out_fused, fused = stamps_of(Engine, ticks_per_sync=1,
                                 record_traffic=False)
    assert out_fused == out_ref
    assert fused == ref, "tick stamps diverged between engines"
    assert any(len(o) == 1 for o in out_ref.values()), \
        "workload must exercise a prefill-terminated (max_new=1) request"
    for uid, (admit, first, done) in ref.items():
        assert first == admit, "t0 is emitted at the admission tick"
        assert done == admit + len(out_ref[uid]) - 2 if len(out_ref[uid]) > 1 \
            else done == admit


def test_bursty_arrivals_outputs_schedule_independent(mp):
    """Greedy outputs under bursty Poisson admission == all-at-once batch:
    the slot-isolation invariant extended to the real traffic generator."""
    model, params = mp
    eng = Engine(model, params, slots=SLOTS, max_len=MAX_LEN,
                 ticks_per_sync=3, record_traffic=False)
    reqs = poisson_requests(9, seed=3, vocab=512, arrival_rate=0.3,
                            burst_amp=0.8, burst_period=24.0,
                            prompt_bounds=(2, 9), new_bounds=(1, 7))
    out_bursty = run_arrivals(eng, reqs)
    assert len(out_bursty) == 9
    eng.reset()
    out_batch = run_staggered(eng, [list(poisson_requests(
        9, seed=3, vocab=512, arrival_rate=0.3, burst_amp=0.8,
        burst_period=24.0, prompt_bounds=(2, 9), new_bounds=(1, 7)))])
    assert out_bursty == out_batch


def test_run_budget_is_k_granular_and_reports_unfinished(mp):
    """run(max_ticks) must never overshoot the budget mid-window (the
    window scan length is static) and must report what's left."""
    model, params = mp
    eng = Engine(model, params, slots=2, max_len=MAX_LEN,
                 ticks_per_sync=4, record_traffic=False)
    for uid in range(3):
        eng.submit(Request(uid=uid, prompt=[2 + uid, 3], max_new_tokens=20))
    left = eng.run(max_ticks=6)      # one K=4 window fits, a second doesn't
    assert eng.ticks == 4, "a partial window must not run (no overshoot)"
    assert left == 3                 # 2 mid-decode in slots + 1 queued
    assert eng.run() == 0            # unlimited-by-default finishes the rest
    assert all(r is None for r in eng.slot_req)


def test_run_arrivals_strict_raises_on_budget_exhaustion(mp):
    model, params = mp
    eng = Engine(model, params, slots=1, max_len=MAX_LEN,
                 ticks_per_sync=2, record_traffic=False)
    reqs = poisson_requests(4, seed=0, vocab=512, arrival_rate=2.0,
                            prompt_bounds=(2, 4), new_bounds=(6, 10))
    with pytest.raises(RuntimeError, match="did not finish"):
        run_arrivals(eng, reqs, max_ticks=4)
    eng.reset()
    partial = run_arrivals(eng, poisson_requests(
        4, seed=0, vocab=512, arrival_rate=2.0, prompt_bounds=(2, 4),
        new_bounds=(6, 10)), max_ticks=4, strict=False)
    assert len(partial) < 4


def test_engine_latency_stamps_and_tracer(mp):
    """After an arrival-driven run every finished request carries the full
    stamp set, latency_summary has non-empty percentiles in both domains,
    and the tracer saw prefill / decode-window / drain spans that export
    to a valid chrome trace."""
    model, params = mp
    tracer = Tracer(name="test")
    eng = Engine(model, params, slots=SLOTS, max_len=MAX_LEN,
                 ticks_per_sync=2, record_traffic=False, tracer=tracer)
    reqs = poisson_requests(6, seed=4, vocab=512, arrival_rate=0.5,
                            prompt_bounds=(2, 8), new_bounds=(2, 6))
    run_arrivals(eng, reqs)
    for r in reqs:
        assert r.done and r.submit_time is not None
        assert r.admit_time is not None and r.done_time is not None
        assert r.submit_tick <= r.admit_tick == r.first_token_tick
        assert r.submit_time <= r.admit_time <= r.done_time
    s = latency_summary(reqs)
    assert s["completed"] == s["n"] == 6
    for domain in ("wall", "ticks"):
        assert {"p50", "p95", "p99"} <= set(s[domain]["e2e_s" if domain ==
                                            "wall" else "e2e"])
    trace = tracer.to_chrome_trace()
    validate_chrome_trace(trace)
    cats = {e.get("cat") for e in trace["traceEvents"] if e["ph"] == "X"}
    assert {"prefill", "decode", "host"} <= cats


# --- request validation -----------------------------------------------------


def test_submit_never_raises_marks_failed_and_keeps_serving(mp):
    """Regression (DESIGN.md §16): a malformed request must NOT raise out
    of submit and wedge the caller's loop — it finalizes as FAILED with
    the validation message, and the engine keeps serving healthy work."""
    model, params = mp
    eng = Engine(model, params, slots=1, max_len=8, ticks_per_sync=1,
                 record_traffic=False)
    bad = [Request(uid=0, prompt=[], max_new_tokens=1),
           Request(uid=1, prompt=list(range(9)), max_new_tokens=1),
           Request(uid=2, prompt=[1], max_new_tokens=0)]
    for b in bad:
        assert eng.submit(b) is False
    assert [b.state for b in bad] == ["FAILED"] * 3
    assert "empty prompt" in bad[0].reason
    assert "exceeds" in bad[1].reason
    assert "max_new_tokens" in bad[2].reason
    assert len(eng._queue) == 0
    good = Request(uid=3, prompt=[1, 2, 3], max_new_tokens=3)
    assert eng.submit(good) is True
    assert eng.run() == 0 and good.done and good.state == "DONE"
    assert eng.resilience_stats()["failed"] == 3


# --- serve-mode NVM records -------------------------------------------------


def test_serve_records_and_nvm_verdicts(mp):
    model, params = mp
    eng = Engine(model, params, slots=2, max_len=16, ticks_per_sync=2,
                 record_traffic=True)
    eng.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=4))
    eng.submit(Request(uid=1, prompt=[4, 5], max_new_tokens=3))
    eng.run()
    recs = eng.serve_records()
    kinds = {r["kind"] for r in recs}
    assert "decode" in kinds and "prefill" in kinds
    for r in recs:
        assert r["roofline"]["bytes_per_device"] > 0
        assert r["roofline"]["memory_s"] > 0
    decode = next(r for r in recs if r["kind"] == "decode")
    assert decode["ticks"] == eng._counts["decode_ticks"] > 0
    verdicts = eng.nvm_verdicts()
    assert len(verdicts) == len(recs)
    for v in verdicts:
        assert set(v.energy_ratio) == {"STT", "SOT"}
        assert v.edp_ratio["SOT"] > 0


def test_analyze_serve_rejects_termless_records(mp):
    from repro.core.crosslayer import analyze_serve
    with pytest.raises(ValueError, match="roofline terms"):
        analyze_serve([{"arch": "x", "shape": "serve_decode", "mesh": "1dev",
                        "roofline": {"bytes_per_device": 1.0}}])
    assert analyze_serve([]) == []


def test_record_traffic_off_yields_no_records(mp):
    model, params = mp
    eng = Engine(model, params, slots=2, max_len=16, ticks_per_sync=2,
                 record_traffic=False)
    eng.submit(Request(uid=0, prompt=[1, 2], max_new_tokens=3))
    eng.run()
    assert eng.serve_records() == []
    assert eng.nvm_verdicts() == []
