"""Bench clock + BENCH-file durability + the CI regression ratchet.

Covers the three serve-clock accounting fixes (DESIGN.md §14):
  * ``timed`` must block on async JAX outputs before reading the clock
    (a sleepy dummy computation must not time as ~0);
  * ``append_bench_record`` must be atomic and must preserve a
    malformed existing file to a ``.corrupt`` sidecar;
  * ``benchmarks.gate`` must fail on a synthetic regression, ratchet
    per (leg, clock), and never gate legacy clock-less history.
"""
import json
import time

import pytest

from benchmarks.common import CLOCK, append_bench_record, timed
from benchmarks.gate import check_file, main as gate_main


# --- timed() blocks on async dispatch ---------------------------------------


class _AsyncResult:
    """Mimics a dispatched-but-unfinished jax.Array: the work only
    happens when someone blocks on it."""

    def __init__(self, seconds):
        self._seconds = seconds

    def block_until_ready(self):
        time.sleep(self._seconds)
        return self


def test_timed_blocks_on_async_outputs():
    delay = 0.05
    out, us = timed("sleepy", lambda: _AsyncResult(delay), repeats=2)
    assert isinstance(out, _AsyncResult)
    # the seed timed() returned in microseconds here; the blocking clock
    # must charge (at least) the dispatched work to every repeat
    assert us >= 0.8 * delay * 1e6, f"async work not timed: {us:.1f}us"


def test_timed_still_cheap_for_host_values():
    out, us = timed("host", lambda: (1.0, None, {"a": 2}), repeats=2)
    assert out == (1.0, None, {"a": 2})
    assert us < 1e5


# --- append_bench_record durability -----------------------------------------


def test_append_bench_record_roundtrip_and_clock(tmp_path):
    path = tmp_path / "BENCH_x.json"
    append_bench_record(path, {"speedup": 10.0})
    append_bench_record(path, {"speedup": 11.0, "clock": "naive"})
    data = json.loads(path.read_text())
    assert [r["speedup"] for r in data["history"]] == [10.0, 11.0]
    assert data["latest"]["speedup"] == 11.0
    # the clock stamp is injected, but an explicit one is preserved
    assert data["history"][0]["clock"] == CLOCK
    assert data["history"][1]["clock"] == "naive"
    # no tmp droppings left behind
    assert [p.name for p in tmp_path.iterdir()] == ["BENCH_x.json"]


def test_append_bench_record_preserves_corrupt_file(tmp_path):
    path = tmp_path / "BENCH_x.json"
    truncated = '{"latest": {"speedup": 5.0}, "history": [{"speed'
    path.write_text(truncated)
    append_bench_record(path, {"speedup": 12.0})
    # the malformed original is preserved verbatim, not clobbered
    sidecar = tmp_path / "BENCH_x.json.corrupt"
    assert sidecar.read_text() == truncated
    data = json.loads(path.read_text())
    assert data["latest"]["speedup"] == 12.0
    assert len(data["history"]) == 1


def test_append_bench_record_non_dict_payload(tmp_path):
    path = tmp_path / "BENCH_x.json"
    path.write_text("[1, 2, 3]\n")   # valid json, wrong shape
    append_bench_record(path, {"speedup": 1.0})
    assert (tmp_path / "BENCH_x.json.corrupt").exists()
    assert json.loads(path.read_text())["latest"]["speedup"] == 1.0


def test_append_bench_record_does_not_mutate_caller_record(tmp_path):
    rec = {"speedup": 2.0}
    append_bench_record(tmp_path / "BENCH_x.json", rec)
    assert rec == {"speedup": 2.0}


# --- the regression ratchet -------------------------------------------------


def _bench_file(tmp_path, records, name="BENCH_serve.json"):
    path = tmp_path / name
    path.write_text(json.dumps(
        {"latest": records[-1], "history": records}))
    return path


def test_gate_fails_on_synthetic_regression(tmp_path, capsys):
    _bench_file(tmp_path, [
        {"speedup": 20.0, "clock": CLOCK, "attn_impl": "xla"},
        {"speedup": 5.0, "clock": CLOCK, "attn_impl": "xla"},
    ])
    rc = gate_main(["--root", str(tmp_path), "--tolerance", "0.35"])
    assert rc == 1
    assert "FAIL" in capsys.readouterr().out


def test_gate_passes_within_tolerance_and_on_improvement(tmp_path):
    _bench_file(tmp_path, [
        {"speedup": 20.0, "clock": CLOCK, "attn_impl": "xla"},
        {"speedup": 14.0, "clock": CLOCK, "attn_impl": "xla"},  # -30% < 35%
        {"speedup": 25.0, "clock": CLOCK, "attn_impl": "xla"},
    ])
    assert gate_main(["--root", str(tmp_path)]) == 0


def test_gate_ratchets_against_best_not_latest(tmp_path):
    # drift scenario: a slow record lands, then "recovers" to a value
    # still far below the best — the ratchet must compare against BEST
    _bench_file(tmp_path, [
        {"speedup": 30.0, "clock": CLOCK, "attn_impl": "xla"},
        {"speedup": 6.0, "clock": CLOCK, "attn_impl": "xla"},
        {"speedup": 9.0, "clock": CLOCK, "attn_impl": "xla"},
    ])
    assert gate_main(["--root", str(tmp_path)]) == 1


def test_gate_keys_on_leg_and_clock(tmp_path):
    # pre-fix naive records are wildly higher (they never blocked); they
    # must not become the baseline for post-fix blocking records, and a
    # frozen naive group must never fail the gate
    path = _bench_file(tmp_path, [
        {"speedup": 500.0, "attn_impl": "xla"},              # naive legacy
        {"speedup": 80.0, "clock": CLOCK, "attn_impl": "pallas_decode"},
        {"speedup": 20.0, "clock": CLOCK, "attn_impl": "xla"},
        {"speedup": 19.0, "clock": CLOCK, "attn_impl": "xla"},
        {"leg": "poisson_burst", "clock": CLOCK,
         "latency": {"wall": {}}},                           # no speedup
    ])
    assert gate_main(["--root", str(tmp_path)]) == 0
    results = {(r["leg"], r["clock"]): r
               for r in check_file(path, "speedup", True, 0.35)}
    assert results[("xla", "naive")]["ok"]
    assert "not gated" in results[("xla", "naive")]["note"]
    assert results[("xla", CLOCK)]["best"] == 20.0
    assert results[("pallas_decode", CLOCK)]["note"].startswith("no baseline")
    assert ("poisson_burst", CLOCK) not in results


def test_gate_missing_requested_bench_fails(tmp_path):
    assert gate_main(["--root", str(tmp_path), "--bench", "serve"]) == 1
    # ... but an empty dir with no explicit selection passes (nothing ran)
    assert gate_main(["--root", str(tmp_path)]) == 0


def test_gate_tolerance_validation(tmp_path):
    with pytest.raises(SystemExit):
        gate_main(["--root", str(tmp_path), "--tolerance", "1.5"])
