"""Model substrate: per-arch smoke + decode/forward consistency + flash vjp."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, reduced, smoke_shape
from repro.models import build_model, make_inputs
from repro.models.attention import chunked_attention, naive_attention


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_train_step(arch):
    """Reduced config: one forward/loss on CPU; shapes + finiteness."""
    cfg = reduced(get_config(arch))
    m = build_model(cfg, max_seq=64)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_inputs(cfg, smoke_shape("train"))
    logits, _, aux = m.forward(params, batch, mode="train")
    assert logits.shape == (2, 64, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    loss = jax.jit(m.loss)(params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_prefill_decode(arch):
    cfg = reduced(get_config(arch))
    m = build_model(cfg, max_seq=64)
    params = m.init(jax.random.PRNGKey(0))
    sh = smoke_shape("prefill")
    batch = make_inputs(cfg, sh)
    logits, cache = jax.jit(m.prefill)(params, batch)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    db = {"tokens": jnp.zeros((sh.global_batch, 1), jnp.int32)}
    if cfg.family == "encdec":
        db["enc_out"] = jnp.zeros((sh.global_batch, 16, cfg.d_model),
                                  jnp.dtype(cfg.dtype))
    lg, new_cache = jax.jit(m.decode_step)(params, cache, db, sh.seq_len - 1)
    assert lg.shape == (sh.global_batch, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg, np.float32)).all()


@pytest.mark.parametrize("arch", ["llama3-8b", "gemma2-27b", "mamba2-1.3b",
                                  "recurrentgemma-2b", "qwen2-7b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode must reproduce the full forward's logits."""
    cfg = reduced(get_config(arch), dtype="float32")
    T = 12
    m = build_model(cfg, max_seq=T)
    params = m.init(jax.random.PRNGKey(1))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, T), 0,
                                cfg.vocab_size, jnp.int32)
    full, _, _ = m.forward(params, {"tokens": tokens}, mode="train",
                           attn_impl="naive")
    cache = m.init_cache(2, T)
    step = jax.jit(lambda p, c, b, pos: m.decode_step(p, c, b, pos))
    for t in range(T):
        lg, cache = step(params, cache, {"tokens": tokens[:, t:t + 1]}, t)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(full[:, t]),
            rtol=2e-3, atol=2e-3)


def test_whisper_decode_matches_forward():
    cfg = reduced(get_config("whisper-tiny"), dtype="float32")
    T = 8
    m = build_model(cfg, max_seq=T)
    params = m.init(jax.random.PRNGKey(1))
    frames = jax.random.normal(jax.random.PRNGKey(3), (2, 16, cfg.d_model))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, T), 0,
                                cfg.vocab_size, jnp.int32)
    from repro.models.transformer import encoder_forward
    enc = encoder_forward(cfg, params, frames)
    full, _, _ = m.forward(params, {"tokens": tokens, "enc_out": enc},
                           mode="train", attn_impl="naive")
    cache = m.init_cache(2, T)
    for t in range(T):
        lg, cache = m.decode_step(
            params, cache, {"tokens": tokens[:, t:t + 1], "enc_out": enc}, t)
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full[:, t]),
                                   rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("causal,window,cap,gqa", [
    (True, 0, 0.0, 2), (True, 32, 50.0, 1), (False, 0, 0.0, 4),
])
def test_flash_vjp_matches_naive(causal, window, cap, gqa):
    B, Sq, K, hd = 2, 64, 2, 16
    H = K * gqa
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd))
    k = jax.random.normal(ks[1], (B, Sq, K, hd))
    v = jax.random.normal(ks[2], (B, Sq, K, hd))

    def f1(q, k, v):
        return (chunked_attention(q, k, v, causal=causal, window=window,
                                  logit_cap=cap, kv_block=16) ** 2).sum()

    def f2(q, k, v):
        return (naive_attention(q, k, v, causal=causal, window=window,
                                logit_cap=cap) ** 2).sum()

    np.testing.assert_allclose(f1(q, k, v), f2(q, k, v), rtol=2e-5)
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=3e-4, atol=3e-5)


def test_moe_routes_and_balances():
    cfg = reduced(get_config("granite-moe-3b-a800m"))
    m = build_model(cfg, max_seq=64)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_inputs(cfg, smoke_shape("train"))
    _, _, aux = m.forward(params, batch, mode="train")
    assert float(aux) > 0  # aux loss present
    # capacity drop must not NaN
    loss = m.loss(params, batch)
    assert np.isfinite(float(loss))


def test_param_counts_plausible():
    # full configs should land near their nameplate sizes (moonshot's
    # ASSIGNED dims — 48L x 64e x d_ff 1408 — give ~28B total; its "a3b"
    # active count is what matches the name, checked below)
    expected = {"llama3-8b": 8.0e9, "qwen2-7b": 7.6e9,
                "phi3-mini-3.8b": 3.8e9, "gemma2-27b": 27.2e9,
                "mamba2-1.3b": 1.3e9, "recurrentgemma-2b": 2.7e9,
                "moonshot-v1-16b-a3b": 27e9}
    for arch, n in expected.items():
        got = get_config(arch).param_count()
        assert 0.6 < got / n < 1.45, (arch, got, n)
    active = get_config("moonshot-v1-16b-a3b").active_param_count()
    assert 2.5e9 < active < 5.5e9  # "a3b"
    active_g = get_config("granite-moe-3b-a800m").active_param_count()
    assert active_g < get_config("granite-moe-3b-a800m").param_count()
