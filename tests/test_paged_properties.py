"""Hypothesis property tests for the paged KV cache's host bookkeeping
(serve/paged.py): exact refcount conservation between the pool, the
radix tree, and slot holders under random admit/release/evict
interleavings; no page double-allocation; eviction completeness.
"""
from collections import Counter

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.serve.paged import (PagePool, PagePoolExhausted, RadixTree,
                               pages_for)


@given(data=st.data())
@settings(max_examples=25, deadline=None)
def test_refcounts_exactly_conserved_under_random_ops(data):
    """Random interleaving of admit-style (match + share + alloc +
    insert), slot release, and LRU eviction: at every step the pool's
    refcounts equal tree-held + slot-held references exactly, no page is
    double-allocated, and the free list stays consistent."""
    ps = data.draw(st.sampled_from([2, 4]))
    pool = PagePool(48, ps)
    tree = RadixTree(pool)
    slot_refs: Counter = Counter()
    held_groups = []
    for _ in range(data.draw(st.integers(5, 30))):
        op = data.draw(st.sampled_from(["admit", "admit", "release",
                                        "evict"]))
        if op == "admit":
            prompt = data.draw(st.lists(st.integers(0, 3), min_size=1,
                                        max_size=14))
            matched, shared = tree.match(prompt[:len(prompt) - 1])
            n_full = matched // ps
            for p in shared[:n_full]:
                pool.share(p)
            live_before = {p for g in held_groups for p in g}
            live_before |= set(tree.held_refs())
            try:
                new = pool.alloc(pages_for(len(prompt), ps) - n_full)
            except PagePoolExhausted:
                new = None
            if new is None:
                for p in shared[:n_full]:
                    pool.release(p)
            else:
                # no double-allocation: fresh pages were not live
                assert not (set(new) & live_before)
                pages = shared[:n_full] + new
                tree.insert(prompt, pages)
                held_groups.append(pages)
                slot_refs.update(pages)
        elif op == "release" and held_groups:
            g = held_groups.pop(
                data.draw(st.integers(0, len(held_groups) - 1)))
            for p in g:
                pool.release(p)
            slot_refs.subtract(g)
        elif op == "evict":
            tree.evict(data.draw(st.integers(0, 48)))
        pool.check(tree.held_refs() + slot_refs)
    tree.clear()
    for g in held_groups:
        for p in g:
            pool.release(p)
    pool.check(Counter())
    assert pool.free_pages == pool.num_pages


@given(seed=st.integers(0, 10**6))
@settings(max_examples=15, deadline=None)
def test_eviction_frees_everything_when_unpinned(seed):
    rng = np.random.default_rng(seed)
    pool = PagePool(32, 4)
    tree = RadixTree(pool)
    for _ in range(6):
        n = int(rng.integers(1, 12))
        prompt = [int(t) for t in rng.integers(0, 4, size=n)]
        try:
            pages = pool.alloc(pages_for(len(prompt), 4))
        except PagePoolExhausted:
            break
        tree.insert(prompt, pages)
        for p in pages:               # hand the "slot" refs straight back
            pool.release(p)
    tree.evict(pool.num_pages)        # nothing pinned -> all pages free
    assert pool.free_pages == pool.num_pages
    pool.check(Counter())


@given(seed=st.integers(0, 10**6))
@settings(max_examples=15, deadline=None)
def test_match_returns_true_prefix_with_exact_page_cover(seed):
    rng = np.random.default_rng(seed)
    ps = int(rng.choice([2, 4]))
    pool = PagePool(64, ps)
    tree = RadixTree(pool)
    stored = []
    for _ in range(5):
        n = int(rng.integers(1, 14))
        prompt = tuple(int(t) for t in rng.integers(0, 3, size=n))
        try:
            pages = pool.alloc(pages_for(len(prompt), ps))
        except PagePoolExhausted:
            break
        tree.insert(prompt, pages)
        stored.append(prompt)
        for p in pages:
            pool.release(p)
    probe = tuple(int(t) for t in rng.integers(0, 3, size=10))
    matched, pages = tree.match(probe)
    best = max((len(_common(s, probe)) for s in stored), default=0)
    assert matched == best
    assert len(pages) == pages_for(matched, ps)
    pool.check(tree.held_refs())


def _common(a, b):
    out = []
    for x, y in zip(a, b):
        if x != y:
            break
        out.append(x)
    return out
