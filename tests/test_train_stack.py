"""Training-stack hardening suite (no hypothesis dependency, so it runs
wherever tier-1 runs): checkpoint writer/retention/error-propagation,
StragglerMonitor strike semantics, compressed-psum sum/mean contract, and
the error-feedback optimizer wrapper."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DataConfig, Pipeline, batch_for_step
from repro.optim import AdamW, constant
from repro.optim.compress import compressed_psum, wrap_optimizer
from repro.train.checkpoint import CheckpointManager
from repro.train.elastic import StragglerMonitor


# --- checkpoint round-trips ---------------------------------------------------


def test_checkpoint_resave_same_step_updates(tmp_path):
    # the seed writer crashed invisibly here: os.replace(tmp, final) on an
    # existing non-empty destination dir raises inside the daemon thread
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, {"w": jnp.zeros(3)}, blocking=True)
    mgr.save(5, {"w": jnp.ones(3)}, blocking=True)
    assert mgr.all_steps() == [5]
    restored = mgr.restore({"w": jnp.zeros(3)})
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.ones(3))


def test_checkpoint_writer_error_propagates(tmp_path, monkeypatch):
    mgr = CheckpointManager(str(tmp_path))

    def boom(*a, **kw):
        raise OSError("disk full")

    monkeypatch.setattr("repro.train.checkpoint.np.save", boom)
    mgr.save(1, {"w": jnp.zeros(2)})
    with pytest.raises(OSError, match="disk full"):
        mgr.wait()
    # the error is consumed: the manager keeps working afterwards
    monkeypatch.undo()
    mgr.save(2, {"w": jnp.zeros(2)}, blocking=True)
    assert mgr.all_steps() == [2]


def test_checkpoint_writer_error_surfaces_on_next_save(tmp_path,
                                                       monkeypatch):
    mgr = CheckpointManager(str(tmp_path))

    def boom(*a, **kw):
        raise RuntimeError("writer died")

    monkeypatch.setattr("repro.train.checkpoint.np.save", boom)
    mgr.save(1, {"w": jnp.zeros(2)})
    mgr._thread.join()
    monkeypatch.undo()
    with pytest.raises(RuntimeError, match="writer died"):
        mgr.save(2, {"w": jnp.zeros(2)})


def test_checkpoint_crash_mid_swap_recovers(tmp_path):
    # simulate a kill between the two swap renames: the step exists only
    # as step_N.old — a fresh manager's recovery sweep must republish it
    import os
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, {"w": jnp.full(3, 7.0)}, blocking=True)
    os.rename(tmp_path / "step_7", tmp_path / "step_7.old")
    assert CheckpointManager(str(tmp_path)).all_steps() == [7]
    restored = CheckpointManager(str(tmp_path)).restore({"w": jnp.zeros(3)})
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.full(3, 7.0))
    # completed swap: leftover .old beside a published final is dropped
    mgr.save(7, {"w": jnp.zeros(3)}, blocking=True)
    os.makedirs(tmp_path / "step_7.old", exist_ok=True)
    CheckpointManager(str(tmp_path))
    assert not (tmp_path / "step_7.old").exists()


def test_checkpoint_keep_zero_rejected(tmp_path):
    # keep=0 used to make _gc slice steps[:-0] == [], silently disabling
    # retention instead of meaning anything
    with pytest.raises(ValueError):
        CheckpointManager(str(tmp_path), keep=0)
    mgr = CheckpointManager(str(tmp_path), keep=1)
    for s in (1, 2, 3):
        mgr.save(s, {"w": jnp.zeros(2)}, blocking=True)
    assert mgr.all_steps() == [3]


def test_checkpoint_bf16_roundtrip_and_reshard(tmp_path):
    # np.load hands bf16 back as raw '|V2' void records; restore must
    # reinterpret via the manifest dtype (bf16 params checkpoint now)
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path))
    w = jnp.arange(8.0, dtype=jnp.bfloat16)
    mgr.save(1, {"w": w}, blocking=True)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data"))}
    restored = mgr.restore({"w": jnp.zeros(8, jnp.bfloat16)}, shardings=sh)
    assert restored["w"].dtype == jnp.bfloat16
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"], np.float32),
                                  np.asarray(w, np.float32))


# --- pipeline resume determinism ---------------------------------------------


def test_pipeline_resume_matches_random_access():
    cfg = DataConfig(256, 16, 4, seed=11)
    p = Pipeline(cfg, start_step=7)
    got = [next(p) for _ in range(3)]
    p.close()
    for i, b in enumerate(got):
        want = batch_for_step(cfg, 7 + i)
        np.testing.assert_array_equal(b["tokens"], want["tokens"])
        np.testing.assert_array_equal(b["labels"], want["labels"])
    assert p.state["step"] == 10


# --- straggler strike semantics ----------------------------------------------


def test_straggler_reported_once_per_episode():
    mon = StragglerMonitor(num_hosts=4, threshold=1.5, patience=3)
    reports = []
    for step in range(8):
        for h in range(4):
            mon.record(h, 1.0 if h != 2 else 3.0)
        reports.append(mon.stragglers())
    # one report per `patience` strikes, then the counter resets — a
    # sustained straggler is reported once per episode, not every call
    assert reports == [[], [], [2], [], [], [2], [], []]


def test_straggler_double_call_does_not_rereport():
    # the seed launcher called stragglers() twice per step (once in the
    # `if`, once in the print), doubling strike accrual; with the reset
    # semantics the second call must not re-report the same episode
    mon = StragglerMonitor(num_hosts=4, threshold=1.5, patience=3)
    for step in range(2):
        for h in range(4):
            mon.record(h, 1.0 if h != 1 else 4.0)
        assert mon.stragglers() == []
    for h in range(4):
        mon.record(h, 1.0 if h != 1 else 4.0)
    assert mon.stragglers() == [1]
    assert mon.stragglers() == []


def test_straggler_recovery_resets_strikes():
    mon = StragglerMonitor(num_hosts=4, threshold=1.5, patience=3)
    for h in range(4):     # one slow step: one strike for host 2
        mon.record(h, 1.0 if h != 2 else 3.0)
    assert mon.stragglers() == []
    for _ in range(4):     # recover until the EMA decays below threshold
        for h in range(4):
            mon.record(h, 1.0)
    assert mon.stragglers() == []    # healthy call zeroes the strike
    reports = []
    for _ in range(3):     # relapse: must take FULL patience again
        for h in range(4):
            mon.record(h, 1.0 if h != 2 else 3.0)
        reports.append(mon.stragglers())
    assert reports == [[], [], [2]]


# --- compressed psum + error-feedback wrapper --------------------------------


def test_compressed_psum_sum_vs_mean_contract():
    # vmap with a named axis runs the same psum/pmax collective program
    # shard_map runs per-device; 4 shard groups on one host
    shards = 4
    x = jax.random.normal(jax.random.PRNGKey(2), (shards, 32))

    s = jax.vmap(lambda xs: compressed_psum({"g": xs}, "dp")["g"],
                 axis_name="dp")(x)
    m = jax.vmap(lambda xs: compressed_psum({"g": xs}, "dp", mean=True)["g"],
                 axis_name="dp")(x)
    tol = float(jnp.abs(x).max()) / 127 * shards + 1e-6
    np.testing.assert_allclose(np.asarray(s[0]), np.asarray(x.sum(0)),
                               atol=tol)
    np.testing.assert_allclose(np.asarray(m[0]), np.asarray(x.mean(0)),
                               atol=tol / shards + 1e-6)
    # exact relation between the two contracts, quantization and all
    np.testing.assert_allclose(np.asarray(s[0] / shards), np.asarray(m[0]),
                               rtol=1e-6)


def test_wrap_optimizer_state_and_convergence():
    opt = wrap_optimizer(AdamW(lr=constant(0.1), weight_decay=0.0))
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    assert set(state) == {"inner", "err"}           # EF rides in opt state
    assert set(opt.state_axes({"w": ("x",)})) == {"inner", "err"}
    a = opt.abstract_state(params)
    assert a["err"]["w"].shape == (2,)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, m = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2
    assert float(m["grad_norm"]) >= 0               # inner metrics surface


class _Probe:
    """Inner-optimizer probe: records the (compressed) gradients it is
    fed, so tests can check what the EF wrapper actually delivers."""

    def init(self, params):
        return {"seen": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "n": jnp.zeros(())}

    def update(self, grads, state, params):
        new = {"seen": jax.tree.map(jnp.add, state["seen"], grads),
               "n": state["n"] + 1}
        return params, new, {}


def test_wrap_optimizer_error_feedback_bias_vanishes():
    # the mean of the quantized gradients the inner optimizer saw must
    # converge to the true gradient (the property 1-bit Adam rests on)
    opt = wrap_optimizer(_Probe())
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (128,))}
    params = {"w": jnp.zeros(128)}
    state = opt.init(params)
    steps = 50
    for _ in range(steps):
        params, state, _ = opt.update(g, state, params)
    mean_seen = np.asarray(state["inner"]["seen"]["w"]) / steps
    np.testing.assert_allclose(mean_seen, np.asarray(g["w"]), atol=2e-3)


def test_wrap_optimizer_sharded_ef_bias_vanishes():
    # distributed EF schedule: per-shard residuals are banked BEFORE the
    # compressed combine, so the combined-gradient bias vanishes too —
    # the inner optimizer's running mean must converge to the true
    # shard-mean gradient
    shards = 4
    opt = wrap_optimizer(_Probe(), shards=shards)
    g = {"w": jax.random.normal(jax.random.PRNGKey(3), (shards, 64))}
    params = {"w": jnp.zeros(64)}
    state = opt.init(params)
    assert state["err"]["w"].shape == (shards, 64)  # per-worker buffers
    steps = 50
    for _ in range(steps):
        params, state, _ = opt.update(g, state, params)
    mean_seen = np.asarray(state["inner"]["seen"]["w"]) / steps
    np.testing.assert_allclose(mean_seen, np.asarray(g["w"].mean(0)),
                               atol=2e-3)


def test_wrap_optimizer_error_feedback_carries():
    # int8-quantizing a two-scale gradient loses the small component; the
    # error buffer must bank it so it is applied on a later step
    opt = wrap_optimizer(AdamW(lr=constant(0.0), weight_decay=0.0,
                               clip_norm=0.0))
    params = {"w": jnp.zeros(2)}
    state = opt.init(params)
    g = {"w": jnp.array([1000.0, 1e-3])}  # 1e-3 << scale: quantizes to 0
    _, state, _ = opt.update(g, state, params)
    err = np.asarray(state["err"]["w"])
    assert err[1] != 0.0                  # the lost mass is banked
    _, state2, _ = opt.update({"w": jnp.zeros(2)}, state, params)
    assert abs(np.asarray(state2["err"]["w"])[1]) <= abs(err[1]) + 1e-9
