"""Hypothesis property tests on system invariants (primitives, energy
model, HLO parser robustness, MoE dispatch conservation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.core.cache_model import evaluate_config
from repro.core.energy import evaluate, relative
from repro.core.profiles import MemoryProfile
from repro.core.tuner import tune
from repro.launch.hlo_analysis import analyze_hlo, parse_hlo
from repro.models.common import rms_norm, rope, softcap


# --- primitives -------------------------------------------------------------


@given(st.integers(0, 5), st.integers(1, 4))
@settings(max_examples=15, deadline=None)
def test_rope_preserves_norm(seed, heads):
    """Rotations preserve per-head vector norms."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (2, 8, heads, 16))
    pos = jnp.arange(8)
    y = rope(x, pos[None, :], 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)


def test_rope_position_zero_is_identity():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 2, 8))
    y = rope(x, jnp.zeros((1, 1)), 10000.0)
    np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-6)


def test_rope_is_relative():
    """<rope(q,p1), rope(k,p2)> depends only on p1 - p2."""
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 16))
    def dot(p1, p2):
        qr = rope(q, jnp.array([[p1]]), 100.0)
        kr = rope(k, jnp.array([[p2]]), 100.0)
        return float(jnp.sum(qr * kr))
    np.testing.assert_allclose(dot(3, 1), dot(7, 5), rtol=1e-5)


@given(st.integers(0, 5))
@settings(max_examples=10, deadline=None)
def test_rmsnorm_unit_rms(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 32)) * 7.0
    y = rms_norm(x, jnp.zeros(32))
    rms = np.sqrt(np.mean(np.asarray(y, np.float32) ** 2, -1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


@given(st.floats(1.0, 100.0), st.floats(-1e4, 1e4))
@settings(max_examples=30, deadline=None)
def test_softcap_bounded_and_monotone(cap, v):
    eps = 1e-5 * cap
    y = float(softcap(jnp.float32(v), cap))
    assert abs(y) <= cap + eps
    y2 = float(softcap(jnp.float32(v + 1.0), cap))
    assert y2 >= y - eps  # non-decreasing up to f32 rounding


# --- energy model invariants --------------------------------------------------


@given(reads=st.floats(1e3, 1e9), writes=st.floats(1e3, 1e9),
       dram=st.floats(0, 1e7))
@settings(max_examples=30, deadline=None)
def test_energy_positive_and_monotone_in_traffic(reads, writes, dram):
    ppa = tune("STT", 3)
    p1 = MemoryProfile("w", "hpc", 1, reads, writes, dram)
    p2 = MemoryProfile("w", "hpc", 1, reads * 2, writes, dram)
    e1, e2 = evaluate(p1, ppa), evaluate(p2, ppa)
    assert e1.total_nj > 0 and e1.delay_ns > 0
    assert e2.dynamic_nj > e1.dynamic_nj
    assert e2.edp_with_dram > e1.edp_with_dram


@given(rw=st.floats(1.0, 30.0))
@settings(max_examples=20, deadline=None)
def test_stt_vs_sot_ordering(rw):
    """SOT's fast writes mean SOT EDP <= STT EDP for any R/W mix."""
    stt, sot = tune("STT", 3), tune("SOT", 3)
    p = MemoryProfile("w", "hpc", 1, rw * 1e6, 1e6, 1e4)
    sram = evaluate(p, tune("SRAM", 3))
    r_stt = relative(sram, evaluate(p, stt))
    r_sot = relative(sram, evaluate(p, sot))
    assert r_sot["edp_with_dram"] <= r_stt["edp_with_dram"] * 1.05


def test_evaluate_config_matches_grid_point():
    p = evaluate_config("SOT", 4, banks=8, rows=1024,
                        access_type="Normal")
    assert p.banks == 8 and p.rows == 1024 and p.capacity_mb == 4


# --- HLO parser robustness ------------------------------------------------------


def test_parse_hlo_ignores_garbage():
    comps, entry = parse_hlo("not hlo at all\n\nrandom text {}")
    assert entry is None
    stats = analyze_hlo("garbage")
    assert stats.flops == 0 and stats.bytes == 0


def test_parse_hlo_on_simple_jit():
    compiled = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((64, 32), jnp.float32),
        jax.ShapeDtypeStruct((32, 16), jnp.float32)).compile()
    stats = analyze_hlo(compiled.as_text())
    assert stats.flops == pytest.approx(2 * 64 * 32 * 16)
    # traffic >= operands + output
    assert stats.bytes >= (64 * 32 + 32 * 16 + 64 * 16) * 4


# --- MoE dispatch conservation ---------------------------------------------------


@given(seed=st.integers(0, 20))
@settings(max_examples=10, deadline=None)
def test_moe_identity_experts_preserve_scale(seed):
    """With all-equal expert outputs, combine must reproduce gate-weighted
    identity (no token duplication/loss through dispatch+combine)."""
    from repro.configs import get_config, reduced
    from repro.models.common import materialize
    from repro.models.moe import moe_block, moe_param_defs

    cfg = reduced(get_config("granite-moe-3b-a800m"),
                  moe_capacity_factor=8.0)  # no drops
    defs = moe_param_defs(cfg)
    params = materialize(defs, jax.random.PRNGKey(seed), "float32")
    x = jax.random.normal(jax.random.PRNGKey(seed + 100), (2, 16,
                                                           cfg.d_model))
    # make every expert the same linear map -> output independent of routing
    for k in ("w_up", "w_gate", "w_down"):
        params[k] = jnp.broadcast_to(params[k][:1], params[k].shape)
    y1, _ = moe_block(cfg, params, x)
    params2 = dict(params, router=params["router"] * -1.0)  # reroute
    y2, _ = moe_block(cfg, params2, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-2, atol=2e-3)
