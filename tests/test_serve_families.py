"""Family-generic slot-state banks (ISSUE 10): every registry config
serves batched with greedy parity vs ``EngineReference``.

The three slot-bank families (mamba2 ssm, recurrentgemma hybrid, whisper
encdec) get the full staggered / uneven-length / eos matrix at K=1 and
K=4 — the acceptance oracle for the StateBank refactor.  The stacked-KV
archs get a lighter parity smoke (their deep matrix already lives in
test_serve_engine.py on llama3-8b).  Bank metadata itself is pinned for
ALL archs: ``state_banks()`` must key exactly like the decode cache.
"""
import functools

import jax
import numpy as np
import pytest

from repro.configs import get_config, list_archs, reduced
from repro.models import build_model
from repro.models.api import StateBank
from repro.serve import (Engine, EngineReference, Request, mixed_requests,
                         run_staggered, staggered_groups)

MAX_LEN = 40
SLOTS = 3
BANK_ARCHS = ("mamba2-1.3b", "recurrentgemma-2b", "whisper-tiny")
KV_ARCHS = tuple(a for a in list_archs() if a not in BANK_ARCHS)


@functools.lru_cache(maxsize=None)
def _mp(arch):
    cfg = reduced(get_config(arch), dtype="float32")
    model = build_model(cfg, max_seq=MAX_LEN)
    return model, model.init(jax.random.PRNGKey(0))


def _workload(seed=5, n=6):
    return mixed_requests(n, seed=seed, vocab=512, prompt_lens=(2, 9),
                          max_new=(2, 8))


# --- bank metadata (all archs) ----------------------------------------------


@pytest.mark.parametrize("arch", list_archs())
def test_state_banks_key_exactly_like_the_cache(arch):
    model, _ = _mp(arch)
    banks = model.state_banks()
    defs = model.cache_defs(SLOTS, 16)
    assert set(banks) == set(defs), \
        "state_banks() must name every cache entry and nothing else"
    for n, b in banks.items():
        assert isinstance(b, StateBank) and b.name == n
        shape = defs[n].shape
        assert b.batch_axis < len(shape)
        assert shape[b.batch_axis] == SLOTS, \
            f"bank {n}: batch_axis {b.batch_axis} is not the slot axis"
        if b.kind in ("kv", "ring"):
            assert b.seq_axis is not None and shape[b.seq_axis] <= 16


def test_statebank_contract_validation():
    with pytest.raises(ValueError, match="kind"):
        StateBank("x", "paged", batch_axis=0)
    with pytest.raises(ValueError, match="batch_axis"):
        StateBank("x", "kv", batch_axis=2, seq_axis=1)


# --- greedy parity: the slot-bank families, full matrix ---------------------


@pytest.mark.parametrize("arch", BANK_ARCHS)
def test_bank_family_parity_staggered_uneven_eos(arch):
    """Staggered arrivals, uneven prompt/output lengths, eos exits: fused
    outputs == reference outputs, token for token, at K=1 and K=4."""
    model, params = _mp(arch)
    ref = EngineReference(model, params, slots=SLOTS, max_len=MAX_LEN)
    probe = run_staggered(ref, staggered_groups(_workload(), 2))
    eos = next(t for o in probe.values() for t in o[1:])

    ref = EngineReference(model, params, slots=SLOTS, max_len=MAX_LEN,
                          eos_id=eos)
    out_ref = run_staggered(ref, staggered_groups(_workload(), 2))
    assert any(o[-1] == eos and len(o) > 1 for o in out_ref.values()), \
        "workload must exercise an eos exit"
    for K in (1, 4):
        eng = Engine(model, params, slots=SLOTS, max_len=MAX_LEN,
                     eos_id=eos, ticks_per_sync=K, record_traffic=False)
        out = run_staggered(eng, staggered_groups(_workload(), 2))
        assert out == out_ref, f"{arch} K={K} diverged from reference"


@pytest.mark.parametrize("arch", BANK_ARCHS)
def test_bank_family_outputs_schedule_independent(arch):
    model, params = _mp(arch)
    eng = Engine(model, params, slots=SLOTS, max_len=MAX_LEN,
                 ticks_per_sync=3, record_traffic=False)
    out_a = run_staggered(eng, staggered_groups(_workload(seed=6), 1))
    eng.reset()
    out_b = run_staggered(eng, [list(_workload(seed=6))])
    assert out_a == out_b


# --- greedy parity: stacked-KV archs, light smoke ---------------------------


@pytest.mark.parametrize("arch", KV_ARCHS)
def test_kv_arch_parity_smoke(arch):
    model, params = _mp(arch)
    ref = EngineReference(model, params, slots=SLOTS, max_len=MAX_LEN)
    out_ref = run_staggered(ref, staggered_groups(_workload(n=5), 2))
    eng = Engine(model, params, slots=SLOTS, max_len=MAX_LEN,
                 ticks_per_sync=4, record_traffic=False)
    out = run_staggered(eng, staggered_groups(_workload(n=5), 2))
    assert out == out_ref, f"{arch} diverged from reference"


# --- bank semantics ---------------------------------------------------------


def test_recurrent_slot_free_resets_banks():
    """After every request drains, all guarded bank rows must sit at
    their reset value — stale recurrent state on slot reuse was the
    failure mode the reset protocol exists for."""
    model, params = _mp("recurrentgemma-2b")
    eng = Engine(model, params, slots=SLOTS, max_len=MAX_LEN,
                 ticks_per_sync=2, record_traffic=False)
    for r in _workload(seed=3, n=5):
        eng.submit(r)
    assert eng.run() == 0
    for n in eng._guarded:
        want = np.full_like(np.asarray(eng.cache[n]), eng._bank_reset[n])
        np.testing.assert_array_equal(np.asarray(eng.cache[n]), want,
                                      err_msg=f"bank {n} kept stale state")


def test_encdec_enc_bank_row_isolated():
    """Admitting a whisper request writes ONLY its slot's enc/out row;
    the encoder program runs at the fixed (slots, max_len) shape so both
    engines' rows are bitwise identical."""
    model, params = _mp("whisper-tiny")
    eng = Engine(model, params, slots=SLOTS, max_len=MAX_LEN,
                 ticks_per_sync=1, record_traffic=False)
    eng.submit(Request(uid=0, prompt=[5, 7, 11], max_new_tokens=4))
    eng._admit()
    enc = np.asarray(eng.cache["enc/out"])
    assert np.abs(enc[0]).sum() > 0, "admitted row must hold encoder output"
    np.testing.assert_array_equal(enc[1:], np.zeros_like(enc[1:]))

    ref = EngineReference(model, params, slots=SLOTS, max_len=MAX_LEN)
    ref._prefill(0, Request(uid=0, prompt=[5, 7, 11], max_new_tokens=4))
    np.testing.assert_array_equal(
        np.asarray(ref.cache["enc/out"])[0], enc[0],
        err_msg="enc/out rows must be bitwise identical across engines")
