"""Property suite for the serve traffic generator (model-free).

Poisson arrival statistics, heavy-tailed length bounds, burst
modulation, seeded reproducibility, and input validation — the
engine-facing side (run_arrivals parity, latency stamps) lives in
tests/test_serve_engine.py.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.serve import (lognormal_lengths, poisson_arrivals,
                         poisson_requests)


def test_arrivals_sorted_strictly_increasing():
    rng = np.random.default_rng(0)
    t = poisson_arrivals(500, rate=0.5, rng=rng)
    assert len(t) == 500
    assert np.all(t > 0)
    assert np.all(np.diff(t) > 0)


def test_homogeneous_poisson_mean_within_tolerance():
    rng = np.random.default_rng(1)
    for rate in (0.25, 2.0):
        t = poisson_arrivals(4000, rate=rate, rng=rng)
        mean_gap = float(np.mean(np.diff(t)))
        # n=4000 exponential gaps: sample mean within ~5 sigma of 1/rate
        assert mean_gap == pytest.approx(1.0 / rate, rel=0.1)


def test_burst_modulation_shifts_mass_into_the_peak():
    """lambda(t) = r (1 + a sin(2 pi t / P)): the first half of each
    period is boosted, the second suppressed — arrival mass must follow."""
    rng = np.random.default_rng(2)
    period = 40.0
    t = poisson_arrivals(6000, rate=1.0, rng=rng, burst_amp=0.9,
                         burst_period=period)
    assert np.all(np.diff(t) > 0)
    phase = np.mod(t, period)
    peak = int(np.sum(phase < period / 2))
    trough = len(t) - peak
    assert peak > 1.5 * trough, (peak, trough)


def test_burst_zero_matches_homogeneous_stream():
    # amp=0 must take the plain exponential-gap path (every proposal
    # accepted), so the long-run rate is just the homogeneous one
    rng = np.random.default_rng(3)
    t = poisson_arrivals(3000, rate=0.5, rng=rng, burst_amp=0.0)
    assert float(np.mean(np.diff(t))) == pytest.approx(2.0, rel=0.1)


def test_lognormal_lengths_honor_bounds():
    rng = np.random.default_rng(4)
    ls = lognormal_lengths(2000, rng=rng, log_mean=2.0, sigma=1.0,
                           bounds=(3, 17))
    assert ls.min() >= 3 and ls.max() <= 17
    assert ls.dtype == np.int64
    # heavy tail actually exercises both clips
    assert (ls == 3).any() and (ls == 17).any()


def test_poisson_requests_bounds_and_reproducibility():
    kw = dict(seed=7, vocab=256, arrival_rate=0.5, burst_amp=0.5,
              prompt_bounds=(2, 11), new_bounds=(1, 9))
    a = poisson_requests(50, **kw)
    b = poisson_requests(50, **kw)
    c = poisson_requests(50, **dict(kw, seed=8))
    assert [dataclasses.asdict(r) for r in a] == \
        [dataclasses.asdict(r) for r in b], "same seed must reproduce"
    assert [r.prompt for r in a] != [r.prompt for r in c]
    arrivals = [r.arrival for r in a]
    assert arrivals == sorted(arrivals)
    assert [r.uid for r in a] == list(range(50))
    for r in a:
        assert 2 <= len(r.prompt) <= 11
        assert 1 <= r.max_new_tokens <= 9
        assert all(1 <= t < 256 for t in r.prompt)
        assert r.arrival > 0


def test_poisson_requests_temperature_every():
    reqs = poisson_requests(6, seed=0, temperature=0.7, temperature_every=2)
    assert [r.temperature for r in reqs] == [0.0, 0.7] * 3


def test_generator_validation():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError, match="rate"):
        poisson_arrivals(5, rate=0.0, rng=rng)
    with pytest.raises(ValueError, match="burst_amp"):
        poisson_arrivals(5, rate=1.0, rng=rng, burst_amp=1.5)
    with pytest.raises(ValueError, match="burst_period"):
        poisson_arrivals(5, rate=1.0, rng=rng, burst_amp=0.5,
                         burst_period=0.0)
    with pytest.raises(ValueError, match="bounds"):
        lognormal_lengths(5, rng=rng, log_mean=1.0, sigma=0.5,
                          bounds=(9, 3))


def test_mean_rate_against_integrated_intensity():
    """Time-averaged modulated rate equals the base rate (sin integrates
    to ~0 over whole periods): n arrivals should take ~n/rate ticks."""
    rng = np.random.default_rng(5)
    n, rate = 5000, 1.0
    t = poisson_arrivals(n, rate=rate, rng=rng, burst_amp=0.8,
                         burst_period=16.0)
    expected = n / rate
    assert math.isclose(t[-1], expected, rel_tol=0.1), (t[-1], expected)
