"""Fused train window: device-batch bitwise parity, window-vs-oracle loss
trajectories (plain / microbatched / compressed), window checkpointing +
exact resume, and the train-traffic -> crosslayer verdict handoff
(DESIGN.md §12)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.crosslayer import analyze_train
from repro.data import DataConfig, Pipeline, batch_for_step, device_batch_at
from repro.models import build_model
from repro.optim import AdamW, constant
from repro.train.checkpoint import CheckpointManager
from repro.train.trainer import (effective_optimizer, init_state,
                                 make_train_step, make_train_window)

SEQ, BATCH, K = 8, 4, 4


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("llama3-8b"), dtype="float32", num_layers=1,
                  d_model=16, d_ff=32, num_heads=1, num_kv_heads=1,
                  head_dim=16, vocab_size=128)
    model = build_model(cfg, max_seq=SEQ)
    opt = AdamW(lr=constant(1e-3), weight_decay=0.0)
    dcfg = DataConfig(cfg.vocab_size, SEQ, BATCH)
    return model, opt, dcfg


# --- device-side batch generation --------------------------------------------


def test_device_batch_bitwise_matches_host():
    for seed, step, hosts, hid in ((0, 0, 1, 0), (3, 17, 1, 0),
                                   (1, 12345, 2, 1)):
        cfg = DataConfig(512, 16, 4 * hosts, seed=seed, num_hosts=hosts,
                         host_id=hid)
        host = batch_for_step(cfg, step)
        dev = jax.tree.map(np.asarray, device_batch_at(cfg, step))
        np.testing.assert_array_equal(host["tokens"], dev["tokens"])
        np.testing.assert_array_equal(host["labels"], dev["labels"])


def test_device_batch_traced_step_in_scan():
    cfg = DataConfig(256, 8, 2)

    @jax.jit
    def all_batches(start):
        def body(step, _):
            return step + 1, device_batch_at(cfg, step)["tokens"]
        _, toks = jax.lax.scan(body, start, None, length=3)
        return toks

    toks = np.asarray(all_batches(jnp.int32(5)))
    for i in range(3):
        np.testing.assert_array_equal(
            toks[i], batch_for_step(cfg, 5 + i)["tokens"])


def test_device_batch_tokens_in_vocab_and_shifted():
    cfg = DataConfig(128, 16, 4)
    b = jax.tree.map(np.asarray, device_batch_at(cfg, 9))
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 128
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# --- window vs per-step oracle ------------------------------------------------


def _oracle_losses(model, opt, dcfg, steps, **step_kw):
    opt_eff = effective_optimizer(opt,
                                  step_kw.get("compress_grads", False),
                                  step_kw.get("compress_shards", 1))
    state = init_state(model, opt_eff, jax.random.PRNGKey(0))
    fn = jax.jit(make_train_step(model, opt, **step_kw),
                 donate_argnums=(0,))
    data = Pipeline(dcfg)
    out = []
    for _ in range(steps):
        state, m = fn(state, jax.tree.map(jnp.asarray, next(data)))
        out.append((float(m["loss"]), float(m["grad_norm"])))
    data.close()
    return out, state


def _window_losses(model, opt, dcfg, steps, **win_kw):
    opt_eff = effective_optimizer(opt,
                                  win_kw.get("compress_grads", False),
                                  win_kw.get("compress_shards", 1))
    state = init_state(model, opt_eff, jax.random.PRNGKey(0))
    win = make_train_window(model, opt, steps_per_sync=steps, data_cfg=dcfg,
                            record_traffic=False, **win_kw)
    state, m = win(state)
    return list(zip(np.asarray(m["loss"]).tolist(),
                    np.asarray(m["grad_norm"]).tolist())), state


@pytest.mark.parametrize("kw", [
    {},
    {"microbatches": 2},
    {"compress_grads": True, "compress_shards": 2},
    {"microbatches": 2, "compress_grads": True, "compress_shards": 2},
], ids=["plain", "microbatched", "compressed", "micro+compressed"])
def test_window_matches_per_step_oracle(setup, kw):
    model, opt, dcfg = setup
    oracle, s1 = _oracle_losses(model, opt, dcfg, K, **kw)
    fused, s2 = _window_losses(model, opt, dcfg, K, **kw)
    assert fused == oracle  # bitwise: same tokens, same step program
    np.testing.assert_array_equal(np.asarray(s1["params"]["emb/tok"]),
                                  np.asarray(s2["params"]["emb/tok"]))
    assert int(s2["step"]) == K


def test_window_step_counter_is_data_position(setup):
    # two windows == one double-length window: the step counter carried in
    # state is the only data cursor, so trajectories must concatenate
    model, opt, dcfg = setup
    state = init_state(model, opt, jax.random.PRNGKey(0))
    win = make_train_window(model, opt, steps_per_sync=2, data_cfg=dcfg,
                            record_traffic=False)
    state, m1 = win(state)
    state, m2 = win(state)
    both = np.concatenate([np.asarray(m1["loss"]), np.asarray(m2["loss"])])
    fused, _ = _window_losses(model, opt, dcfg, 4)
    np.testing.assert_array_equal(both, np.asarray([l for l, _ in fused]))


def test_window_checkpoint_restore_resumes_exactly(setup, tmp_path):
    model, opt, dcfg = setup
    win = make_train_window(model, opt, steps_per_sync=2, data_cfg=dcfg,
                            record_traffic=False)
    mgr = CheckpointManager(str(tmp_path))

    state = init_state(model, opt, jax.random.PRNGKey(0))
    state, _ = win(state)                       # window 1 (steps 0-1)
    mgr.save(2, state, blocking=True)
    state, m_cont = win(state)                  # window 2, uninterrupted

    like = init_state(model, opt, jax.random.PRNGKey(1))  # different init
    restored = mgr.restore(like)
    assert int(restored["step"]) == 2
    restored, m_res = win(restored)             # window 2 after restore
    np.testing.assert_array_equal(np.asarray(m_cont["loss"]),
                                  np.asarray(m_res["loss"]))
    np.testing.assert_array_equal(np.asarray(m_cont["grad_norm"]),
                                  np.asarray(m_res["grad_norm"]))


def test_window_validates_args(setup):
    model, opt, dcfg = setup
    with pytest.raises(ValueError):
        make_train_window(model, opt, steps_per_sync=0, data_cfg=dcfg)
    with pytest.raises(ValueError):  # 4 rows not divisible by 3 chunks
        make_train_window(model, opt, steps_per_sync=1, microbatches=3,
                          data_cfg=dcfg)
    with pytest.raises(ValueError):  # shards without compression
        make_train_step(model, opt, compress_shards=2)


# --- train-traffic -> crosslayer handoff -------------------------------------


def test_train_records_and_verdicts(setup):
    model, opt, dcfg = setup
    win = make_train_window(model, opt, steps_per_sync=2, data_cfg=dcfg)
    assert win.train_records() == []            # nothing ran yet
    state = init_state(model, opt, jax.random.PRNGKey(0))
    state, _ = win(state)
    state, _ = win(state)
    recs = win.train_records()
    assert len(recs) == 1 and recs[0]["kind"] == "train"
    assert recs[0]["steps"] == 4                # 2 windows x K=2
    roof = recs[0]["roofline"]
    assert roof["flops_per_device"] > 0 and roof["bytes_per_device"] > 0
    verdicts = win.nvm_verdicts()
    assert len(verdicts) == 1
    v = verdicts[0]
    assert v.shape == f"train_window_b{BATCH}_s{SEQ}_k2"
    for mem in ("STT", "SOT"):
        assert v.energy_ratio[mem] > 0 and v.edp_ratio[mem] > 0


def test_analyze_train_uses_write_heavier_split():
    # same roofline terms must score differently by mode: analyze_train
    # splits with TRAIN_READ_FRACTION (more writes), analyze_serve with
    # the read-heavy inference convention — the verdict really does
    # depend on the R/W mix, not just byte totals
    from repro.core.crosslayer import (READ_FRACTION, TRAIN_READ_FRACTION,
                                       analyze_serve)
    assert TRAIN_READ_FRACTION < READ_FRACTION
    rec = {"arch": "x", "mesh": "1dev", "shape": "t", "kind": "train",
           "roofline": {"flops_per_device": 1e12, "bytes_per_device": 1e9,
                        "collective_bytes": 0.0, "compute_s": 1e-3,
                        "memory_s": 2e-3, "collective_s": 0.0}}
    t = analyze_train([rec])[0]
    s = analyze_serve([rec])[0]
    assert t.writes > s.writes and t.reads < s.reads
    assert t.reads / (t.reads + t.writes) == pytest.approx(
        TRAIN_READ_FRACTION)
    for mem in ("STT", "SOT"):
        # at the calibrated 100+MB tier, sectored MRAM writes come out
        # CHEAPER than SRAM line writes, so the write-heavier train mix
        # shifts the energy ratio in MRAM's favor — the point is that it
        # shifts (direction pinned so a silent split regression fails)
        assert t.energy_ratio[mem] < s.energy_ratio[mem]


def test_analyze_train_missing_roofline_raises():
    with pytest.raises(ValueError, match="record_traffic"):
        analyze_train([{"arch": "x", "mesh": "1dev", "shape": "t",
                        "roofline": {"bytes_per_device": 1.0}}])
    assert analyze_train([]) == []
