"""Batched traffic engine vs the scalar per-point reference.

The parity tests are the engine's correctness contract: every (workload,
mode, batch) cell of the batched tensor must match the seed scalar path
(``profiles.profile_reference``) to 1e-6 relative, and
``paper_profiles()`` must keep its exact order/labels.  Regression tests
pin the new loud-failure behaviors (HPCG mode/batch ValueError,
``analyze_dryrun_dir`` FileNotFoundError) and thread the modern-config
cohort through the Fig-3 / iso-capacity pipeline.  None of these use
hypothesis (see test_traffic_properties.py for the property suite).
"""
import math

import pytest

from repro.core import traffic as tr
from repro.core.iso import batch_sweep, iso_capacity, summarize
from repro.core.profiles import (TRAFFIC, paper_profiles, profile,
                                 profile_reference)
from repro.core.workloads import HPCG, NETWORKS

PARITY_RTOL = 1e-6
FIELDS = ("l2_reads", "l2_writes", "dram")


# --- parity with the scalar reference --------------------------------------


@pytest.mark.parametrize("name", list(NETWORKS))
@pytest.mark.parametrize("mode", tr.MODES)
def test_profile_parity(name, mode):
    for batch in (1, 4, 64, 512):
        eng = profile(name, mode, batch)
        ref = profile_reference(name, mode, batch)
        for f in FIELDS:
            assert getattr(eng, f) == pytest.approx(
                getattr(ref, f), rel=PARITY_RTOL), (name, mode, batch, f)


@pytest.mark.parametrize("name", list(HPCG))
def test_hpcg_parity(name):
    eng = profile(name, "hpc", 1)
    ref = profile_reference(name, "hpc", 1)
    for f in FIELDS:
        assert getattr(eng, f) == pytest.approx(getattr(ref, f),
                                                rel=PARITY_RTOL)


def test_paper_profiles_order_and_parity():
    profs = paper_profiles()
    assert [p.label for p in profs] == [
        f"{n}-{s}" for n in NETWORKS for s in ("I", "T")] + list(HPCG)
    for p in profs:
        ref = profile_reference(p.name, p.mode, p.batch)
        for f in FIELDS:
            assert getattr(p, f) == pytest.approx(getattr(ref, f),
                                                  rel=PARITY_RTOL)


def test_tensor_is_one_batched_evaluation():
    batches = (1.0, 4.0, 64.0)
    tt = tr.compute_traffic(tr.paper_pack(), batches)
    w = len(tt.names)
    assert tt.reads.shape == tt.writes.shape == tt.dram.shape \
        == (w, len(tr.MODES), len(batches))
    # every DL cell matches the per-point path
    for name in NETWORKS:
        for mi, mode in enumerate(tr.MODES):
            for bi, b in enumerate(batches):
                ref = profile_reference(name, mode, int(b))
                wi = tt.names.index(name)
                assert tt.reads[wi, mi, bi] == pytest.approx(
                    ref.l2_reads, rel=PARITY_RTOL)


def test_batch_sweep_matches_per_point():
    sw = batch_sweep("AlexNet", "training", (4, 32))
    assert set(sw) == {4, 32}
    # per-point pipeline: scalar profile -> scalar iso_capacity
    for b in (4, 32):
        per_point = iso_capacity([profile_reference("AlexNet", "training",
                                                    b)])[0]
        for m in ("STT", "SOT"):
            for k, v in per_point.metrics[m].items():
                assert sw[b].metrics[m][k] == pytest.approx(v, rel=1e-5)


# --- loud-failure regressions ----------------------------------------------


@pytest.mark.parametrize("mode,batch", [("inference", 1), ("training", 64),
                                        ("hpc", 4)])
def test_hpcg_invalid_args_raise(mode, batch):
    with pytest.raises(ValueError, match="HPC workload"):
        profile("HPCG-S", mode, batch)
    with pytest.raises(ValueError, match="HPC workload"):
        profile_reference("HPCG-S", mode, batch)


def test_tensor_hpc_guard_matches_profile_guard():
    """The tensor view enforces the same HPCG guard as profile(), so
    batch_sweep and other direct consumers can't get mislabeled rows."""
    tt = tr.compute_traffic(tr.paper_pack(), (4.0,))
    with pytest.raises(ValueError, match="HPC workload"):
        tt.profile("HPCG-S", "training", 4)
    assert tt.profile("HPCG-S", "hpc", 1).rw_ratio > 0


def test_analyze_dryrun_dir_missing_raises(tmp_path):
    from repro.core.crosslayer import analyze_dryrun_dir
    missing = tmp_path / "nope"
    with pytest.raises(FileNotFoundError, match="nope"):
        analyze_dryrun_dir(str(missing))
    empty = tmp_path / "empty"
    empty.mkdir()
    with pytest.raises(FileNotFoundError, match="mytag"):
        analyze_dryrun_dir(str(empty), tag="mytag")


# --- Fig-3 band -------------------------------------------------------------


def test_paper_rw_ratios_in_fig3_band():
    for p in paper_profiles():
        assert 1.5 <= p.rw_ratio <= 26.5, (p.label, p.rw_ratio)


# --- modern-config cohort ---------------------------------------------------


@pytest.fixture(scope="module")
def modern():
    return tr.modern_profiles()


def test_modern_cohort_rw_rows(modern):
    assert len(modern) == 2 * len(tr.MODERN_COHORT) >= 6
    for p in modern:
        assert p.l2_reads > 0 and p.l2_writes > 0 and p.dram > 0
        assert math.isfinite(p.rw_ratio)
    # training adds backward-pass reads: R/W must rise vs inference
    by_name = {p.label: p for p in modern}
    for arch in tr.MODERN_COHORT:
        assert (by_name[f"{arch}-T"].rw_ratio
                > by_name[f"{arch}-I"].rw_ratio)


def test_modern_cohort_iso_capacity_edp(modern):
    res = iso_capacity(modern)
    assert [r.workload for r in res] == [p.label for p in modern]
    s = summarize(res, "edp_with_dram")
    for m in ("STT", "SOT"):
        for r in res:
            v = r.metrics[m]["edp_with_dram"]
            assert math.isfinite(v) and v > 0
        # MRAM tiers must still win on EDP for these workloads
        assert s[m]["mean"] < 1.0


def test_layer_stack_lowering_families():
    from repro.configs import get_config
    for arch in ("llama3-8b", "mamba2-1.3b", "whisper-tiny"):
        stack = tr.LayerStack.from_config(get_config(arch), seq_len=128)
        assert len(stack.layers) > 4
        assert all(l.in_bytes > 0 and l.out_bytes > 0 for l in stack.layers)
    # MoE streams only the active experts
    moe = get_config("granite-moe-3b-a800m")
    stack = tr.LayerStack.from_config(moe, seq_len=128)
    experts = [l for l in stack.layers if l.name.endswith(".experts")]
    assert experts
    mlp_in = 2 * moe.d_ff if moe.gated_mlp else moe.d_ff
    full = moe.num_experts * (moe.d_model * mlp_in
                              + moe.d_ff * moe.d_model) * 2
    assert experts[0].weight_bytes < full


# --- differentiable claim loss ---------------------------------------------


def test_claim_loss_differentiable():
    import jax
    import jax.numpy as jnp

    loss_fn, claims_fn = tr.make_claim_loss()
    t = {k: jnp.asarray(v, jnp.float32) for k, v in TRAFFIC.items()}
    l0 = float(jax.jit(loss_fn)(t))
    # frozen knobs were fit to ~0.18 mean |log err| over the 13 claims
    assert 0.05 < l0 < 0.4
    g = jax.grad(lambda t_: loss_fn(t_))(t)
    assert all(math.isfinite(float(v)) for v in g.values())
    assert any(abs(float(v)) > 0 for v in g.values())
    claims, pen = claims_fn(TRAFFIC)
    assert len(claims) == len(tr.CLAIM_TARGETS) == 13
    assert pen == pytest.approx(0.0, abs=1e-6)
