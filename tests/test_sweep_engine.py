"""Batched sweep engine vs the legacy per-point Algorithm-1 path.

The parity tests are the engine's correctness contract: identical selected
configurations (banks, rows, access type) and matching PPA values on every
(memory, capacity) pair of the default grid, plus the iso-area ladder
search.  The regression tests pin the Table-2 anchor configurations so a
calibration or model change that silently moves the paper's anchors fails
loudly.  None of these use hypothesis, so they run even when the property
suite is skipped.
"""
import math

import numpy as np
import pytest

from repro.core.cache_model import (ACCESS_TYPES, BANKS, CAL, PPA_METRICS,
                                    ROWS)
from repro.core.sweep import (capacity_ladder, iso_area_search,
                              make_calibration_loss, sweep)
from repro.core.table2 import TABLE2_ANCHORS as TABLE2
from repro.core.tuner import (CAPACITIES_MB, MEMORIES, iso_area_capacity,
                              tune, tune_all, tune_reference)


def _key(p):
    return (p.banks, p.rows, p.access_type)


@pytest.fixture(scope="module")
def engine_all():
    return tune_all()


# --- parity with the legacy per-point path ---------------------------------


@pytest.mark.parametrize("mem", MEMORIES)
@pytest.mark.parametrize("cap", CAPACITIES_MB)
def test_tune_parity(engine_all, mem, cap):
    ref = tune_reference(mem, cap)
    eng = engine_all[mem][cap]
    assert _key(eng) == _key(ref)
    for f in PPA_METRICS:
        assert getattr(eng, f) == pytest.approx(getattr(ref, f), rel=1e-6)


def test_single_tune_matches_batched(engine_all):
    for mem in MEMORIES:
        p = tune(mem, 8)
        assert _key(p) == _key(engine_all[mem][8])


def test_iso_area_parity():
    budget = tune("SRAM", 3).area_mm2
    for mem in ("STT", "SOT"):
        # legacy search: walk the ladder per-point, keep the last fit
        best = None
        for cap in capacity_ladder():
            p = tune_reference(mem, cap)
            if p.area_mm2 <= budget * 1.08:
                best = p
        eng = iso_area_capacity(mem, budget)
        assert eng.capacity_mb == best.capacity_mb
        assert _key(eng) == _key(best)


def test_iso_area_search_batches_both_nvms():
    budget = tune("SRAM", 3).area_mm2
    out = iso_area_search(("STT", "SOT"), budget)
    assert out["SOT"].capacity_mb > out["STT"].capacity_mb > 3


def test_iso_area_no_fit_raises_with_budget():
    with pytest.raises(ValueError, match="0.001"):
        iso_area_capacity("STT", 0.001)


# --- sweep result structure ------------------------------------------------


def test_grid_shapes_and_edap_consistency():
    s = sweep(MEMORIES, CAPACITIES_MB)
    shape = (len(MEMORIES), len(CAPACITIES_MB), len(BANKS), len(ROWS),
             len(ACCESS_TYPES))
    for k in PPA_METRICS + ("edap",):
        assert s.grid[k].shape == shape
        assert s.tuned[k].shape == shape[:2]
    # Algorithm 1 picks close to (but not necessarily at) the grid minimum
    gmin = s.grid["edap"].reshape(shape[0], shape[1], -1).min(axis=2)
    assert np.all(gmin <= s.tuned["edap"])
    assert np.all(s.tuned["edap"] <= 1.2 * gmin)


def test_config_roundtrip():
    s = sweep(("STT",), (4,))
    p = s.config("STT", 4)
    banks, rows, acc = s.selection("STT", 4)
    assert (p.banks, p.rows, p.access_type) == (banks, rows, acc)
    assert p.capacity_mb == 4.0 and p.mem == "STT"


# --- Table-2 anchor regression ---------------------------------------------


@pytest.mark.parametrize("key", list(TABLE2))
def test_table2_anchors_through_engine(key):
    mem, cap = key
    s = sweep((mem,), (float(cap),))
    p = s.config(mem, float(cap))
    for field, target in TABLE2[key].items():
        assert abs(math.log(getattr(p, field) / target)) < 0.45, (key, field)


def test_table2_mean_error_pinned():
    errs = []
    for (mem, cap), tgt in TABLE2.items():
        p = tune(mem, cap)
        errs += [abs(math.log(getattr(p, f) / t)) for f, t in tgt.items()]
    assert sum(errs) / len(errs) < 0.15


def test_table2_anchor_selections_pinned():
    """The EDAP-tuned design points behind the paper's Table-2 anchors.

    These pins are the frozen-calibration contract: if CAL or the circuit
    model changes enough to move an anchor's selected configuration, this
    fails and the constants must be re-frozen via tools/calibrate_cache.py.
    """
    expected = {(mem, cap): _key(tune_reference(mem, cap))
                for (mem, cap) in TABLE2}
    for (mem, cap), sel in expected.items():
        assert _key(tune(mem, cap)) == sel, (mem, cap)
        assert sel[2] == "Sequential"


# --- differentiable calibration --------------------------------------------


def test_calibration_loss_matches_frozen_fit():
    import jax

    targets = {k: dict(rl=v["read_latency_ns"], wl=v["write_latency_ns"],
                       re=v["read_energy_nj"], we=v["write_energy_nj"],
                       lk=v["leakage_mw"], ar=v["area_mm2"])
               for k, v in TABLE2.items()}
    fields = dict(rl="read_latency_ns", wl="write_latency_ns",
                  re="read_energy_nj", we="write_energy_nj",
                  lk="leakage_mw", ar="area_mm2")
    weights = {k: 1.0 for k in fields}
    loss = make_calibration_loss(targets, weights, fields)
    cal = {k: float(v) for k, v in CAL.items()}
    l0 = float(loss(cal))
    # unweighted mean |log err| of the frozen constants (~0.088)
    assert 0.0 < l0 < 0.15

    g = jax.grad(lambda c: loss(c))(cal)
    finite = [math.isfinite(float(v)) for v in g.values()]
    assert all(finite)
    assert any(abs(float(g[k])) > 0 for k in g if k != "wr_sector_bits")
