"""Hypothesis properties of the batched traffic engine (§4.1 / Fig 6).

Invariants: training R/W ratio monotone-increasing and inference R/W
monotone-decreasing in batch for every paper workload (the Fig-6
direction claims), scalar-vs-batched parity at 1e-6 relative on random
cells, positive traffic everywhere, and the pack's float64 reductions
matching the padded per-layer arrays they summarize.
"""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.core import traffic as tr
from repro.core.profiles import profile, profile_reference
from repro.core.workloads import NETWORKS

NET_NAMES = sorted(NETWORKS)


@given(name=st.sampled_from(NET_NAMES),
       b1=st.integers(1, 512), b2=st.integers(1, 512))
@settings(max_examples=30, deadline=None)
def test_rw_ratio_monotone_in_batch(name, b1, b2):
    """Training gets MORE read-dominant with batch, inference LESS."""
    lo, hi = sorted((b1, b2))
    tt = tr.compute_traffic(tr.paper_pack(), (float(lo), float(hi)))
    tr_lo = tt.profile(name, "training", lo).rw_ratio
    tr_hi = tt.profile(name, "training", hi).rw_ratio
    inf_lo = tt.profile(name, "inference", lo).rw_ratio
    inf_hi = tt.profile(name, "inference", hi).rw_ratio
    assert tr_lo <= tr_hi * (1 + 1e-6)
    assert inf_lo >= inf_hi * (1 - 1e-6)


@given(name=st.sampled_from(NET_NAMES),
       mode=st.sampled_from(tr.MODES),
       batch=st.integers(1, 1024))
@settings(max_examples=40, deadline=None)
def test_scalar_batched_parity(name, mode, batch):
    eng = profile(name, mode, batch)
    ref = profile_reference(name, mode, batch)
    for f in ("l2_reads", "l2_writes", "dram"):
        rel = abs(getattr(eng, f) / getattr(ref, f) - 1.0)
        assert rel < 1e-6, (name, mode, batch, f, rel)
    assert eng.l2_reads > 0 and eng.l2_writes > 0 and eng.dram > 0


def test_paper_workloads_in_fig3_band():
    from repro.core.profiles import paper_profiles
    for p in paper_profiles():
        assert 1.5 <= p.rw_ratio <= 26.5, (p.label, p.rw_ratio)


def test_pack_reductions_match_padded_arrays():
    """The (W,) float64 reductions are exactly the masked layer sums of
    the padded (W, Lmax) descriptor arrays they were built from."""
    pack = tr.paper_pack()
    lay = pack.layers
    m = lay["mask"]
    expect = {
        "a_conv": (lay["in_bytes"] * lay["kk"] * lay["is_conv"] * m).sum(1),
        "a_fc": (lay["in_bytes"] * lay["is_fc"] * m).sum(1),
        "s_in": (lay["in_bytes"] * m).sum(1),
        "s_out": (lay["out_bytes"] * m).sum(1),
        "w_conv": (lay["weight_bytes"] * lay["is_conv"] * m).sum(1),
        "w_fc": (lay["weight_bytes"] * lay["is_fc"] * m).sum(1),
    }
    for k, v in expect.items():
        np.testing.assert_allclose(pack.reduced[k], v, rtol=1e-12)
    # padding is inert: masked-out entries are zero
    assert np.all(lay["in_bytes"] * (1 - m) == 0)
