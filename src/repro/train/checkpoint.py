"""Fault-tolerant checkpointing.

Design (1000+ node posture):
  * atomic: write to ``step_N.tmp`` then rename — a crash mid-save never
    corrupts the latest checkpoint;
  * async: a background thread serializes device arrays snapshotted at
    save() call time, so the train loop never blocks on disk;
  * self-describing: a JSON manifest stores shapes/dtypes/step/config hash;
  * reshardable: restore() takes target shardings (any mesh) and
    device_puts each leaf — this is what makes elastic up/down-scaling work
    (see train/elastic.py); on multi-host each process would restore only
    its addressable shards (jax.device_put with NamedSharding handles it);
  * retention: keep the last ``keep`` checkpoints, delete older ones.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

_SEP = "::"  # path separator for flattened pytree keys


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        if keep < 1:
            # keep=0 used to hit ``steps[:-0] == []`` in _gc, silently
            # turning retention off instead of doing anything sane
            raise ValueError(f"keep must be >= 1, got {keep}")
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self._recover()

    def _recover(self):
        """Crash-recovery sweep for interrupted re-save swaps: a crash
        between the two renames in ``_write`` leaves the data only under
        ``step_N.old`` — republish it; if the swap completed, the leftover
        ``.old`` is garbage — drop it."""
        for old in self.dir.glob("step_*.old"):
            final = self.dir / old.name[:-len(".old")]
            if final.exists():
                shutil.rmtree(old, ignore_errors=True)
            else:
                os.rename(old, final)

    # ---- save -----------------------------------------------------------
    def save(self, step: int, state: Any, *, blocking: bool = False,
             extra: Optional[Dict] = None):
        """Snapshot ``state`` (device->host copy now), serialize async.

        Raises any exception the PREVIOUS async write died with (see
        ``wait``) before starting the new one — writer failures never die
        invisibly in the daemon thread.
        """
        flat = _flatten(state)
        host = {k: np.asarray(v) for k, v in flat.items()}
        self.wait()
        self._thread = threading.Thread(
            target=self._write_guarded, args=(step, host, extra or {}),
            daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def _write_guarded(self, step: int, host: Dict[str, np.ndarray],
                       extra: Dict):
        try:
            self._write(step, host, extra)
        except BaseException as e:  # surfaced by wait()/next save()
            self._error = e

    def _write(self, step: int, host: Dict[str, np.ndarray], extra: Dict):
        tmp = self.dir / f"step_{step}.tmp"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "time": time.time(), "extra": extra,
                    "leaves": {}}
        for key, arr in host.items():
            fname = f"{abs(hash(key)) % 10**12}_{len(manifest['leaves'])}.npy"
            np.save(tmp / fname, arr)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype)}
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            # re-saving an existing step: os.replace onto a non-empty dir
            # raises, so swap — park the old dir under a name all_steps()
            # ignores, publish the new one, then drop the old.  A crash
            # between the renames leaves the step only under ``.old``;
            # the ``_recover`` sweep on next startup republishes it, so
            # either the old or the new checkpoint survives, never a
            # corrupt mix.
            old = self.dir / f"step_{step}.old"
            if old.exists():
                shutil.rmtree(old)
            os.rename(final, old)
            os.rename(tmp, final)
            shutil.rmtree(old, ignore_errors=True)
        else:
            os.rename(tmp, final)  # atomic on POSIX
        self._gc()

    def wait(self):
        """Block until the in-flight write finishes; re-raise its error."""
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # ---- restore ---------------------------------------------------------
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.is_dir() and not p.name.endswith(".tmp"):
                try:
                    out.append(int(p.name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``like``; optionally reshard.

        ``shardings`` (same pytree structure, NamedSharding leaves) places
        every leaf onto the CURRENT mesh — restoring a checkpoint written on
        a different mesh size is exactly this call (elastic restart).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat_like = _flatten(like)
        flat_sh = _flatten(shardings) if shardings is not None else {}
        out = {}
        for key, ref in flat_like.items():
            meta = manifest["leaves"].get(key)
            if meta is None:
                raise KeyError(f"checkpoint at step {step} missing {key!r}")
            arr = np.load(d / meta["file"])
            if str(arr.dtype) != meta["dtype"]:
                # np.load hands ml_dtypes leaves (bf16, f8) back as raw
                # void records ('|V2'); reinterpret via the manifest dtype
                import jax.numpy as jnp
                arr = arr.view(jnp.dtype(meta["dtype"]))
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(
                    f"{key}: checkpoint shape {arr.shape} != {ref.shape}")
            sh = flat_sh.get(key)
            out[key] = (jax.device_put(arr, sh) if sh is not None
                        else jax.device_put(arr))
        # rebuild tree
        treedef = jax.tree_util.tree_structure(like)
        keys = list(_flatten(like).keys())
        return jax.tree_util.tree_unflatten(
            treedef, [out[k] for k in keys])

    def manifest(self, step: Optional[int] = None) -> Dict:
        step = step if step is not None else self.latest_step()
        return json.loads(
            (self.dir / f"step_{step}" / "manifest.json").read_text())
