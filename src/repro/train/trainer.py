"""Training step factory and the fused multi-step train window.

``make_train_step(state, batch)`` is the seed per-step path: loss -> grads
-> AdamW, with optional gradient accumulation (microbatching) and
error-feedback int8 gradient compression.  It stays the PARITY ORACLE for
``make_train_window`` — one jitted, state-donating ``lax.scan`` over
``steps_per_sync`` full train steps whose batches are hashed ON DEVICE
(data/pipeline.py::device_batch_at, the bitwise twin of the host pipeline),
so the host only drains stacked loss/grad-norm metrics at window
boundaries.  The window's compiled roofline terms accumulate into dry-run-
shaped records (``train_records``) scored by ``crosslayer.analyze_train``
-> train-mode SRAM/STT/SOT verdicts (DESIGN.md §12).

Gradient compression (``compress_grads=True``) wires the optim/compress.py
error-feedback int8 path in for real: the optimizer is wrapped with
``wrap_optimizer`` (error buffers live in the opt state, so they
checkpoint/reshard/donate with the Adam moments) and — with
``compress_shards > 1`` — per-shard-group gradients combine through
``compressed_psum_ef(..., mean=True)`` under a named data axis, each
shard's quantization residual banked in its OWN error buffer before the
reduce (per-worker EF, the 1-bit-Adam-family schedule; exactly one
quantization per step).  The named-axis collective runs under ``vmap``
over explicit shard groups, so the single-controller jit sees the same
program that shard_map runs per-device on a multi-host data axis.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.data.pipeline import DataConfig, device_batch_at
from repro.models.api import Model
from repro.optim.adamw import AdamW
from repro.optim.compress import wrap_optimizer
from repro.sharding import constrain

TrainState = Dict[str, Any]  # {"params", "opt", "step"}


def effective_optimizer(opt: AdamW, compress_grads: bool = False,
                        compress_shards: int = 1):
    """The optimizer whose state the train step actually carries.

    ``compress_grads=True`` wraps ``opt`` with the error-feedback int8
    compressor (per-shard error buffers when ``compress_shards > 1``);
    build/restore train state with THIS so the state structure matches
    what ``make_train_step``/``make_train_window`` expect.
    """
    return (wrap_optimizer(opt, shards=compress_shards) if compress_grads
            else opt)


def init_state(model: Model, opt, key) -> TrainState:
    params = model.init(key)
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def abstract_state(model: Model, opt) -> TrainState:
    params = model.abstract_params()
    return {"params": params, "opt": opt.abstract_state(params),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def state_axes(model: Model, opt) -> TrainState:
    axes = model.param_axes()
    return {"params": axes, "opt": opt.state_axes(axes), "step": ()}


def _split_leading(x, n: int):
    return x.reshape((n, x.shape[0] // n) + x.shape[1:])


def window_boundary_crossed(step: int, window: int, every: int) -> bool:
    """True when the window that just ended at ``step`` (i.e. covered
    steps ``step - window .. step``) crossed a multiple of ``every`` —
    the checkpoint cadence shared by launch/train.py and the examples."""
    return (step // every) > ((step - window) // every)


def make_train_step(model: Model, opt: AdamW, *, microbatches: int = 1,
                    compress_grads: bool = False, compress_shards: int = 1,
                    attn_impl: str = "chunked") -> Callable:
    """Build the jittable train step.

    ``microbatches`` grad-accumulates over row chunks of the batch;
    ``compress_grads`` switches the optimizer to the error-feedback int8
    wrapper.  With ``compress_shards > 1`` each shard group microbatch-
    accumulates locally, then the wrapper combines the per-shard
    gradients through ``compressed_psum_ef(..., mean=True)`` on a named
    data axis, banking each shard's residual BEFORE the reduce — the
    distributed error-feedback DP schedule, one quantization per step.
    State must be built with
    ``effective_optimizer(opt, compress_grads, compress_shards)``.
    """
    if microbatches < 1:
        raise ValueError("microbatches must be >= 1")
    if compress_shards < 1:
        raise ValueError("compress_shards must be >= 1")
    if compress_shards > 1 and not compress_grads:
        raise ValueError("compress_shards > 1 requires compress_grads=True")
    opt_eff = effective_optimizer(opt, compress_grads, compress_shards)

    def loss_fn(params, batch):
        return model.loss(params, batch, attn_impl=attn_impl)

    param_axes = model.param_axes()

    def reshard_grads(grads):
        """Pin every grad to its parameter's sharding before the optimizer.

        Without this, backward leaves gradients in whatever (often fully
        gathered) layout the loss used them in, and the elementwise AdamW
        update then runs on gathered f32 moments — measured 147 GiB/device
        on llama3 train_4k under the fsdp strategy (§Perf iteration L2).
        """
        return {k: constrain(g, param_axes[k]) for k, g in grads.items()}

    def local_grads(params, batch):
        """(mean loss, mean grads) over ``microbatches`` chunks of batch."""
        if microbatches == 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        micro = jax.tree.map(lambda x: _split_leading(x, microbatches),
                             batch)

        def acc_body(carry, mb):
            loss_acc, grads_acc = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            return (loss_acc + l,
                    jax.tree.map(jnp.add, grads_acc, g)), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(
            acc_body, (jnp.zeros(()), zeros), micro)
        return (loss / microbatches,
                jax.tree.map(lambda g: g / microbatches, grads))

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        params = state["params"]
        if compress_shards == 1:
            loss, grads = local_grads(params, batch)
            grads = reshard_grads(grads)
        else:
            shards = jax.tree.map(
                lambda x: _split_leading(x, compress_shards), batch)
            # per-shard local grads, stacked on a leading (shards,) axis;
            # the EF int8 combine happens inside the wrapped optimizer
            # (per-shard residuals banked before the reduce)
            loss, grads = jax.vmap(
                lambda mb: local_grads(params, mb))(shards)
            loss = jnp.mean(loss)
            grads = {k: constrain(g, ("batch",) + tuple(param_axes[k]))
                     for k, g in grads.items()}

        new_params, new_opt, metrics = opt_eff.update(
            grads, state["opt"], params)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = dict(metrics, loss=loss)
        return new_state, metrics

    return train_step


class TrainWindow:
    """Fused multi-step training window (the train-side twin of
    serve.Engine's fused decode window, DESIGN.md §12).

    One jitted, state-donating program scans ``steps_per_sync`` (K) full
    train steps; each step hashes its own batch on device from
    ``state["step"]`` (``device_batch_at`` — the bitwise twin of the host
    pipeline), so between host syncs nothing crosses the host boundary and
    the drain is one ``(K,)``-stacked metrics transfer.  The per-step
    oracle (``make_train_step`` + ``data.Pipeline``) consumes the SAME
    token stream, which is what makes loss/metric trajectories directly
    comparable (tests/test_train_engine.py; benchmarks/train_engine.py).

    ``record_traffic=True`` lowers+compiles the window a second time and
    runs the §8 roofline HLO walker over it; per-step terms (window / K)
    accumulate into dry-run-shaped records (``train_records``) scored by
    ``core.crosslayer.analyze_train`` -> train-mode SRAM/STT/SOT verdicts
    (``nvm_verdicts``, printed by launch/train.py).
    """

    def __init__(self, model: Model, opt: AdamW, data_cfg: DataConfig, *,
                 steps_per_sync: int, microbatches: int = 1,
                 compress_grads: bool = False, compress_shards: int = 1,
                 attn_impl: str = "chunked", record_traffic: bool = True,
                 state_shardings: Any = None, donate: bool = True):
        if steps_per_sync < 1:
            raise ValueError("steps_per_sync must be >= 1")
        chunks = microbatches * max(compress_shards, 1)
        if data_cfg.host_batch % chunks:
            raise ValueError(
                f"host batch {data_cfg.host_batch} not divisible by "
                f"microbatches x compress_shards = {chunks}")
        self.model = model
        self.opt = effective_optimizer(opt, compress_grads, compress_shards)
        self.data_cfg = data_cfg
        self.steps_per_sync = int(steps_per_sync)
        self.record_traffic = record_traffic
        self._step_fn = make_train_step(
            model, opt, microbatches=microbatches,
            compress_grads=compress_grads, compress_shards=compress_shards,
            attn_impl=attn_impl)

        def window(state: TrainState):
            def body(state, _):
                batch = device_batch_at(data_cfg, state["step"])
                state, metrics = self._step_fn(state, batch)
                return state, {"loss": metrics["loss"],
                               "grad_norm": metrics["grad_norm"],
                               "lr": metrics["lr"]}

            return jax.lax.scan(body, state, None,
                                length=self.steps_per_sync)

        jit_kw: Dict[str, Any] = {}
        if donate:
            jit_kw["donate_argnums"] = (0,)
        if state_shardings is not None:
            jit_kw["in_shardings"] = (state_shardings,)
            jit_kw["out_shardings"] = (state_shardings, None)
        self._window_jit = jax.jit(window, **jit_kw)
        self._traffic = None
        self._analyzed = False   # attempted-once latch: a failed analysis
        self._windows_run = 0    # must not re-lower+compile every window

    # ---- traffic accounting --------------------------------------------
    def _analyze(self, state):
        """Roofline terms of the compiled window.  Failures degrade to
        None (training keeps running) but warn loudly — a silently empty
        ``train_records()`` would erase the NVM-verdict handoff while CI
        stays green."""
        if not self.record_traffic:
            return None
        try:
            from repro.launch import roofline as rf
            return rf.analyze(self._window_jit.lower(state).compile())
        except Exception as e:  # pragma: no cover - backend-dependent
            import warnings
            warnings.warn(
                f"train traffic analysis failed ({e!r}); train_records() "
                "will be empty", RuntimeWarning, stacklevel=2)
            return None

    # ---- engine loop ----------------------------------------------------
    def __call__(self, state: TrainState
                 ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        """Run one window: K fused train steps.  Returns (new state,
        stacked ``(K,)`` metrics).  ``state`` is donated — use the
        returned state."""
        if self.record_traffic and not self._analyzed:
            self._traffic = self._analyze(state)
            self._analyzed = True
        state, metrics = self._window_jit(state)
        self._windows_run += 1
        return state, metrics

    # ---- train-mode NVM verdicts ---------------------------------------
    def train_records(self, mesh: Optional[str] = None) -> List[dict]:
        """Dry-run-shaped records of the window's measured traffic: one
        record with PER-STEP roofline terms of the compiled K-step window,
        consumable by ``core.crosslayer.analyze_train`` — the train-mode
        answer to the paper's "would an MRAM tier help THIS workload"
        question, asked of the write-heavy regime where Roy et al. (2023)
        show the STT-MRAM trade-off is sharpest."""
        rl = self._traffic
        if rl is None or not self._windows_run:
            return []
        mesh = mesh or f"{jax.device_count()}dev"
        K = self.steps_per_sync
        cfg = self.data_cfg
        return [{
            "arch": self.model.cfg.arch, "mesh": mesh, "kind": "train",
            "shape": f"train_window_b{cfg.host_batch}_s{cfg.seq_len}_k{K}",
            "steps": self._windows_run * K,
            "roofline": {
                "flops_per_device": rl.flops_per_device / K,
                "bytes_per_device": rl.bytes_per_device / K,
                "collective_bytes": rl.collective_bytes / K,
                "compute_s": rl.compute_s / K,
                "memory_s": rl.memory_s / K,
                "collective_s": rl.collective_s / K,
            }}]

    def nvm_verdicts(self, tier_mb: Optional[float] = None):
        """SRAM/STT/SOT tier verdicts on the window's measured traffic."""
        from repro.core.crosslayer import analyze_train
        kw = {} if tier_mb is None else {"tier_mb": tier_mb}
        return analyze_train(self.train_records(), **kw)


def make_train_window(model: Model, opt: AdamW, *, steps_per_sync: int,
                      microbatches: int = 1, data_cfg: DataConfig,
                      **kw) -> TrainWindow:
    """Build the fused K-step train window (see ``TrainWindow``)."""
    return TrainWindow(model, opt, data_cfg, steps_per_sync=steps_per_sync,
                       microbatches=microbatches, **kw)
