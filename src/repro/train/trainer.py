"""Training step factory: loss -> grads -> AdamW, with optional gradient
accumulation (microbatching) and error-feedback int8 gradient compression.

The returned ``train_step(state, batch)`` is a pure function suitable for
``jax.jit`` under a mesh with explicit in/out shardings (see launch/dryrun).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.api import Model
from repro.optim.adamw import AdamW
from repro.sharding import constrain

TrainState = Dict[str, Any]  # {"params", "opt", "step"}


def init_state(model: Model, opt: AdamW, key) -> TrainState:
    params = model.init(key)
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def abstract_state(model: Model, opt: AdamW) -> TrainState:
    params = model.abstract_params()
    return {"params": params, "opt": opt.abstract_state(params),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def state_axes(model: Model, opt: AdamW) -> TrainState:
    axes = model.param_axes()
    return {"params": axes, "opt": opt.state_axes(axes), "step": ()}


def make_train_step(model: Model, opt: AdamW, *, microbatches: int = 1,
                    attn_impl: str = "chunked") -> Callable:
    """Build the jittable train step (optionally gradient-accumulated)."""

    def loss_fn(params, batch):
        return model.loss(params, batch, attn_impl=attn_impl)

    param_axes = model.param_axes()

    def reshard_grads(grads):
        """Pin every grad to its parameter's sharding before the optimizer.

        Without this, backward leaves gradients in whatever (often fully
        gathered) layout the loss used them in, and the elementwise AdamW
        update then runs on gathered f32 moments — measured 147 GiB/device
        on llama3 train_4k under the fsdp strategy (§Perf iteration L2).
        """
        return {k: constrain(g, param_axes[k]) for k, g in grads.items()}

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        params = state["params"]
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = reshard_grads(grads)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                loss_acc, grads_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (loss_acc + l,
                        jax.tree.map(jnp.add, grads_acc, g)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.zeros(()), zeros), micro)
            loss = loss / microbatches
            grads = reshard_grads(
                jax.tree.map(lambda g: g / microbatches, grads))

        new_params, new_opt, metrics = opt.update(grads, state["opt"], params)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = dict(metrics, loss=loss)
        return new_state, metrics

    return train_step
