"""Elastic scaling + straggler mitigation.

Elastic scaling: when the healthy-device count changes (node failure,
capacity add), pick the best mesh from a preference ladder, rebuild
shardings from the SAME logical-axis rules, and restore the latest
checkpoint resharded onto the new mesh (CheckpointManager.restore with new
shardings). Nothing about the model or step function changes — that is the
point of rule-based sharding.

Straggler mitigation: an EMA step-time monitor per host; a host whose step
time exceeds ``threshold`` x the fleet median for ``patience`` consecutive
steps is reported for eviction, which triggers the elastic path above.
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import Dict, List, Optional, Sequence, Tuple

from repro.launch.mesh import make_mesh_for


@dataclasses.dataclass(frozen=True)
class MeshChoice:
    devices: int
    model_parallelism: int
    pods: int


def choose_mesh(num_devices: int,
                preferences: Sequence[Tuple[int, int]] = ((16, 2), (16, 1),
                                                          (8, 1), (4, 1),
                                                          (2, 1), (1, 1))
                ) -> MeshChoice:
    """Largest viable (model_parallelism, pods) config for device count."""
    for model, pods in preferences:
        if num_devices % (model * pods) == 0 and num_devices >= model * pods:
            return MeshChoice(num_devices, model, pods)
    return MeshChoice(num_devices, 1, 1)


def remesh(num_devices: int):
    c = choose_mesh(num_devices)
    return make_mesh_for(c.devices, model_parallelism=c.model_parallelism,
                         pods=c.pods)


class StragglerMonitor:
    """Flags hosts whose EMA step time exceeds threshold x fleet median."""

    def __init__(self, num_hosts: int, threshold: float = 1.5,
                 patience: int = 5, ema: float = 0.3):
        self.num_hosts = num_hosts
        self.threshold = threshold
        self.patience = patience
        self.ema_coef = ema
        self._ema: Dict[int, float] = {}
        self._strikes: Dict[int, int] = {h: 0 for h in range(num_hosts)}

    def record(self, host: int, step_time_s: float) -> None:
        prev = self._ema.get(host)
        self._ema[host] = (step_time_s if prev is None else
                           self.ema_coef * step_time_s
                           + (1 - self.ema_coef) * prev)

    def stragglers(self) -> List[int]:
        """Advance strike counters one step and report hosts that crossed
        ``patience``.  This MUTATES state — call it exactly once per
        recorded step (the seed launcher called it twice per step, double-
        counting strikes).  A reported host's strikes reset, so it is
        reported once per sustained episode instead of on every subsequent
        call (the eviction it triggers is not instantaneous)."""
        if len(self._ema) < max(2, self.num_hosts // 2):
            return []
        med = statistics.median(self._ema.values())
        out = []
        for h, t in self._ema.items():
            if t > self.threshold * med:
                self._strikes[h] += 1
            else:
                self._strikes[h] = 0
            if self._strikes[h] >= self.patience:
                out.append(h)
                self._strikes[h] = 0
        return out


@dataclasses.dataclass
class ElasticEvent:
    kind: str            # "failure" | "straggler" | "scale_up"
    hosts: List[int]
    new_device_count: int


def plan_recovery(event: ElasticEvent):
    """Return (mesh_choice, action) for an elastic event. The runner then:
    1) quiesces, 2) builds the new mesh, 3) restores the latest checkpoint
    with shardings derived from the same rules on the new mesh, 4) resumes
    the data pipeline at the checkpointed step."""
    choice = choose_mesh(event.new_device_count)
    return choice, ("evict+remesh" if event.kind != "scale_up"
                    else "quiesce+remesh")
