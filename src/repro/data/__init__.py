from repro.data.pipeline import (DataConfig, Pipeline, batch_for_step,
                                 device_batch_at)

__all__ = ["DataConfig", "Pipeline", "batch_for_step", "device_batch_at"]
