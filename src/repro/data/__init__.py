from repro.data.pipeline import DataConfig, Pipeline, batch_for_step

__all__ = ["DataConfig", "Pipeline", "batch_for_step"]
