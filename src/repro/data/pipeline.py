"""Deterministic synthetic LM data pipeline.

Production posture: host-sharded (each process generates only its shard of
the global batch), deterministic in (seed, step) so restarts resume exactly,
with a background prefetch thread. Token streams are hash-generated (no
dataset dependency) with a Zipf-ish marginal so losses behave like text.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


def _batch_at(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Deterministic batch for (seed, step, host). Zipf-ish tokens."""
    rng = np.random.Generator(np.random.Philox(
        key=cfg.seed, counter=[step, cfg.host_id, 0, 0]))
    u = rng.random((cfg.host_batch, cfg.seq_len + 1))
    # inverse-CDF of a truncated zipf(1.1)
    ranks = (u ** -2.2 - 1.0)
    tokens = np.clip(ranks.astype(np.int64), 0, cfg.vocab_size - 1)
    tokens = tokens.astype(np.int32)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


class Pipeline:
    """Prefetching iterator with checkpointable position."""

    def __init__(self, cfg: DataConfig, start_step: int = 0,
                 prefetch: int = 2):
        self.cfg = cfg
        self._step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        step = self._step
        while not self._stop.is_set():
            batch = _batch_at(self.cfg, step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        step, batch = self._q.get()
        self._step = step + 1
        return batch

    @property
    def state(self) -> Dict[str, int]:
        """Checkpointable position (next step to consume)."""
        return {"step": self._step}

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)


def batch_for_step(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Random access (used by restarts and tests)."""
    return _batch_at(cfg, step)
