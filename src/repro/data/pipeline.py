"""Deterministic synthetic LM data pipeline.

Production posture: host-sharded (each process generates only its shard of
the global batch), deterministic in (seed, step) so restarts resume exactly,
with a background prefetch thread. Token streams are counter-hash-generated
(no dataset dependency) with a heavy-tailed, log-uniform-ish marginal so
losses behave like text.

The generator is a pure uint32 counter hash (lowbias32-style avalanche),
which gives it a property the old numpy-Philox path could not have: an
exact DEVICE-SIDE twin.  ``device_batch_at`` reproduces ``_batch_at``
bit-for-bit in jnp (wrap-around uint32 multiply/xor/shift semantics are
identical in numpy and XLA), and accepts a *traced* step scalar — this is
what lets the fused train window (train/trainer.py::make_train_window)
generate its batches inside ``lax.scan`` while the host-side per-step
oracle consumes the very same tokens from this pipeline (DESIGN.md §12).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

# lowbias32 avalanche constants (Hash Prospector) + fold/stream salts
_MIX_A = 0x7FEB352D
_MIX_B = 0x846CA68B
_GOLDEN = 0x9E3779B9
_SALT_SHIFT = 0x85EBCA6B


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


def _mix32(x, xp):
    """32-bit avalanche; exact under numpy AND jnp uint32 wrap semantics."""
    x = x ^ (x >> xp.uint32(16))
    x = x * xp.uint32(_MIX_A)
    x = x ^ (x >> xp.uint32(15))
    x = x * xp.uint32(_MIX_B)
    x = x ^ (x >> xp.uint32(16))
    return x


def _tokens_at(seed, step, host_id, host_batch: int, seq_len: int,
               vocab_size: int, xp):
    """(host_batch, seq_len + 1) int32 token grid for one (seed, step, host).

    Marginal: ``(h1 % vocab) >> (h2 & 15)`` — uniform within each octave,
    ~equal mass per octave, i.e. log-uniform over the vocab (Zipf exponent
    ~1).  ``step`` may be a traced jnp scalar (uint32 conversion is exact
    for any step < 2**31).  All arithmetic is wrap-around uint32, so the
    numpy and jnp instantiations agree bitwise.
    """
    n = host_batch * (seq_len + 1)
    # fold (seed, step, host) into a stream base; 1-element array on the
    # numpy path so integer wrap never trips scalar-overflow warnings
    base = xp.full((1,), _GOLDEN, dtype=xp.uint32)
    base = _mix32(base ^ xp.asarray(seed).astype(xp.uint32), xp)
    base = _mix32(base ^ xp.asarray(step).astype(xp.uint32), xp)
    base = _mix32(base ^ xp.asarray(host_id).astype(xp.uint32), xp)
    idx = xp.arange(n, dtype=xp.uint32)
    h1 = _mix32(idx ^ base, xp)
    h2 = _mix32(h1 ^ xp.uint32(_SALT_SHIFT), xp)
    tok = (h1 % xp.uint32(vocab_size)) >> (h2 & xp.uint32(15))
    return tok.astype(xp.int32).reshape(host_batch, seq_len + 1)


def _batch_at(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Deterministic batch for (seed, step, host). Heavy-tailed tokens."""
    tokens = _tokens_at(cfg.seed, step, cfg.host_id, cfg.host_batch,
                        cfg.seq_len, cfg.vocab_size, np)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


def device_batch_at(cfg: DataConfig, step) -> Dict:
    """Bitwise twin of ``_batch_at`` in jnp; ``step`` may be traced.

    This is the fused train window's batch source: inside one jitted
    ``lax.scan`` each step hashes its own batch on device, so the host
    never materializes or transfers training tokens between sync points.
    Parity with the host path is enforced in tests/test_train_engine.py.
    """
    import jax.numpy as jnp

    tokens = _tokens_at(cfg.seed, step, cfg.host_id, cfg.host_batch,
                        cfg.seq_len, cfg.vocab_size, jnp)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


class Pipeline:
    """Prefetching iterator with checkpointable position."""

    def __init__(self, cfg: DataConfig, start_step: int = 0,
                 prefetch: int = 2):
        self.cfg = cfg
        self._step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        step = self._step
        while not self._stop.is_set():
            batch = _batch_at(self.cfg, step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        step, batch = self._q.get()
        self._step = step + 1
        return batch

    @property
    def state(self) -> Dict[str, int]:
        """Checkpointable position (next step to consume)."""
        return {"step": self._step}

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)


def batch_for_step(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Random access (used by restarts and tests)."""
    return _batch_at(cfg, step)
