"""Roofline-term derivation from compiled dry-run artifacts.

Terms (per DESIGN.md §8 — cost_analysis on this JAX build reports PER-DEVICE
flops/bytes, verified empirically):

    compute_term    = flops_per_device / PEAK_FLOPS
    memory_term     = bytes_per_device / HBM_BW
    collective_term = link_bytes_per_device / ICI_BW

collective bytes are parsed from the optimized HLO text with ring-model
factors: all-gather / reduce-scatter x(n-1)/n, all-reduce x2(n-1)/n,
all-to-all x(n-1)/n, collective-permute x1, with n = replica-group size.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

# TPU v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12        # bf16 FLOP/s
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

# JAX dtype-name spellings of the HLO shorthands above
_DTYPE_ALIASES = {"bfloat16": "bf16", "float16": "f16", "float32": "f32",
                  "float64": "f64", "int8": "s8", "int32": "s32",
                  "float8_e4m3fn": "f8e4m3fn", "float8_e5m2": "f8e5m2"}


def dtype_bytes(dtype: str) -> int:
    """Bytes per element of an HLO or JAX dtype name.

    The single sizing convention for modeled byte surfaces — the HLO
    walker and the traffic engine's ``LayerStack`` lowering
    (``core.traffic``) both size tensors through it.
    """
    key = _DTYPE_ALIASES.get(dtype, dtype)
    if key not in _DTYPE_BYTES:
        raise KeyError(f"unknown dtype {dtype!r}")
    return _DTYPE_BYTES[key]


_COLL_RE = re.compile(
    r"(?P<outshape>[\w\[\],{}\s()]*?)"
    r"\b(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(txt: str) -> float:
    """Sum byte sizes of all 'dtype[a,b,c]' shapes in a fragment."""
    total = 0.0
    for m in _SHAPE_RE.finditer(txt):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    op_counts: Dict[str, int]
    op_bytes: Dict[str, float]        # ring-model per-device link bytes
    raw_bytes: Dict[str, float]       # payload bytes (no ring factor)

    @property
    def total_bytes(self) -> float:
        return sum(self.op_bytes.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = {}
    link_bytes: Dict[str, float] = {}
    raw: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _COLL_RE.search(line)
        if not m or "-done" in line.split("=")[0]:
            continue
        op = m.group("op")
        # replica group size n
        n = 1
        g = _GROUPS_RE.search(line)
        if g:
            n = int(g.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(line)
            if gl:
                first = gl.group(1).split("}")[0].split("{")[-1]
                n = max(1, len([x for x in first.split(",") if x.strip()]))
        # output shape: LHS of '='; for -start ops it's a tuple incl. inputs
        lhs = line.split("=", 1)[0]
        rhs_shapes = line.split("=", 1)[1] if "=" in line else ""
        out_bytes = _shape_bytes(lhs)
        if op == "all-reduce":
            payload = out_bytes
            factor = 2.0 * (n - 1) / max(n, 1)
        elif op == "all-gather":
            # LHS is the gathered (full) shape; ring moves (n-1)/n of it
            payload = out_bytes
            factor = (n - 1) / max(n, 1)
        elif op == "reduce-scatter":
            # LHS is the scattered shard; ring moves (n-1)*shard per device
            payload = out_bytes * n
            factor = (n - 1) / max(n, 1)
        elif op == "all-to-all":
            payload = out_bytes
            factor = (n - 1) / max(n, 1)
        else:  # collective-permute
            payload = out_bytes
            factor = 1.0
            if _SRC_TGT_RE.search(line):
                n = 2  # point-to-point
        counts[op] = counts.get(op, 0) + 1
        link_bytes[op] = link_bytes.get(op, 0.0) + payload * factor
        raw[op] = raw.get(op, 0.0) + payload
    return CollectiveStats(counts, link_bytes, raw)


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    collectives: Dict[str, float]
    collective_counts: Dict[str, int]
    temp_bytes: float
    arg_bytes: float
    xla_flops: float = 0.0   # raw cost_analysis (while bodies counted once)
    xla_bytes: float = 0.0
    bytes_by_scope: Dict[str, float] = None
    flops_by_scope: Dict[str, float] = None

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def model_flops_util(self, model_flops_per_device: float) -> float:
        """MODEL_FLOPS fraction of the roofline bound (MFU-like)."""
        if self.bound_s <= 0:
            return 0.0
        return model_flops_per_device / PEAK_FLOPS / self.bound_s

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes": self.collective_bytes,
            "collectives": self.collectives,
            "collective_counts": self.collective_counts,
            "temp_bytes": self.temp_bytes,
            "arg_bytes": self.arg_bytes,
            "xla_flops": self.xla_flops,
            "xla_bytes": self.xla_bytes,
            "bytes_by_scope": self.bytes_by_scope,
            "flops_by_scope": self.flops_by_scope,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
        }


def analyze(compiled) -> Roofline:
    """Derive roofline terms from a compiled executable.

    FLOPs / HBM bytes / collective link bytes come from the while-aware HLO
    walker (repro.launch.hlo_analysis) because XLA's HloCostAnalysis counts
    while bodies once instead of x trip_count. The raw cost_analysis values
    are kept as reference fields.
    """
    from repro.launch.hlo_analysis import analyze_hlo

    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    xla_flops = float(ca.get("flops", 0.0))
    xla_bytes = float(ca.get("bytes accessed", 0.0))
    stats = analyze_hlo(compiled.as_text())
    temp = arg = 0.0
    try:
        ma = compiled.memory_analysis()
        temp = float(getattr(ma, "temp_size_in_bytes", 0.0))
        arg = float(getattr(ma, "argument_size_in_bytes", 0.0))
    except Exception:
        pass
    return Roofline(
        flops_per_device=stats.flops,
        bytes_per_device=stats.bytes,
        collective_bytes=stats.collective_link_bytes,
        collectives=stats.collective_bytes_by_op,
        collective_counts=stats.collective_counts,
        temp_bytes=temp,
        arg_bytes=arg,
        xla_flops=xla_flops,
        xla_bytes=xla_bytes,
        bytes_by_scope=stats.bytes_by_scope,
        flops_by_scope=stats.flops_by_scope,
    )


def model_flops(cfg, shape, chips: int) -> float:
    """Per-device MODEL_FLOPS: 6·N·D train, 2·N·tokens serve (N = active)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        total = 6.0 * n_active * shape.seq_len * shape.global_batch
    elif shape.kind == "prefill":
        total = 2.0 * n_active * shape.seq_len * shape.global_batch
    else:  # decode: one token per sequence
        total = 2.0 * n_active * shape.global_batch
    return total / chips
