"""Production training launcher.

Builds the largest viable mesh from the available devices (elastic ladder),
derives shardings from the rule engine, restores the latest checkpoint
(resharding onto the current mesh if the fleet changed), and trains with
async checkpointing + straggler monitoring.

Two execution paths share one state layout:
  * fused (default): ``train.trainer.make_train_window`` scans
    ``--steps-per-sync`` (K) full train steps inside one jitted,
    state-donating program, hashing every batch on device — the host only
    drains stacked metrics at window boundaries, where it also checkpoints
    (``CheckpointManager`` at window boundaries, so elastic restore still
    resumes exactly) and prints the window's train-mode NVM verdicts
    (``crosslayer.analyze_train``) at the end;
  * ``--no-fused``: the seed per-step loop (host pipeline batches, one
    dispatch per step) — the parity oracle the fused path is tested
    against.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b \
        --steps 100 --reduced          # CPU-sized
On a real TPU fleet drop --reduced; the same code paths run the full
config on the production mesh.  In fused mode the launcher runs whole
windows, so the final step rounds UP to the next multiple of K.
"""
import argparse
import time

import jax
import numpy as np

from repro.launch.mesh import mesh_context
from repro.configs import get_config, reduced as reduce_cfg
from repro.data import DataConfig, Pipeline
from repro.models import build_model
from repro.optim import AdamW, warmup_cosine
from repro.sharding import activation_sharding, default_rules, tree_shardings
from repro.train.checkpoint import CheckpointManager
from repro.train.elastic import StragglerMonitor, choose_mesh, remesh
from repro.train.trainer import (effective_optimizer, init_state,
                                 make_train_step, make_train_window,
                                 state_axes, window_boundary_crossed)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--strategy", default="tp", choices=["tp", "fsdp"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--fused", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="fused K-step train windows (--no-fused for the "
                         "seed per-step oracle loop)")
    ap.add_argument("--steps-per-sync", type=int, default=10,
                    help="fused train steps per host sync (K)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true",
                    help="error-feedback int8 gradient compression "
                         "(optim/compress.py) in the train step")
    ap.add_argument("--compress-shards", type=int, default=1,
                    help="data-parallel shard groups combined through "
                         "compressed_psum (requires --compress-grads)")
    ap.add_argument("--verdicts", action=argparse.BooleanOptionalAction,
                    default=True, help="print train-mode NVM verdicts "
                                       "(fused mode only)")
    args = ap.parse_args()

    n = jax.device_count()
    mesh = remesh(n)
    choice = choose_mesh(n)
    print(f"devices={n} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}"
          f" (model_parallelism={choice.model_parallelism})")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg, num_layers=4, d_model=128, d_ff=256)
    model = build_model(cfg, max_seq=args.seq)
    opt = AdamW(lr=warmup_cosine(args.lr, 10, args.steps))
    opt_eff = effective_optimizer(opt, args.compress_grads,
                                  args.compress_shards)
    rules = default_rules(fsdp=cfg.fsdp, multi_pod=(len(mesh.shape) == 3),
                          strategy=args.strategy)
    dcfg = DataConfig(cfg.vocab_size, args.seq, args.batch,
                      num_hosts=jax.process_count(),
                      host_id=jax.process_index())

    with mesh_context(mesh), activation_sharding(mesh, rules):
        state = init_state(model, opt_eff, jax.random.PRNGKey(0))
        st_sh = tree_shardings(state_axes(model, opt_eff), state, mesh,
                               rules)
        state = jax.tree.map(jax.device_put, state, st_sh)

        mgr = CheckpointManager(args.ckpt_dir, keep=2)
        start = 0
        if mgr.latest_step() is not None:
            # elastic restart: reshards onto whatever mesh we built above
            state = mgr.restore(state, shardings=st_sh)
            start = int(mgr.latest_step())
            print(f"restored step {start} (resharded onto current mesh)")

        mon = StragglerMonitor(num_hosts=jax.process_count())
        if args.fused:
            win = _run_fused(args, model, opt, dcfg, st_sh, state, mgr, mon,
                             start)
            if args.verdicts and win is not None:
                for v in win.nvm_verdicts():
                    print(f"  {v.shape}: energy vs SRAM "
                          f"STT {v.energy_ratio['STT']:.3f} / "
                          f"SOT {v.energy_ratio['SOT']:.3f}   EDP "
                          f"STT {v.edp_ratio['STT']:.3f} / "
                          f"SOT {v.edp_ratio['SOT']:.3f}")
        else:
            _run_per_step(args, model, opt, dcfg, st_sh, state, mgr, mon,
                          start)


def _run_fused(args, model, opt, dcfg, st_sh, state, mgr, mon, start):
    """Window loop: K fused steps per host sync; checkpoint + straggler
    accounting at window boundaries.  Returns the window (for verdicts),
    or None if the restored step already covers ``--steps``."""
    K = args.steps_per_sync
    if start >= args.steps:
        print(f"restored step {start} >= --steps {args.steps}; nothing to "
              f"do (checkpoints {mgr.all_steps()})")
        return None
    win = make_train_window(
        model, opt, steps_per_sync=K, microbatches=args.microbatches,
        compress_grads=args.compress_grads,
        compress_shards=args.compress_shards, data_cfg=dcfg,
        state_shardings=st_sh)
    last_loss = None
    t0 = time.time()
    step = start
    while step < args.steps:
        state, metrics = win(state)
        # drain: ONE host transfer of the stacked (K,) metrics; blocking
        # here also makes the recorded time device time, not dispatch time
        losses = np.asarray(metrics["loss"])
        step += K
        mon.record(jax.process_index(), (time.time() - t0) / K)
        t0 = time.time()
        flagged = mon.stragglers()
        if flagged:
            print(f"straggler(s) {flagged}: would trigger evict+remesh "
                  f"(see train/elastic.py)")
        if window_boundary_crossed(step, K, args.ckpt_every) \
                or step >= args.steps:
            mgr.save(step, state, blocking=(step >= args.steps))
        last_loss = float(losses[-1])
        print(f"step {step:4d} loss {last_loss:.4f} "
              f"(window mean {float(losses.mean()):.4f})")
    print(f"done @{step}: loss {last_loss:.4f}; "
          f"checkpoints {mgr.all_steps()}")
    return win


def _run_per_step(args, model, opt, dcfg, st_sh, state, mgr, mon, start):
    """The seed per-step oracle loop (host pipeline, one dispatch/step)."""
    step_fn = jax.jit(
        make_train_step(model, opt, microbatches=args.microbatches,
                        compress_grads=args.compress_grads,
                        compress_shards=args.compress_shards),
        in_shardings=(st_sh, None), out_shardings=(st_sh, None),
        donate_argnums=(0,))
    data = Pipeline(dcfg, start_step=start)
    t0 = time.time()
    metrics = {}
    for i, batch in zip(range(start, args.steps), data):
        state, metrics = step_fn(state, jax.tree.map(np.asarray, batch))
        # block before timing: otherwise we record async dispatch time,
        # not device step time, and the straggler monitor sees noise
        jax.block_until_ready(metrics)
        mon.record(jax.process_index(), time.time() - t0)
        t0 = time.time()
        flagged = mon.stragglers()   # mutates strikes: call ONCE per step
        if flagged:
            print(f"straggler(s) {flagged}: would trigger evict+remesh "
                  f"(see train/elastic.py)")
        if (i + 1) % args.ckpt_every == 0:
            mgr.save(i + 1, state)
        if (i + 1) % 10 == 0:
            print(f"step {i+1:4d} loss {float(metrics['loss']):.4f}")
    mgr.save(max(args.steps, start), state, blocking=True)
    data.close()
    # restoring at/after the final step leaves the loop body unentered and
    # metrics empty — the seed's closing float(metrics['loss']) raised
    tail = (f"loss {float(metrics['loss']):.4f}; " if metrics else
            f"restored step {start} >= --steps {args.steps}, no steps run; ")
    print(f"done @{max(args.steps, start)}: {tail}"
          f"checkpoints {mgr.all_steps()}")


if __name__ == "__main__":
    main()
