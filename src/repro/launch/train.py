"""Production training launcher.

Builds the largest viable mesh from the available devices (elastic ladder),
derives shardings from the rule engine, restores the latest checkpoint
(resharding onto the current mesh if the fleet changed), and runs the
jitted train step with async checkpointing + straggler monitoring.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b \
        --steps 100 --reduced          # CPU-sized
On a real TPU fleet drop --reduced; the same code paths run the full
config on the production mesh.
"""
import argparse
import time

import jax
import numpy as np

from repro.launch.mesh import mesh_context
from repro.configs import get_config, reduced as reduce_cfg
from repro.data import DataConfig, Pipeline
from repro.models import build_model
from repro.optim import AdamW, warmup_cosine
from repro.sharding import activation_sharding, default_rules, tree_shardings
from repro.train.checkpoint import CheckpointManager
from repro.train.elastic import StragglerMonitor, choose_mesh, remesh
from repro.train.trainer import init_state, make_train_step, state_axes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--strategy", default="tp", choices=["tp", "fsdp"])
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()

    n = jax.device_count()
    mesh = remesh(n)
    choice = choose_mesh(n)
    print(f"devices={n} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}"
          f" (model_parallelism={choice.model_parallelism})")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg, num_layers=4, d_model=128, d_ff=256)
    model = build_model(cfg, max_seq=args.seq)
    opt = AdamW(lr=warmup_cosine(args.lr, 10, args.steps))
    rules = default_rules(fsdp=cfg.fsdp, multi_pod=(len(mesh.shape) == 3),
                          strategy=args.strategy)

    with mesh_context(mesh), activation_sharding(mesh, rules):
        state = init_state(model, opt, jax.random.PRNGKey(0))
        st_sh = tree_shardings(state_axes(model, opt), state, mesh, rules)
        state = jax.tree.map(jax.device_put, state, st_sh)
        step_fn = jax.jit(make_train_step(model, opt),
                          in_shardings=(st_sh, None),
                          out_shardings=(st_sh, None),
                          donate_argnums=(0,))

        mgr = CheckpointManager(args.ckpt_dir, keep=2)
        start = 0
        if mgr.latest_step() is not None:
            # elastic restart: reshards onto whatever mesh we built above
            state = mgr.restore(state, shardings=st_sh)
            start = int(mgr.latest_step())
            print(f"restored step {start} (resharded onto current mesh)")

        data = Pipeline(DataConfig(cfg.vocab_size, args.seq, args.batch),
                        start_step=start)
        mon = StragglerMonitor(num_hosts=jax.process_count())
        t0 = time.time()
        metrics = {}
        for i, batch in zip(range(start, args.steps), data):
            state, metrics = step_fn(state, jax.tree.map(np.asarray, batch))
            mon.record(jax.process_index(), time.time() - t0)
            t0 = time.time()
            if mon.stragglers():
                print(f"straggler(s) {mon.stragglers()}: would trigger "
                      f"evict+remesh (see train/elastic.py)")
            if (i + 1) % args.ckpt_every == 0:
                mgr.save(i + 1, state)
            if (i + 1) % 10 == 0:
                print(f"step {i+1:4d} loss {float(metrics['loss']):.4f}")
        mgr.save(args.steps, state, blocking=True)
        data.close()
        print(f"done @{args.steps}: loss {float(metrics['loss']):.4f}; "
              f"checkpoints {mgr.all_steps()}")


if __name__ == "__main__":
    main()
