"""While-aware analyzer for optimized HLO text.

XLA's HloCostAnalysis counts a ``while`` body ONCE, not x trip_count, so
``compiled.cost_analysis()`` grossly under-reports FLOPs/bytes for scanned
layer stacks (verified empirically: llama3-8b train reported 8.8x fewer
FLOPs than 6*N*D). This module re-derives:

  * FLOPs        — from ``dot`` ops (2 * prod(out_dims) * prod(contract_dims))
  * HBM traffic  — per-instruction operand+output bytes with special handling
                   for dynamic-slice / dynamic-update-slice / fusions (models
                   perfect elementwise fusion: only instruction-surface bytes
                   touch HBM)
  * collective link bytes — ring-model factors per op with replica-group size

Each computation's totals are multiplied by the product of enclosing while
trip counts (parsed from ``backend_config={"known_trip_count":...}``),
walking the call graph from ENTRY through while bodies and calls.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(?P<shape>.*?)\s"
    r"(?P<op>[a-z][a-z0-9\-]*)\(")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_GROUPS_ARR_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_SKIP_OPS = frozenset({
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "after-all", "partition-id", "replica-id",
    "opt-barrier", "call",
})


def _shapes(txt: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(txt):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _nbytes(shapes: List[Tuple[str, List[int]]]) -> float:
    total = 0.0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    shape_txt: str
    operands: List[str]
    attrs: str

    def out_shapes(self):
        return _shapes(self.shape_txt)

    def out_bytes(self) -> float:
        return _nbytes(self.out_shapes())


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: List[Instr]
    by_name: Dict[str, Instr]
    params: Dict[str, str]  # param name -> shape text (from signature)


def _split_operands_attrs(line: str, op_start: int) -> Tuple[str, str]:
    """Given index of the op's '(' return (operand_text, attr_text)."""
    depth = 0
    i = op_start
    while i < len(line):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                return line[op_start + 1:i], line[i + 1:]
        i += 1
    return line[op_start + 1:], ""


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" "):
            hdr = _COMP_HDR_RE.match(line)
            if hdr:
                name = hdr.group(2)
                params: Dict[str, str] = {}
                sig = line.split("->")[0]
                for pm in re.finditer(r"([\w.\-]+):\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[\d,]*\})?))", sig):
                    params[pm.group(1)] = pm.group(2)
                cur = Computation(name, bool(hdr.group(1)), [], {}, params)
                comps[name] = cur
                if hdr.group(1):
                    entry = name
            elif line.startswith("}"):
                cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape_txt, op = m.group(1), m.group("shape"), m.group("op")
        op_paren = m.end() - 1
        operand_txt, attrs = _split_operands_attrs(line, op_paren)
        operands = _OPERAND_RE.findall(operand_txt)
        instr = Instr(name, op, shape_txt, operands, attrs)
        cur.instrs.append(instr)
        cur.by_name[name] = instr
    return comps, entry


def _operand_bytes(comp: Computation, ref: str) -> float:
    if ref in comp.by_name:
        return comp.by_name[ref].out_bytes()
    if ref in comp.params:
        return _nbytes(_shapes(comp.params[ref]))
    return 0.0


def _operand_shape(comp: Computation, ref: str):
    if ref in comp.by_name:
        return comp.by_name[ref].out_shapes()
    if ref in comp.params:
        return _shapes(comp.params[ref])
    return []


def _group_size(attrs: str) -> int:
    g = _GROUPS_ARR_RE.search(attrs)
    if g:
        return int(g.group(2))
    gl = _GROUPS_LIST_RE.search(attrs)
    if gl:
        return max(1, len([x for x in gl.group(1).split(",") if x.strip()]))
    return 1


def _dot_flops(comp: Computation, ins: Instr) -> float:
    out = ins.out_shapes()
    out_elems = 1.0
    for _, dims in out:
        for d in dims:
            out_elems *= d
    k = 1.0
    cd = _LHS_CDIMS_RE.search(ins.attrs)
    if cd and ins.operands:
        lhs = _operand_shape(comp, ins.operands[0])
        if lhs:
            _, dims = lhs[0]
            for idx in (int(x) for x in cd.group(1).split(",") if x):
                if idx < len(dims):
                    k *= dims[idx]
    return 2.0 * out_elems * k


def _fusion_traffic(comps: Dict[str, Computation], comp: Computation,
                    ins: Instr) -> float:
    """Surface HBM traffic of a fusion: params (dynamic-slice aware) + out."""
    cm = _CALLS_RE.search(ins.attrs)
    fused = comps.get(cm.group(1)) if cm else None
    total = 0.0
    if fused is None:
        total = sum(_operand_bytes(comp, o) for o in set(ins.operands))
        return total + ins.out_bytes()
    # map fusion operand i -> fused parameter instruction
    param_instrs = [i for i in fused.instrs if i.op == "parameter"]
    # order of parameters follows parameter(N) index == operand order
    for idx, op_ref in enumerate(ins.operands):
        full = _operand_bytes(comp, op_ref)
        pi = param_instrs[idx] if idx < len(param_instrs) else None
        if pi is not None:
            consumers = [i for i in fused.instrs if pi.name in i.operands]
            if consumers and all(c.op == "dynamic-slice" for c in consumers):
                full = sum(c.out_bytes() for c in consumers)
        total += full
    root = fused.instrs[-1] if fused.instrs else None
    if root is not None and root.op == "dynamic-update-slice":
        upd = (_operand_shape(fused, root.operands[1])
               if len(root.operands) > 1 else [])
        total += 2.0 * _nbytes(upd)
    else:
        total += ins.out_bytes()
    return total


_META_RE = re.compile(r'op_name="([^"]*)"')
# named scopes we attribute bytes/flops to (kernelization candidates)
SCOPES = ("flash_attention", "ssd_scan", "rglru_scan", "moe_dispatch")


def _scope_of(attrs: str) -> Optional[str]:
    m = _META_RE.search(attrs)
    if not m:
        return None
    name = m.group(1)
    for s in SCOPES:
        if s in name:
            return s
    return None


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    bytes: float = 0.0
    collective_link_bytes: float = 0.0
    collective_bytes_by_op: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    collective_counts: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    dot_count: int = 0
    unknown_trip_whiles: int = 0
    bytes_by_scope: Dict[str, float] = dataclasses.field(default_factory=dict)
    flops_by_scope: Dict[str, float] = dataclasses.field(default_factory=dict)

    def _add_scope(self, attrs: str, nbytes: float, nflops: float = 0.0):
        s = _scope_of(attrs)
        if s:
            self.bytes_by_scope[s] = self.bytes_by_scope.get(s, 0.0) + nbytes
            if nflops:
                self.flops_by_scope[s] = (self.flops_by_scope.get(s, 0.0)
                                          + nflops)


def analyze_hlo(text: str) -> HloStats:
    comps, entry = parse_hlo(text)
    stats = HloStats()
    if entry is None:
        return stats
    # accumulate multipliers over the while/call graph
    mult: Dict[str, float] = {}
    stack = [(entry, 1.0)]
    while stack:
        name, m = stack.pop()
        if name not in comps:
            continue
        mult[name] = mult.get(name, 0.0) + m
        comp = comps[name]
        for ins in comp.instrs:
            if ins.op == "while":
                trip = 1.0
                tm = _TRIP_RE.search(ins.attrs)
                if tm:
                    trip = float(tm.group(1))
                else:
                    stats.unknown_trip_whiles += 1
                bm = _BODY_RE.search(ins.attrs)
                if bm:
                    stack.append((bm.group(1), m * trip))
            elif ins.op == "call":
                cm = _CALLS_RE.search(ins.attrs) or (
                    _OPERAND_RE.search(ins.attrs))
                tgt = _CALLS_RE.search(ins.attrs)
                if tgt:
                    stack.append((tgt.group(1), m))
            elif ins.op == "conditional":
                for br in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                                      r"(?:true|false)_computation=%([\w.\-]+))",
                                      ins.attrs):
                    for g in br.groups():
                        if g:
                            for t in _OPERAND_RE.findall(g) or [g]:
                                stack.append((t, m))

    for name, m in mult.items():
        comp = comps[name]
        for ins in comp.instrs:
            op = ins.op
            if op == "dot":
                fl = m * _dot_flops(comp, ins)
                by = m * (
                    sum(_operand_bytes(comp, o) for o in set(ins.operands))
                    + ins.out_bytes())
                stats.flops += fl
                stats.dot_count += 1
                stats.bytes += by
                stats._add_scope(ins.attrs, by, fl)
                continue
            coll = [c for c in COLLECTIVES if op in (c, c + "-start")]
            if coll:
                base = coll[0]
                n = _group_size(ins.attrs)
                out_b = ins.out_bytes()
                if base == "all-reduce":
                    payload, factor = out_b, 2.0 * (n - 1) / max(n, 1)
                elif base == "all-gather":
                    payload, factor = out_b, (n - 1) / max(n, 1)
                elif base == "reduce-scatter":
                    payload, factor = out_b * n, (n - 1) / max(n, 1)
                elif base == "all-to-all":
                    payload, factor = out_b, (n - 1) / max(n, 1)
                else:  # collective-permute
                    payload, factor = out_b, 1.0
                link = m * payload * factor
                stats.collective_link_bytes += link
                stats.collective_bytes_by_op[base] = (
                    stats.collective_bytes_by_op.get(base, 0.0) + link)
                stats.collective_counts[base] = (
                    stats.collective_counts.get(base, 0) + int(m))
                stats.bytes += m * 2.0 * out_b
                continue
            if op.endswith("-done") or op in _SKIP_OPS:
                continue
            if op == "fusion":
                by = m * _fusion_traffic(comps, comp, ins)
            elif op == "dynamic-slice":
                by = m * 2.0 * ins.out_bytes()
            elif op == "dynamic-update-slice":
                upd = (_operand_shape(comp, ins.operands[1])
                       if len(ins.operands) > 1 else [])
                by = m * 2.0 * _nbytes(upd)
            else:
                by = m * (
                    sum(_operand_bytes(comp, o) for o in set(ins.operands))
                    + ins.out_bytes())
            stats.bytes += by
            stats._add_scope(ins.attrs, by)
    return stats
