"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state). The single-pod mesh is 16x16 = 256 chips ("data","model");
the multi-pod mesh is 2x16x16 = 512 chips ("pod","data","model").
``make_mesh_for`` generalizes to arbitrary device counts for elastic
re-meshing (see train/elastic.py).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_for(num_devices: int, *, model_parallelism: int = 16,
                  pods: int = 1):
    """Largest (pod, data, model) mesh that fits ``num_devices`` devices."""
    import jax

    model = model_parallelism
    while model > 1 and num_devices % model:
        model //= 2
    data = num_devices // (model * pods)
    if data < 1:
        raise ValueError(
            f"cannot build mesh: {num_devices} devices, model={model}, "
            f"pods={pods}")
    if pods > 1:
        return jax.make_mesh((pods, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def mesh_context(mesh):
    """``jax.set_mesh(mesh)`` where available (jax >= 0.5), else the Mesh's
    own context manager — the launchers' single mesh-scoping entry point so
    they run on every jax this repo supports."""
    import jax

    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh
