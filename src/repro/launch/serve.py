"""Production serving launcher: mesh + sharded params + batched engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced
"""
import argparse

import jax

from repro.configs import get_config, reduced as reduce_cfg
from repro.models import build_model
from repro.serve import Engine, Request
from repro.sharding import default_rules, tree_shardings
from repro.train.elastic import remesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args()

    mesh = remesh(jax.device_count())
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = build_model(cfg, max_seq=args.max_len)
    rules = default_rules(fsdp=False)  # serving: params over model axis only

    with jax.set_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        p_sh = tree_shardings(model.param_axes(), params, mesh, rules)
        params = jax.tree.map(jax.device_put, params, p_sh)
        eng = Engine(model, params, slots=args.slots, max_len=args.max_len)
        for i in range(args.requests):
            eng.submit(Request(uid=i, prompt=[1 + i, 2 + i],
                               max_new_tokens=6))
        eng.run()
    print(f"served {args.requests} requests on "
          f"{dict(zip(mesh.axis_names, mesh.devices.shape))}")


if __name__ == "__main__":
    main()
