"""Production serving launcher: mesh + sharded params + fused engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b \
        --no-reduced --ticks-per-sync 16 --temperature 0.7

``--reduced`` defaults on (CPU-runnable smoke config) and — unlike the
seed's ``action="store_true", default=True``, which could never be turned
off — is disabled with ``--no-reduced`` for full-size configs.  After the
run the launcher prints the engine's serve-mode NVM verdicts: SRAM vs
STT/SOT-MRAM energy/EDP on the measured decode-tick and prefill traffic.
"""
import argparse
import time

import jax

from repro.configs import get_config, reduced as reduce_cfg
from repro.launch.mesh import mesh_context
from repro.models import build_model
from repro.serve import Engine, mixed_requests, run_staggered, \
    staggered_groups
from repro.sharding import default_rules, tree_shardings
from repro.train.elastic import remesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="smoke-sized config (--no-reduced for full size)")
    ap.add_argument("--ticks-per-sync", type=int, default=8,
                    help="fused decode ticks per host drain (K)")
    ap.add_argument("--attn-impl", choices=("xla", "pallas_decode"),
                    default="xla",
                    help="decode-tick attention: jnp full-cache path (the "
                         "parity oracle) or the Pallas blocked kernel with "
                         "fused KV scatter (interpret mode on CPU)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature for every 2nd request "
                         "(0 = all greedy)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verdicts", action=argparse.BooleanOptionalAction,
                    default=True, help="print serve-mode NVM verdicts")
    args = ap.parse_args()

    mesh = remesh(jax.device_count())
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = build_model(cfg, max_seq=args.max_len)
    rules = default_rules(fsdp=False)  # serving: params over model axis only

    with mesh_context(mesh):
        params = model.init(jax.random.PRNGKey(0))
        p_sh = tree_shardings(model.param_axes(), params, mesh, rules)
        params = jax.tree.map(jax.device_put, params, p_sh)
        eng = Engine(model, params, slots=args.slots, max_len=args.max_len,
                     seed=args.seed, ticks_per_sync=args.ticks_per_sync,
                     record_traffic=args.verdicts,
                     attn_impl=args.attn_impl)
        reqs = mixed_requests(
            args.requests, seed=args.seed, vocab=cfg.vocab_size,
            prompt_lens=(2, max(2, args.max_len // 4)),
            max_new=(2, max(2, args.max_len // 8)),
            temperature=args.temperature,
            temperature_every=2 if args.temperature > 0 else 0)
        t0 = time.time()
        outputs = run_staggered(eng, staggered_groups(reqs, args.slots))
        dt = time.time() - t0
    ntok = sum(len(o) for o in outputs.values())
    print(f"served {args.requests} requests / {ntok} tokens in "
          f"{eng.ticks} ticks (K={args.ticks_per_sync}, "
          f"attn={args.attn_impl}) = {ntok / dt:.0f} tok/s on "
          f"{dict(zip(mesh.axis_names, mesh.devices.shape))}")
    if args.verdicts:
        for v in eng.nvm_verdicts():
            print(f"  {v.shape}: energy vs SRAM "
                  f"STT {v.energy_ratio['STT']:.3f} / "
                  f"SOT {v.energy_ratio['SOT']:.3f}   EDP "
                  f"STT {v.edp_ratio['STT']:.3f} / "
                  f"SOT {v.edp_ratio['SOT']:.3f}")


if __name__ == "__main__":
    main()
