"""Production serving launcher: mesh + sharded params + fused engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b \
        --no-reduced --ticks-per-sync 16 --temperature 0.7
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b \
        --arrival-rate 0.5 --burst-amp 0.6 --trace-out /tmp/serve.json

``--reduced`` defaults on (CPU-runnable smoke config) and — unlike the
seed's ``action="store_true", default=True``, which could never be turned
off — is disabled with ``--no-reduced`` for full-size configs.

``--arrival-rate > 0`` switches from fixed staggered groups to the real
traffic generator (DESIGN.md §14): Poisson arrivals in the tick domain
(optionally burst-modulated via ``--burst-amp``/``--burst-period``),
lognormal heavy-tailed prompt/output lengths, admission by arrival time.
After an arrival-driven run the launcher prints TTFT/TPOT/end-to-end
p50/p95/p99 (tick-domain and wall-clock) and FAILS if the percentiles
are empty or any request went unserved — the CI smoke leans on that.
``--trace-out PATH`` attaches a telemetry tracer and writes a
chrome://tracing JSON of the engine's prefill calls, decode windows, and
host drains.  After every run the launcher prints the engine's
serve-mode NVM verdicts: SRAM vs STT/SOT-MRAM energy/EDP on the measured
decode-tick and prefill traffic — family-tagged shapes (DESIGN.md §17),
with ssm/hybrid recurrent-bank traffic scored under its write-heavier
read split.  ``--list-configs`` prints every registry arch with its
family and which engines (dense/paged) can serve it, then exits.

Resilience plumbing (DESIGN.md §16): ``--deadline-ticks`` gives every
arrival-driven request an absolute deadline and ``--max-queue-depth``
caps the admission queue (excess submissions shed).  Every run prints a
terminal-state histogram next to the paged-stats line, and ``--strict``
(default on) exits non-zero if any request ended FAILED or never
reached a terminal state — the CI smokes lean on that exit code.
"""
import argparse
import collections
import time

import jax

from repro.configs import get_config, reduced as reduce_cfg
from repro.launch.mesh import mesh_context
from repro.models import build_model
from repro.serve import (FAILED, Engine, PagedEngine, ShedPolicy, Tracer,
                         latency_summary, mixed_requests, poisson_requests,
                         run_arrivals, run_staggered,
                         shared_prefix_requests, staggered_groups)
from repro.sharding import default_rules, tree_shardings
from repro.train.elastic import remesh


def _print_latency(summary: dict) -> None:
    print(f"latency over {summary['completed']}/{summary['n']} requests "
          f"({summary['tokens']} tokens):")
    for domain, unit, scale in (("ticks", "t", 1.0), ("wall", "ms", 1e3)):
        for metric, stats in sorted(summary[domain].items()):
            line = " ".join(f"{k} {v * scale:.2f}{unit}"
                            for k, v in stats.items() if k != "max")
            print(f"  {domain:5s} {metric:7s} {line}")


def _terminal_report(eng, reqs, strict: bool) -> None:
    """Terminal-state histogram + strict-mode exit code: FAILED or
    non-terminal requests are a launcher failure, shed/timed-out are
    legitimate admission-control outcomes (reported, not fatal)."""
    hist = collections.Counter(r.state for r in reqs)
    rs = eng.resilience_stats()
    extras = {k: v for k, v in rs.items()
              if v and k not in ("shed", "timed_out", "failed")}
    print(f"terminal states: "
          + " ".join(f"{k}={v}" for k, v in sorted(hist.items()))
          + (f"  resilience: {extras}" if extras else ""))
    stuck = [r.uid for r in reqs if not r.terminal]
    failed = [r.uid for r in reqs if r.state == FAILED]
    if strict and (stuck or failed):
        raise SystemExit(
            f"strict mode: {len(stuck)} non-terminal {stuck[:8]} / "
            f"{len(failed)} FAILED {failed[:8]} requests "
            f"(states: {dict(hist)})")


def _list_configs() -> None:
    """Registry listing with per-engine serve capability (serve_modes):
    which engines — Engine/EngineReference ("dense") and/or PagedEngine
    ("paged") — accept each config."""
    from repro.configs import all_configs
    from repro.models.api import _FAMILY_SERVE_MODES
    print(f"{'arch':<22} {'family':<8} engines")
    for arch, cfg in all_configs().items():
        modes = _FAMILY_SERVE_MODES[cfg.family]
        engines = ["Engine", "EngineReference"] if "dense" in modes else []
        if "paged" in modes:
            engines.append("PagedEngine")
        print(f"{arch:<22} {cfg.family:<8} {', '.join(engines)}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--list-configs", action="store_true",
                    help="print every registry config with its family and "
                         "the serve engines that accept it, then exit")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="smoke-sized config (--no-reduced for full size)")
    ap.add_argument("--ticks-per-sync", type=int, default=8,
                    help="fused decode ticks per host drain (K)")
    ap.add_argument("--attn-impl",
                    choices=("xla", "pallas_decode", "paged",
                             "pallas_paged"),
                    default="xla",
                    help="decode-tick attention: jnp full-cache path (the "
                         "parity oracle), the Pallas blocked kernel with "
                         "fused KV scatter, the paged-KV jnp gather path, "
                         "or the Pallas paged kernel with scalar-prefetch "
                         "page tables (interpret mode on CPU); 'paged'/"
                         "'pallas_paged' run the PagedEngine with "
                         "radix-tree prefix sharing (DESIGN.md §15)")
    ap.add_argument("--page-size", type=int, default=8,
                    help="tokens per KV page (paged engine only)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="physical page-pool size (paged engine only; "
                         "default slots * max_len / page_size)")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="serve the shared-prefix template workload and "
                         "FAIL unless the paged engine actually shares "
                         "prefix pages (zero prefix hits = regression)")
    ap.add_argument("--sample-impl", choices=("xla", "pallas"),
                    default="xla",
                    help="token sampling: two-step XLA path or the fused "
                         "one-launch Pallas kernel")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature for every 2nd request "
                         "(0 = all greedy)")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="mean Poisson arrivals per decode tick; > 0 "
                         "switches to arrival-driven traffic with "
                         "heavy-tailed lengths and SLO latency output")
    ap.add_argument("--burst-amp", type=float, default=0.0,
                    help="sinusoidal burst modulation amplitude in [0, 1] "
                         "for the arrival rate")
    ap.add_argument("--burst-period", type=float, default=64.0,
                    help="burst modulation period in ticks")
    ap.add_argument("--trace-out", default=None,
                    help="write a chrome://tracing JSON of engine windows "
                         "(prefill / decode / host drain) to this path")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verdicts", action=argparse.BooleanOptionalAction,
                    default=True, help="print serve-mode NVM verdicts")
    ap.add_argument("--deadline-ticks", type=float, default=None,
                    help="per-request deadline in ticks past arrival "
                         "(arrival-driven runs only); overdue work is "
                         "shed or timed out instead of served late")
    ap.add_argument("--max-queue-depth", type=int, default=None,
                    help="admission queue cap: submissions beyond it are "
                         "shed (backpressure instead of unbounded queue)")
    ap.add_argument("--strict", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="exit non-zero if any request ends FAILED or "
                         "non-terminal (--no-strict to just report)")
    args = ap.parse_args()
    if args.list_configs:
        _list_configs()
        return

    mesh = remesh(jax.device_count())
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = build_model(cfg, max_seq=args.max_len)
    rules = default_rules(fsdp=False)  # serving: params over model axis only

    tracer = Tracer(name=f"serve-{args.arch}") if args.trace_out else None
    with mesh_context(mesh):
        params = model.init(jax.random.PRNGKey(0))
        p_sh = tree_shardings(model.param_axes(), params, mesh, rules)
        params = jax.tree.map(jax.device_put, params, p_sh)
        paged = args.attn_impl in ("paged", "pallas_paged")
        policy = ShedPolicy(max_queue_depth=args.max_queue_depth)
        if paged:
            eng = PagedEngine(
                model, params, slots=args.slots, max_len=args.max_len,
                page_size=args.page_size, num_pages=args.num_pages,
                seed=args.seed, ticks_per_sync=args.ticks_per_sync,
                record_traffic=args.verdicts, sample_impl=args.sample_impl,
                attn_impl=("pallas_paged" if args.attn_impl == "pallas_paged"
                           else "xla"), tracer=tracer, shed_policy=policy)
        elif args.shared_prefix:
            raise SystemExit("--shared-prefix requires a paged engine "
                             "(--attn-impl paged or pallas_paged)")
        else:
            eng = Engine(model, params, slots=args.slots,
                         max_len=args.max_len, seed=args.seed,
                         ticks_per_sync=args.ticks_per_sync,
                         record_traffic=args.verdicts,
                         sample_impl=args.sample_impl,
                         attn_impl=args.attn_impl, tracer=tracer,
                         shed_policy=policy)
        temp_every = 2 if args.temperature > 0 else 0
        t0 = time.time()
        if args.shared_prefix:
            # template length deliberately off the page grid so boundary
            # CoW copies exercise on every admission wave
            tlen = max(args.page_size + args.page_size // 2,
                       args.max_len // 2 - args.page_size // 2)
            reqs = shared_prefix_requests(
                args.requests, seed=args.seed, vocab=cfg.vocab_size,
                template_len=min(tlen, args.max_len - 10),
                suffix_lens=(2, 8),
                max_new=(2, max(2, args.max_len // 8)),
                temperature=args.temperature, temperature_every=temp_every)
            outputs = run_staggered(eng, staggered_groups(reqs, args.slots))
        elif args.arrival_rate > 0:
            reqs = poisson_requests(
                args.requests, seed=args.seed, vocab=cfg.vocab_size,
                arrival_rate=args.arrival_rate, burst_amp=args.burst_amp,
                burst_period=args.burst_period,
                prompt_bounds=(2, max(2, args.max_len // 4)),
                new_bounds=(1, max(2, args.max_len // 8)),
                temperature=args.temperature,
                temperature_every=temp_every,
                deadline_ticks=args.deadline_ticks)
            outputs = run_arrivals(eng, reqs)
        else:
            reqs = mixed_requests(
                args.requests, seed=args.seed, vocab=cfg.vocab_size,
                prompt_lens=(2, max(2, args.max_len // 4)),
                max_new=(2, max(2, args.max_len // 8)),
                temperature=args.temperature,
                temperature_every=temp_every)
            outputs = run_staggered(eng, staggered_groups(reqs, args.slots))
        jax.block_until_ready(eng.cache)   # timings are blocking-clock
        dt = time.time() - t0
    ntok = sum(len(o) for o in outputs.values())
    print(f"served {args.requests} requests / {ntok} tokens in "
          f"{eng.ticks} ticks (K={args.ticks_per_sync}, "
          f"attn={args.attn_impl}) = {ntok / dt:.0f} tok/s on "
          f"{dict(zip(mesh.axis_names, mesh.devices.shape))}")
    if paged:
        st = eng.paged_stats()
        print(f"paged KV: pages-in-use high-water {st['pages_hwm']}"
              f"/{eng.num_pages} (page_size={eng.page_size}), "
              f"prefix-hit rate {st['prefix_hit_rate']:.2f} "
              f"({st['prefix_tokens']}/{st['prompt_tokens']} prompt "
              f"tokens), CoW copies {st['cow_copies']}, "
              f"radix nodes {st['radix_nodes']}, "
              f"deferred {st['deferred']}, evicted {st['evicted_pages']}")
        if args.shared_prefix and st["prefix_tokens"] == 0:
            raise SystemExit(
                "shared-prefix workload produced ZERO prefix hits — "
                "radix-tree sharing is broken")
    _terminal_report(eng, reqs, args.strict)
    if args.arrival_rate > 0 and not args.shared_prefix:
        summary = latency_summary(reqs)
        _print_latency(summary)
        # with admission control engaged (deadlines or a queue cap),
        # shed / timed-out outcomes are legitimate — all-terminal is
        # enforced by _terminal_report; without it, anything short of
        # full completion is a regression
        shedding = (args.deadline_ticks is not None
                    or args.max_queue_depth is not None)
        complete = (summary["completed"] == args.requests
                    or (shedding and summary["completed"] > 0))
        if not complete or not summary["wall"] or not summary["ticks"]:
            raise SystemExit(
                f"latency percentiles empty or incomplete: "
                f"{summary['completed']}/{args.requests} requests finished")
    if tracer is not None:
        path = tracer.save(args.trace_out)
        print(f"chrome trace ({len(tracer.to_chrome_trace()['traceEvents'])}"
              f" events) -> {path}")
    if args.verdicts:
        for v in eng.nvm_verdicts():
            print(f"  {v.shape}: energy vs SRAM "
                  f"STT {v.energy_ratio['STT']:.3f} / "
                  f"SOT {v.energy_ratio['SOT']:.3f}   EDP "
                  f"STT {v.edp_ratio['STT']:.3f} / "
                  f"SOT {v.edp_ratio['SOT']:.3f}")


if __name__ == "__main__":
    main()
