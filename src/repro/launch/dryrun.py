import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct inputs (no allocation), print memory/cost analysis, derive
roofline terms, persist one JSON per cell under results/dryrun/.

The two lines above MUST stay first: jax locks the device count on first
init, and the production meshes need 512 host placeholder devices.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
        --shape train_4k --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import dataclasses
import gc
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, cells, get_config, list_archs
from repro.launch import roofline as rf
from repro.launch.mesh import (make_production_mesh, mesh_axis_sizes,
                               mesh_context)
from repro.models.api import build_model, input_specs
from repro.optim import AdamW, warmup_cosine
from repro.sharding import activation_sharding, default_rules, tree_shardings
from repro.train.trainer import abstract_state, make_train_step, state_axes

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"

INPUT_AXES = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "frames": ("batch", "seq", "embed_act"),
    "vision_embeds": ("batch", "seq", "embed_act"),
    "enc_out": ("batch", "seq", "embed_act"),
}


def _input_shardings(specs, mesh, rules):
    axes = {k: INPUT_AXES[k] for k in specs}
    return tree_shardings(axes, specs, mesh, rules)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             remat: str = None, attn_impl: str = "chunked",
             fsdp: bool = None, microbatches: int = 1,
             tag: str = "baseline", save: bool = True,
             verbose: bool = True, config_overrides: dict = None,
             rules_kwargs: dict = None) -> dict:
    """Lower + compile one cell; return the result record."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    overrides = dict(config_overrides or {})
    if remat is not None:
        overrides["remat"] = remat
    if fsdp is not None:
        overrides["fsdp"] = fsdp
    if shape.kind != "train":
        overrides["fsdp"] = False  # serving: params sharded over model only
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    mesh = make_production_mesh(multi_pod=multi_pod)
    msizes = mesh_axis_sizes(mesh)
    chips = int(jax.numpy.prod(jnp.asarray(list(msizes.values()))))
    rules = default_rules(fsdp=cfg.fsdp, multi_pod=multi_pod,
                          **(rules_kwargs or {}))
    model = build_model(cfg, max_seq=shape.seq_len)
    specs = input_specs(cfg, shape)

    t0 = time.time()
    with mesh_context(mesh), activation_sharding(mesh, rules):
        if shape.kind == "train":
            opt = AdamW(lr=warmup_cosine(3e-4, 100, 10000))
            step_fn = make_train_step(model, opt, microbatches=microbatches,
                                      attn_impl=attn_impl)
            st = abstract_state(model, opt)
            st_shardings = tree_shardings(state_axes(model, opt), st, mesh,
                                          rules)
            in_shardings = (st_shardings, _input_shardings(specs, mesh, rules))
            lowered = jax.jit(step_fn, in_shardings=in_shardings,
                              out_shardings=(st_shardings, None),
                              donate_argnums=(0,)).lower(st, specs)
        elif shape.kind == "prefill":
            def prefill_fn(params, batch):
                return model.prefill(params, batch, attn_impl=attn_impl)
            params = model.abstract_params()
            p_shardings = tree_shardings(model.param_axes(), params, mesh,
                                         rules)
            in_shardings = (p_shardings, _input_shardings(specs, mesh, rules))
            lowered = jax.jit(prefill_fn, in_shardings=in_shardings
                              ).lower(params, specs)
        else:  # decode
            def decode_fn(params, cache, batch, pos):
                return model.decode_step(params, cache, batch, pos,
                                         attn_impl=attn_impl)
            params = model.abstract_params()
            B = shape.global_batch
            cache = model.abstract_cache(B, shape.seq_len)
            p_sh = tree_shardings(model.param_axes(), params, mesh, rules)
            c_sh = tree_shardings(model.cache_axes(B, shape.seq_len), cache,
                                  mesh, rules)
            in_shardings = (p_sh, c_sh, _input_shardings(specs, mesh, rules),
                            None)
            lowered = jax.jit(decode_fn, in_shardings=in_shardings,
                              out_shardings=(None, c_sh),
                              donate_argnums=(1,)).lower(
                params, cache, specs, jax.ShapeDtypeStruct((), jnp.int32))
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    roof = rf.analyze(compiled)
    mf = rf.model_flops(cfg, shape, chips)
    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        }
        mem["peak_bytes_est"] = (mem["argument_bytes"] + mem["temp_bytes"]
                                 + mem["output_bytes"] - mem["alias_bytes"])
    except Exception:
        pass

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": int(chips),
        "tag": tag,
        "kind": shape.kind,
        "knobs": {"remat": cfg.remat, "attn_impl": attn_impl,
                  "fsdp": cfg.fsdp, "microbatches": microbatches},
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem,
        "roofline": roof.to_dict(),
        "model_flops_per_device": mf,
        "model_flops_ratio": (mf / roof.flops_per_device
                              if roof.flops_per_device else None),
        "roofline_fraction": roof.model_flops_util(mf),
    }
    if verbose:
        print(f"[{tag}] {arch} x {shape_name} x {record['mesh']}: "
              f"compile {t_compile:.1f}s  "
              f"compute {roof.compute_s*1e3:.2f}ms  "
              f"memory {roof.memory_s*1e3:.2f}ms  "
              f"collective {roof.collective_s*1e3:.2f}ms  "
              f"dominant={roof.dominant}  "
              f"MF-ratio={record['model_flops_ratio'] and round(record['model_flops_ratio'],3)}  "
              f"peak/dev={mem.get('peak_bytes_est', 0)/2**30:.2f}GiB")
    if save:
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        fname = f"{arch}__{shape_name}__{record['mesh']}__{tag}.json"
        (RESULTS_DIR / fname).write_text(json.dumps(record, indent=1))
    del compiled, lowered
    gc.collect()
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--attn-impl", default="chunked")
    ap.add_argument("--fsdp", default=None,
                    type=lambda s: s.lower() in ("1", "true"))
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    targets = []
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    for arch in archs:
        shapes = ([SHAPES[args.shape]] if args.shape
                  else cells(arch))
        for shape in shapes:
            if shape.name in get_config(arch).skip_shapes:
                print(f"SKIP {arch} x {shape.name} (documented skip)")
                continue
            meshes = {"pod": [False], "multipod": [True],
                      "both": [False, True]}[args.mesh]
            for mp in meshes:
                targets.append((arch, shape.name, mp))

    failures = []
    for arch, shape_name, mp in targets:
        mesh_name = "2x16x16" if mp else "16x16"
        out = RESULTS_DIR / f"{arch}__{shape_name}__{mesh_name}__{args.tag}.json"
        if args.skip_existing and out.exists():
            print(f"skip existing {out.name}")
            continue
        try:
            run_cell(arch, shape_name, multi_pod=mp, remat=args.remat,
                     attn_impl=args.attn_impl, fsdp=args.fsdp,
                     microbatches=args.microbatches, tag=args.tag)
        except Exception as e:
            traceback.print_exc()
            failures.append((arch, shape_name, mesh_name, repr(e)))
    if failures:
        print("FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print(f"dry-run complete: {len(targets)} cells OK")


if __name__ == "__main__":
    main()
