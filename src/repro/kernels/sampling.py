"""Pallas TPU fused token-sampling kernel for the serve decode epilogue.

The engine's two-step sampler (``jnp.argmax`` + ``jax.random.categorical``
+ ``jnp.where`` over temperature) round-trips the full ``(B, V)`` logit
tensor through three separate XLA ops per tick.  This kernel folds the
whole per-row sample into one launch blocked over the vocab:

  grid (B, nv), j innermost (sequential, carries scratch);
  per block: running (max, first-argmax) reduction in VMEM scratch.

Greedy rows (``temps[b] <= 0``) reduce the raw logits and are
*bitwise-equal* to ``jnp.argmax`` (strictly-greater cross-block updates
plus min-index tie-breaks inside a block reproduce first-occurrence
semantics exactly).  Temperature rows add in-kernel Gumbel noise to
``logits / temp`` — a Gumbel-max sample from the same softmax
distribution as ``jax.random.categorical``, but NOT the same draw: the
kernel derives its bits from a counter-based murmur3-finalizer hash of
(key words, flat element index), chosen over ``pltpu.prng_*`` because
it produces identical bits in interpret (CPU) and compiled (TPU) mode
— so only greedy rows are parity-pinned against the XLA path
(DESIGN.md §15).  Sampled rows are deterministic given (key, shapes).

Layouts: logits (B, V); temps (B,) f32; key (2,) uint32 -> (B,) int32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.decode_attention import NEG_INF, decode_block_size


def _shr(h, n):
    return jax.lax.shift_right_logical(h, jnp.uint32(n))


def _fmix(h):
    """murmur3 32-bit finalizer (uint32, wrapping multiplies)."""
    h ^= _shr(h, 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h ^= _shr(h, 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h ^= _shr(h, 16)
    return h


def _sample_kernel(seed_ref, temps_ref, logits_ref, o_ref, m_scr, i_scr, *,
                   bv: int):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[0, 0] = NEG_INF
        i_scr[0, 0] = 0

    x = logits_ref[...].astype(jnp.float32)               # (1, bv)
    t = temps_ref[0, 0]

    # Gumbel-max: argmax(logits/t + g) ~ Categorical(softmax(logits/t)).
    # Counter = the element's flat (row, vocab) index; each key word is
    # folded in through a murmur3 finalizer round.
    col = jax.lax.broadcasted_iota(jnp.uint32, x.shape, 1)
    vocab = jnp.uint32(pl.num_programs(1) * bv)
    ctr = (b.astype(jnp.uint32) * vocab
           + j.astype(jnp.uint32) * jnp.uint32(bv) + col)
    k0 = jax.lax.bitcast_convert_type(seed_ref[0], jnp.uint32)
    k1 = jax.lax.bitcast_convert_type(seed_ref[1], jnp.uint32)
    bits = _fmix(_fmix(ctr ^ k0) ^ k1)
    frac = _shr(bits, 9).astype(jnp.float32)
    u = frac * (2.0 ** -23) + (2.0 ** -24)                # u in (0, 1)
    g = -jnp.log(-jnp.log(u))
    x = jnp.where(t > 0.0, x / jnp.maximum(t, 1e-6) + g, x)

    vmax = jnp.max(x)
    col = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    # first index attaining the block max (jnp.argmax tie-break)
    loc = jnp.min(jnp.where(x == vmax, col, jnp.int32(2 ** 31 - 1)))
    cand = j * bv + loc
    better = vmax > m_scr[0, 0]   # strict: earlier blocks win ties
    i_scr[0, 0] = jnp.where(better, cand, i_scr[0, 0])
    m_scr[0, 0] = jnp.where(better, vmax, m_scr[0, 0])

    @pl.when(j == pl.num_programs(1) - 1)
    def _emit():
        o_ref[0, 0] = i_scr[0, 0]


def fused_sample(logits, temps, key, *, bv: int = 512,
                 interpret: bool = False):
    """One-launch greedy/temperature sample of the next token per row.

    logits (B, V); temps (B,) — <= 0 greedy, > 0 Gumbel-max at that
    temperature; key (2,) uint32 PRNG key data -> tokens (B,) int32.
    """
    B, V = logits.shape
    bv = decode_block_size(V, bv)
    nv = V // bv

    seed = jax.lax.bitcast_convert_type(
        jnp.asarray(key, jnp.uint32), jnp.int32)
    temps2 = jnp.asarray(temps, jnp.float32).reshape(B, 1)

    def row_map(b, j, seed_ref):
        return (b, 0)

    def blk_map(b, j, seed_ref):
        return (b, j)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, nv),
        in_specs=[
            pl.BlockSpec((1, 1), row_map),
            pl.BlockSpec((1, bv), blk_map),
        ],
        out_specs=[pl.BlockSpec((1, 1), row_map)],
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),  # running max
            pltpu.VMEM((1, 1), jnp.int32),    # its first index
        ],
    )
    out = pl.pallas_call(
        functools.partial(_sample_kernel, bv=bv),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((B, 1), jnp.int32)],
        interpret=interpret,
    )(seed, temps2, logits)
    return out[0][:, 0]
