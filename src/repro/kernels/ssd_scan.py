"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

Grid (batch, head, chunk); the chunk axis is sequential and carries the
(P, N) SSM state in VMEM scratch. Each step computes the intra-chunk
quadratic term (Q x Q decay matrix on the MXU), the inter-chunk
contribution from the carried state, and the state update — the same math
as repro.models.ssm.ssd_chunked (the jnp oracle lives in kernels/ref.py).

Layouts: x (B, H, S, P); dt, dtA (B, H, S); Bmat/Cmat (B, S, N);
out (B, H, S, P). S = nc * Q.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_scr, *,
                q_chunk: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    x = x_ref[0, 0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (Q,)
    a = a_ref[0, 0].astype(jnp.float32)          # (Q,)  = dt * A
    Bm = b_ref[0].astype(jnp.float32)            # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)            # (Q, N)

    cum = jnp.cumsum(a)                           # (Q,)
    li = cum[:, None] - cum[None, :]              # (Q, Q)
    tri = jax.lax.broadcasted_iota(jnp.int32, (q_chunk, q_chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q_chunk, q_chunk), 1)
    ldecay = jnp.where(tri, jnp.exp(li), 0.0)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))   # (Q, Q)
    w = cb * ldecay * dt[None, :]                 # weights over j
    y_diag = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())))  # (Q, P)

    s = s_scr[...]                                # (P, N)
    y_off = jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, s, (((1,), (1,)), ((), ())))          # (Q, P)

    dstate = jnp.exp(cum[-1] - cum) * dt          # (Q,)
    s_inc = jax.lax.dot_general(x, Bm * dstate[:, None],
                                (((0,), (0,)), ((), ())))   # (P, N)
    s_scr[...] = s * jnp.exp(cum[-1]) + s_inc
    y_ref[0, 0] = (y_diag + y_off).astype(y_ref.dtype)


def ssd_scan(x, dt, dtA, Bmat, Cmat, *, chunk: int = 128,
             interpret: bool = False):
    """x (B,H,S,P); dt/dtA (B,H,S); Bmat/Cmat (B,S,N) -> y (B,H,S,P)."""
    B, H, S, P = x.shape
    N = Bmat.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    grid = (B, H, nc)
    kernel = functools.partial(_ssd_kernel, q_chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, dtA, Bmat, Cmat)
