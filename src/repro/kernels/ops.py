"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels run with interpret=True, which executes the
kernel body in Python for correctness validation; on TPU they compile to
Mosaic. ``interpret=None`` auto-detects.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import cache_sim as _cs
from repro.kernels import decode_attention as _da
from repro.kernels import flash_attention as _fa
from repro.kernels import paged_attention as _pa
from repro.kernels import rglru_scan as _rg
from repro.kernels import sampling as _sm
from repro.kernels import ssd_scan as _ssd


def _interpret(flag):
    if flag is not None:
        return flag
    return jax.default_backend() == "cpu"


@partial(jax.jit, static_argnames=("causal", "window", "logit_cap", "bq",
                                   "bk", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, logit_cap=0.0,
                    bq=128, bk=128, interpret=None):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               logit_cap=logit_cap, bq=bq, bk=bk,
                               interpret=_interpret(interpret))


@partial(jax.jit, static_argnames=("logit_cap", "bk", "interpret"))
def decode_attention(q, k, v, pos, window, *, logit_cap=0.0, bk=128,
                     interpret=None):
    """Blocked serve-decode attention (cache already holds the new row).

    q (B,H,hd); k/v (B,L,K,hd); pos (B,) i32; window i32 scalar (may be
    traced; <= 0 = global) -> (B,H,hd)."""
    return _da.decode_attention(q, k, v, pos, window, logit_cap=logit_cap,
                                bk=bk, interpret=_interpret(interpret))


@partial(jax.jit, static_argnames=("logit_cap", "bk", "interpret"))
def decode_attention_fused(q, k, v, new_k, new_v, pos, window, *,
                           logit_cap=0.0, bk=128, interpret=None):
    """Fused per-row KV scatter + blocked decode attention.

    Writes new_k/new_v (B,K,hd) at each row's own pos[b] inside the
    launch (aliased caches, no separate dynamic_update_slice pass) and
    returns (o, k_cache, v_cache)."""
    return _da.decode_attention_fused(
        q, k, v, new_k, new_v, pos, window, logit_cap=logit_cap, bk=bk,
        interpret=_interpret(interpret))


@partial(jax.jit, static_argnames=("logit_cap", "interpret"))
def paged_decode_attention(q, k, v, page_table, pos, window, *,
                           logit_cap=0.0, interpret=None):
    """Paged serve-decode attention (pool already holds the new row).

    q (B,H,hd); k/v pools (P,ps,K,hd); page_table (B,nb) i32; pos (B,)
    i32; window i32 scalar (may be traced; <= 0 = global) -> (B,H,hd)."""
    return _pa.paged_decode_attention(
        q, k, v, page_table, pos, window, logit_cap=logit_cap,
        interpret=_interpret(interpret))


@partial(jax.jit, static_argnames=("logit_cap", "interpret"))
def paged_decode_attention_fused(q, k, v, new_k, new_v, page_table, pos,
                                 window, *, logit_cap=0.0, interpret=None):
    """Fused through-the-page-table KV scatter + paged decode attention.

    Writes new_k/new_v (B,K,hd) into each row's boundary page at
    pos[b] % ps inside the launch (aliased pools) and returns
    (o, k_pool, v_pool)."""
    return _pa.paged_decode_attention_fused(
        q, k, v, new_k, new_v, page_table, pos, window,
        logit_cap=logit_cap, interpret=_interpret(interpret))


@partial(jax.jit, static_argnames=("bv", "interpret"))
def fused_sample(logits, temps, key, *, bv=512, interpret=None):
    """One-launch greedy/temperature next-token sample.

    logits (B,V); temps (B,) (<= 0 greedy, bitwise == argmax; > 0
    in-kernel Gumbel-max); key (2,) uint32 -> (B,) int32."""
    return _sm.fused_sample(logits, temps, key, bv=bv,
                            interpret=_interpret(interpret))


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, dtA, Bmat, Cmat, *, chunk=128, interpret=None):
    return _ssd.ssd_scan(x, dt, dtA, Bmat, Cmat, chunk=chunk,
                         interpret=_interpret(interpret))


@partial(jax.jit, static_argnames=("block", "width_tile", "interpret"))
def rglru_scan(a, b, *, block=256, width_tile=512, interpret=None):
    return _rg.rglru_scan_kernel(a, b, block=block, width_tile=width_tile,
                                 interpret=_interpret(interpret))


@partial(jax.jit, static_argnames=("num_sets", "ways", "sets_tile",
                                   "interpret"))
def cache_sim(set_ids, tags, *, num_sets, ways, sets_tile=128,
              interpret=None):
    return _cs.cache_sim(set_ids, tags, num_sets=num_sets, ways=ways,
                         sets_tile=sets_tile, interpret=_interpret(interpret))


@partial(jax.jit, static_argnames=("num_sets", "ways", "sets_tile",
                                   "interpret"))
def cache_sim_ladder(traces, *, num_sets, ways, sets_tile=2048,
                     interpret=None):
    """Batched ladder engine; ``num_sets`` is a static tuple of rung set
    counts. Returns (W, L, 2) int32 [hits, misses]."""
    return _cs.cache_sim_ladder(traces, num_sets, ways=ways,
                                sets_tile=sets_tile,
                                interpret=_interpret(interpret))
