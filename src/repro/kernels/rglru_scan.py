"""Pallas TPU kernel for the RG-LRU linear recurrence.

Grid (batch, width_tile, time_block); time is sequential and carries the
hidden state h (one f32 vector per width tile) in VMEM scratch. Within a
block the recurrence h_t = a_t * h_{t-1} + b_t runs as a fori_loop over
rows of the (Q, Rt) VMEM tiles — vector ops on the VPU, the layout
RecurrentGemma uses on TPU.

Layouts: a, b (B, S, R) with precomputed a_t = exp(log_a) and
b_t = sqrt(1-a^2) * i_t * x_t; out (B, S, R).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, y_ref, h_scr, *, q_block: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0].astype(jnp.float32)     # (Q, Rt)
    b = b_ref[0].astype(jnp.float32)

    def step(i, carry):
        h, ys = carry
        h = a[i] * h + b[i]
        return h, ys.at[i].set(h)

    h0 = h_scr[...]
    ys0 = jnp.zeros_like(a)
    h, ys = jax.lax.fori_loop(0, q_block, step, (h0, ys0))
    h_scr[...] = h
    y_ref[0] = ys.astype(y_ref.dtype)


def rglru_scan_kernel(a, b, *, block: int = 256, width_tile: int = 512,
                      interpret: bool = False):
    """a, b (B, S, R) -> h sequence (B, S, R)."""
    B, S, R = a.shape
    block = min(block, S)
    width_tile = min(width_tile, R)
    assert S % block == 0 and R % width_tile == 0, (S, block, R, width_tile)
    grid = (B, R // width_tile, S // block)
    kernel = functools.partial(_rglru_kernel, q_block=block)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block, width_tile), lambda b_, r, t: (b_, t, r)),
            pl.BlockSpec((1, block, width_tile), lambda b_, r, t: (b_, t, r)),
        ],
        out_specs=pl.BlockSpec((1, block, width_tile),
                               lambda b_, r, t: (b_, t, r)),
        out_shape=jax.ShapeDtypeStruct((B, S, R), a.dtype),
        scratch_shapes=[pltpu.VMEM((width_tile,), jnp.float32)],
        interpret=interpret,
    )(a, b)
