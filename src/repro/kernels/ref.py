"""Pure-jnp oracles for every Pallas kernel (shape-for-shape contracts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def flash_attention_ref(q, k, v, *, causal=True, window=0, logit_cap=0.0):
    """q (B,H,Sq,hd); k/v (B,K,Skv,hd) -> (B,H,Sq,hd). O(S^2) reference."""
    B, H, Sq, hd = q.shape
    _, K, Skv, _ = k.shape
    G = H // K
    kf = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * hd ** -0.5, kf)
    if logit_cap:
        s = logit_cap * jnp.tanh(s / logit_cap)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    ok = jnp.ones((Sq, Skv), bool)
    if causal:
        ok &= k_pos <= q_pos
    if window:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)


def decode_attention_ref(q, k, v, pos, window=0, *, logit_cap=0.0):
    """Kernel-layout oracle for the serve decode kernel: q (B,H,hd);
    k/v (B,L,K,hd) full cache buffers; pos (B,) — row b attends
    ``k_idx <= pos[b]`` (inside its local window when ``window`` > 0;
    <= 0 = global).  Full (B,H,L) logits, plain softmax."""
    B, H, hd = q.shape
    _, L, K, _ = k.shape
    G = H // K
    qr = q.reshape(B, K, G, hd).astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bkgh,btkh->bkgt", qr, k.astype(jnp.float32))
    if logit_cap:
        s = logit_cap * jnp.tanh(s / logit_cap)
    k_idx = jnp.arange(L, dtype=jnp.int32)
    pos = jnp.asarray(pos, jnp.int32)
    w = jnp.asarray(window, jnp.int32)
    ok = k_idx[None, :] <= pos[:, None]
    ok &= (w <= 0) | (k_idx[None, :] > pos[:, None] - w)
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkh->bkgh", p, v.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)


def paged_decode_attention_ref(q, k, v, page_table, pos, window=0, *,
                               logit_cap=0.0):
    """Oracle for the paged decode kernel: gather each row's pages from
    the physical pool into a linear (B, L, K, hd) cache, then run the
    dense decode oracle.  q (B,H,hd); k/v pools (P,ps,K,hd);
    page_table (B,nb) i32; pos (B,)."""
    B = q.shape[0]
    P, ps, K, hd = k.shape
    nb = page_table.shape[1]
    lin_k = k[page_table].reshape(B, nb * ps, K, hd)
    lin_v = v[page_table].reshape(B, nb * ps, K, hd)
    return decode_attention_ref(q, lin_k, lin_v, pos, window,
                                logit_cap=logit_cap)


def ssd_scan_ref(x, dt, dtA, Bmat, Cmat):
    """Naive O(S^2) SSD. x (B,H,S,P); dt/dtA (B,H,S); B/C (B,S,N)."""
    B, H, S, P = x.shape
    cum = jnp.cumsum(dtA.astype(jnp.float32), axis=-1)        # (B,H,S)
    li = cum[..., :, None] - cum[..., None, :]                 # (B,H,S,S)
    tri = jnp.tril(jnp.ones((S, S), bool))
    decay = jnp.where(tri[None, None], jnp.exp(li), 0.0)
    cb = jnp.einsum("bin,bjn->bij", Cmat.astype(jnp.float32),
                    Bmat.astype(jnp.float32))                  # (B,S,S)
    w = cb[:, None] * decay * dt.astype(jnp.float32)[..., None, :]
    y = jnp.einsum("bhij,bhjp->bhip", w, x.astype(jnp.float32))
    return y.astype(x.dtype)


def rglru_scan_ref(a, b):
    """Plain sequential recurrence h_t = a_t h_{t-1} + b_t. (B,S,R)."""
    def step(h, inp):
        a_t, b_t = inp
        h = a_t * h + b_t
        return h, h
    B, S, R = a.shape
    h0 = jnp.zeros((B, R), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (a.transpose(1, 0, 2).astype(jnp.float32),
                                    b.transpose(1, 0, 2).astype(jnp.float32)))
    return ys.transpose(1, 0, 2).astype(a.dtype)


def cache_sim_ref(set_ids, tags, *, num_sets: int, ways: int):
    """jnp scan-based LRU set-associative simulator (oracle)."""
    import numpy as np

    def step(state, inp):
        tag_arr, age_arr, hits, misses = state
        sid, tag = inp
        row_tags = tag_arr[sid]                 # (ways,)
        row_ages = age_arr[sid]
        hit_way = jnp.where(row_tags == tag, jnp.arange(ways), ways).min()
        hit = hit_way < ways
        victim = jnp.argmax(row_ages)
        way = jnp.where(hit, hit_way, victim)
        tag_arr = tag_arr.at[sid, way].set(tag)
        age_arr = age_arr.at[sid].add(1)
        age_arr = age_arr.at[sid, way].set(0)
        return (tag_arr, age_arr, hits + hit.astype(jnp.int32),
                misses + (~hit).astype(jnp.int32)), None

    tag0 = jnp.full((num_sets, ways), -1, jnp.int32)
    age0 = jnp.zeros((num_sets, ways), jnp.int32)
    (t, a, h, m), _ = jax.lax.scan(
        step, (tag0, age0, jnp.int32(0), jnp.int32(0)),
        (set_ids.astype(jnp.int32), tags.astype(jnp.int32)))
    return h, m


def cache_sim_numpy(set_ids, tags, *, num_sets: int, ways: int):
    """Pure-numpy LRU oracle, shape-for-shape with the kernels' tag/age
    state (empty ways carry the oldest age, so fills precede evictions)."""
    import numpy as np

    tag_arr = np.full((num_sets, ways), -1, np.int64)
    age_arr = np.zeros((num_sets, ways), np.int64)
    hits = misses = 0
    for sid, tag in zip(np.asarray(set_ids).tolist(),
                        np.asarray(tags).tolist()):
        match = np.nonzero(tag_arr[sid] == tag)[0]
        if match.size:
            hits += 1
            way = int(match[0])
        else:
            misses += 1
            way = int(np.argmax(age_arr[sid]))
        tag_arr[sid, way] = tag
        age_arr[sid] += 1
        age_arr[sid, way] = 0
    return hits, misses


def cache_sim_ladder_numpy(traces, num_sets_ladder, *, ways: int):
    """Numpy oracle for the batched ladder engine: (W, L, 2) counts."""
    import numpy as np

    traces = np.atleast_2d(np.asarray(traces))
    out = np.zeros((traces.shape[0], len(num_sets_ladder), 2), np.int64)
    for w, trace in enumerate(traces):
        for l, ns in enumerate(num_sets_ladder):
            out[w, l] = cache_sim_numpy(trace % ns, trace // ns,
                                        num_sets=ns, ways=ways)
    return out


def cache_sim_python(set_ids, tags, *, num_sets: int, ways: int):
    """Plain-python dict LRU (second, independent oracle for tests)."""
    import collections
    sets = [collections.OrderedDict() for _ in range(num_sets)]
    hits = misses = 0
    for sid, tag in zip(list(set_ids), list(tags)):
        s = sets[int(sid)]
        t = int(tag)
        if t in s:
            hits += 1
            s.move_to_end(t)
        else:
            misses += 1
            if len(s) >= ways:
                s.popitem(last=False)
            s[t] = True
    return hits, misses
