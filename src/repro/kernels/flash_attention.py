"""Pallas TPU flash-attention kernel (forward).

Grid (batch, q_head, q_block, kv_block); the kv_block dimension is the
innermost "arbitrary" (sequential) axis, carrying the online-softmax state
(m, l, acc) in VMEM scratch. GQA is handled in the k/v index_maps
(q head h reads kv head h // group_size), so k/v are never materialized
per-q-head. Causal + local-window masking and logit soft-capping are
applied with global position iota.

Layouts: q (B, H, Sq, hd); k/v (B, K, Skv, hd); out (B, H, Sq, hd).
Block shapes are 128-aligned for the MXU (Bq x hd and Bk x hd tiles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  kv_blocks: int, bq: int, bk: int, causal: bool,
                  window: int, logit_cap: float, scale: float):
    j = pl.program_id(3)
    i = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (Bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (Bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (Bq, Bk)
    if logit_cap:
        s = logit_cap * jnp.tanh(s / logit_cap)

    q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
    acc_scr[...] = (acc_scr[...] * corr[:, None]
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
    m_scr[...] = m_new

    @pl.when(j == kv_blocks - 1)
    def _emit():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-37)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    logit_cap: float = 0.0, bq: int = 128, bk: int = 128,
                    interpret: bool = False):
    """q (B,H,Sq,hd), k/v (B,K,Skv,hd) -> (B,H,Sq,hd)."""
    B, H, Sq, hd = q.shape
    _, K, Skv, _ = k.shape
    G = H // K
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    if Sq % bq or Skv % bk:
        # a bare assert here was stripped under ``python -O`` and let
        # non-divisible shapes run off the end of the last block
        raise ValueError(
            f"flash_attention needs divisible blocks: Sq={Sq} % bq={bq} = "
            f"{Sq % bq}, Skv={Skv} % bk={bk} = {Skv % bk}")
    nq, nk = Sq // bq, Skv // bk
    grid = (B, H, nq, nk)

    kernel = functools.partial(
        _flash_kernel, kv_blocks=nk, bq=bq, bk=bk, causal=causal,
        window=window, logit_cap=logit_cap, scale=hd ** -0.5)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # m (running max)
            pltpu.VMEM((bq,), jnp.float32),       # l (running sum)
            pltpu.VMEM((bq, hd), jnp.float32),    # acc
        ],
        interpret=interpret,
    )(q, k, v)
