"""Pallas TPU decode-attention kernel for the serve hot path.

One query row per slot attends that slot's KV-cache prefix (``k_idx <=
pos[b]``) — the continuous-batching decode tick of ``serve/engine.py``.
The XLA path (``models.attention.decode_attention``) broadcasts every
slot's query against the FULL ``(B, L)`` cache buffer and materializes a
``(B, K, G, L)`` f32 logit tensor per layer per tick; this kernel streams
the cache in ``bk``-row blocks with flash-style running (m, l, acc)
online-softmax state in VMEM scratch, and — the decode-specific part —
uses the per-row positions as *scalar-prefetch* operands so the KV
block-fetch index map clamps to each row's live window:

  grid (B, nk), j innermost (sequential, carries scratch);
  kv index map   (b, clip(j, lo_b, tb_b), 0, 0)

where ``tb_b = pos[b] // bk`` is the row's last live block and ``lo_b``
the first block inside its local window.  Pallas elides block copies
whose index map repeats the previous index, so a slot at depth 5 in a
4096-deep cache DMAs one block, not 32 — per-slot read traffic scales
with the slot's own depth, the access pattern the paper's LLC analysis
prices (DESIGN.md §13).  All H query heads ride in one grid step (q
block (1, H, hd) reshaped to (K, G, hd) in-kernel), so each KV block is
fetched ONCE per slot — GQA grouping happens in the batched dot, never
as extra grid steps or per-q-head refetches.

The FUSED variant additionally scatters the new token's K/V row into the
cache block that contains ``pos[b]`` inside the same launch (the block is
already in VMEM for the self-attention term), writing only visited
blocks back via an aliased input/output cache buffer — this replaces the
engine's separate per-layer ``cache.at[rows, pos].set`` pass and never
writes a block past a live slot's position (rows beyond ``pos[b]`` in
the boundary block are written back bit-identically).

Layouts (cache-native; no transposes on the hot path):
  q (B, H, hd); k/v cache (B, L, K, hd); new k/v rows (B, K, hd);
  pos (B,) int32; window () int32 (0 or negative = global; may be a
  traced per-layer scalar) -> o (B, H, hd) [, updated k/v caches].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def decode_block_size(max_len: int, bk: int) -> int:
    """Largest KV block <= ``bk`` that divides ``max_len`` (the kernel
    tiles the cache exactly; same contract as cachesim's divisor tile)."""
    for tile in range(min(int(bk), int(max_len)), 0, -1):
        if max_len % tile == 0:
            return tile
    return 1


def _block_bounds(pos_b, win, bk):
    """(lo, tb): first and last live KV-block index for a row at pos_b.

    ``win <= 0`` means global attention (the traced per-layer escape
    hatch shared with the jnp paths).
    """
    tb = pos_b // bk
    lo = jnp.where(win > 0,
                   jnp.maximum(pos_b - win + 1, 0) // bk,
                   0)
    return lo, tb


def _decode_kernel(pos_ref, win_ref, q_ref, k_ref, v_ref, *rest,
                   bk: int, group: int, logit_cap: float, scale: float,
                   fused: bool):
    if fused:
        nk_ref, nv_ref, o_ref, ck_ref, cv_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    j = pl.program_id(1)

    pos_b = pos_ref[b]
    win = win_ref[0]
    lo, tb = _block_bounds(pos_b, win, bk)
    jc = jnp.clip(j, lo, tb)          # block actually mapped by the specs

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kb = k_ref[0].astype(jnp.float32)                     # (bk, K, hd)
    vb = v_ref[0].astype(jnp.float32)
    if fused:
        # The boundary block holds the write position: inject the new
        # token's K/V row so the self-attention term sees it, and write
        # the visited block back (rows > pos_b stay bit-identical).
        row = jax.lax.broadcasted_iota(jnp.int32, (kb.shape[0], 1, 1), 0)
        hit = (jc == tb) & (row == pos_b % bk)
        kb = jnp.where(hit, nk_ref[0].astype(jnp.float32)[None], kb)
        vb = jnp.where(hit, nv_ref[0].astype(jnp.float32)[None], vb)
        ck_ref[0] = kb.astype(ck_ref.dtype)
        cv_ref[0] = vb.astype(cv_ref.dtype)

    @pl.when((j >= lo) & (j <= tb))
    def _accumulate():
        K = kb.shape[1]
        # (K, G, hd): q head k*G + g attends kv head k — same grouping
        # as the h // G index-map trick, done in one batched dot.
        q = (q_ref[0].astype(jnp.float32) * scale).reshape(K, group, -1)
        s = jnp.einsum("kgd,tkd->kgt", q, kb)             # (K, G, bk)
        if logit_cap:
            s = logit_cap * jnp.tanh(s / logit_cap)
        k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        ok = k_pos <= pos_b
        ok &= (win <= 0) | (k_pos > pos_b - win)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=2))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=2)
        acc_scr[...] = (acc_scr[...] * corr[..., None]
                        + jnp.einsum("kgt,tkd->kgd", p, vb))
        m_scr[...] = m_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _emit():
        acc = acc_scr[...] / jnp.maximum(l_scr[...], 1e-37)[..., None]
        o_ref[0] = acc.reshape(o_ref.shape[1:]).astype(o_ref.dtype)


def _call(q, k, v, pos, window, new_k, new_v, *, logit_cap, bk, fused,
          interpret):
    B, H, hd = q.shape
    _, L, K, _ = k.shape
    if H % K:
        raise ValueError(f"q heads {H} not divisible by kv heads {K}")
    G = H // K
    bk = decode_block_size(L, bk)
    nk = L // bk

    pos = jnp.asarray(pos, jnp.int32)
    win = jnp.asarray(window, jnp.int32).reshape(1)

    kernel = functools.partial(
        _decode_kernel, bk=bk, group=G, logit_cap=float(logit_cap),
        scale=hd ** -0.5, fused=fused)

    def q_map(b, j, pos_ref, win_ref):
        return (b, 0, 0)

    def kv_map(b, j, pos_ref, win_ref):
        lo, tb = _block_bounds(pos_ref[b], win_ref[0], bk)
        return (b, jnp.clip(j, lo, tb), 0, 0)

    in_specs = [
        pl.BlockSpec((1, H, hd), q_map),
        pl.BlockSpec((1, bk, K, hd), kv_map),
        pl.BlockSpec((1, bk, K, hd), kv_map),
    ]
    out_specs = [pl.BlockSpec((1, H, hd), q_map)]
    out_shape = [jax.ShapeDtypeStruct((B, H, hd), q.dtype)]
    operands = [q, k, v]
    scratch = [
        pltpu.VMEM((K, G), jnp.float32),      # m (running max, per head)
        pltpu.VMEM((K, G), jnp.float32),      # l (running sum, per head)
        pltpu.VMEM((K, G, hd), jnp.float32),  # acc
    ]
    aliases = {}
    if fused:
        in_specs += [pl.BlockSpec((1, K, hd), q_map),
                     pl.BlockSpec((1, K, hd), q_map)]
        operands += [new_k, new_v]
        out_specs += [pl.BlockSpec((1, bk, K, hd), kv_map),
                      pl.BlockSpec((1, bk, K, hd), kv_map)]
        out_shape += [jax.ShapeDtypeStruct(k.shape, k.dtype),
                      jax.ShapeDtypeStruct(v.shape, v.dtype)]
        # cache in-place: operand indices count the 2 scalar-prefetch
        # args (pos, win), so k/v sit at 3/4; blocks the grid never
        # maps (beyond a row's live window) keep their input bits.
        aliases = {3: 1, 4: 2}

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nk),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch,
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
    )(pos, win, *operands)
    return tuple(out) if fused else out[0]


def decode_attention(q, k, v, pos, window=0, *, logit_cap: float = 0.0,
                     bk: int = 128, interpret: bool = False):
    """Blocked decode attention; the cache already holds the new KV row.

    q (B,H,hd); k/v (B,L,K,hd); pos (B,) int32 -> o (B,H,hd)."""
    return _call(q, k, v, pos, window, None, None, logit_cap=logit_cap,
                 bk=bk, fused=False, interpret=interpret)


def decode_attention_fused(q, k, v, new_k, new_v, pos, window=0, *,
                           logit_cap: float = 0.0, bk: int = 128,
                           interpret: bool = False):
    """Fused scatter + blocked decode attention.

    Writes ``new_k/new_v`` (B,K,hd) into the caches at each row's own
    ``pos[b]`` inside the launch and attends ``k_idx <= pos[b]``.
    Returns (o, k_cache, v_cache); the caches are aliased in/out, so no
    separate per-layer ``dynamic_update_slice`` pass and no full-cache
    copy.  Invariant: no cache row past a live slot's ``pos`` changes.
    """
    return _call(q, k, v, pos, window, new_k, new_v, logit_cap=logit_cap,
                 bk=bk, fused=True, interpret=interpret)
