"""Pallas TPU *paged* decode-attention kernel (DESIGN.md §15).

Extends ``kernels/decode_attention.py`` (§13) to a KV cache that lives
as a shared physical page pool instead of one dense ``(B, L)`` buffer
per slot: k/v are ``(P, ps, K, hd)`` pools (one page = ``ps`` token
positions) and each slot owns a logical->physical page table row
``pt (B, nb)``.  Requests with a common prompt prefix map the *same*
physical pages, so the pool holds one copy of every shared prefix.

The page table rides in as a third scalar-prefetch operand and the KV
block-fetch index map dereferences it:

  grid (B, nb), j innermost (sequential, carries scratch);
  kv index map   (pt[b, clip(j, lo_b, tb_b)], 0, 0, 0)

with ``tb_b = pos[b] // ps`` the row's last live logical page and
``lo_b`` the first page inside its local window — DMA is still clamped
to each slot's own depth exactly as in the dense kernel, and Pallas
elides refetches when consecutive grid steps map the same physical
page.  Logical key positions are reconstructed in-kernel as
``j * ps + iota`` (valid because accumulation is gated on
``lo <= j <= tb`` where the clamp is the identity).

The FUSED variant scatters the new token's K/V row through the page
table into the *boundary page* (the page holding ``pos[b]``) inside
the same launch, via aliased pool buffers.  Preconditions the engine
maintains (DESIGN.md §15): each live row's boundary page is private to
that row (copy-on-write at admission guarantees it), so the in-place
row injection never races; pages shared read-only are written back
bit-identically, and fully unmapped pages keep their input bits.

Layouts: q (B, H, hd); k/v pools (P, ps, K, hd); page_table (B, nb)
int32; pos (B,) int32; window () int32 (0 or negative = global; may be
a traced per-layer scalar) -> o (B, H, hd) [, updated k/v pools].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.decode_attention import NEG_INF, _block_bounds


def _paged_kernel(pt_ref, pos_ref, win_ref, q_ref, k_ref, v_ref, *rest,
                  ps: int, group: int, logit_cap: float, scale: float,
                  fused: bool):
    if fused:
        nk_ref, nv_ref, o_ref, ck_ref, cv_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    j = pl.program_id(1)

    pos_b = pos_ref[b]
    win = win_ref[0]
    lo, tb = _block_bounds(pos_b, win, ps)
    jc = jnp.clip(j, lo, tb)          # logical page actually mapped

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kb = k_ref[0].astype(jnp.float32)                     # (ps, K, hd)
    vb = v_ref[0].astype(jnp.float32)
    if fused:
        # The boundary page holds the write position: inject the new
        # token's K/V row and write the visited page back through the
        # page table (rows > pos_b % ps stay bit-identical; the page
        # is private to this slot by the CoW admission rule).
        row = jax.lax.broadcasted_iota(jnp.int32, (kb.shape[0], 1, 1), 0)
        hit = (jc == tb) & (row == pos_b % ps)
        kb = jnp.where(hit, nk_ref[0].astype(jnp.float32)[None], kb)
        vb = jnp.where(hit, nv_ref[0].astype(jnp.float32)[None], vb)
        ck_ref[0] = kb.astype(ck_ref.dtype)
        cv_ref[0] = vb.astype(cv_ref.dtype)

    @pl.when((j >= lo) & (j <= tb))
    def _accumulate():
        q = (q_ref[0].astype(jnp.float32) * scale).reshape(
            kb.shape[1], group, -1)
        s = jnp.einsum("kgd,tkd->kgt", q, kb)             # (K, G, ps)
        if logit_cap:
            s = logit_cap * jnp.tanh(s / logit_cap)
        # logical position of each key row (jc == j inside the gate)
        k_pos = j * ps + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        ok = k_pos <= pos_b
        ok &= (win <= 0) | (k_pos > pos_b - win)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=2))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=2)
        acc_scr[...] = (acc_scr[...] * corr[..., None]
                        + jnp.einsum("kgt,tkd->kgd", p, vb))
        m_scr[...] = m_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _emit():
        acc = acc_scr[...] / jnp.maximum(l_scr[...], 1e-37)[..., None]
        o_ref[0] = acc.reshape(o_ref.shape[1:]).astype(o_ref.dtype)


def _call(q, k, v, page_table, pos, window, new_k, new_v, *, logit_cap,
          fused, interpret):
    B, H, hd = q.shape
    P, ps, K, _ = k.shape
    nb = page_table.shape[1]
    if H % K:
        raise ValueError(f"q heads {H} not divisible by kv heads {K}")
    G = H // K

    pt = jnp.asarray(page_table, jnp.int32)
    pos = jnp.asarray(pos, jnp.int32)
    win = jnp.asarray(window, jnp.int32).reshape(1)

    kernel = functools.partial(
        _paged_kernel, ps=ps, group=G, logit_cap=float(logit_cap),
        scale=hd ** -0.5, fused=fused)

    def q_map(b, j, pt_ref, pos_ref, win_ref):
        return (b, 0, 0)

    def kv_map(b, j, pt_ref, pos_ref, win_ref):
        lo, tb = _block_bounds(pos_ref[b], win_ref[0], ps)
        return (pt_ref[b, jnp.clip(j, lo, tb)], 0, 0, 0)

    in_specs = [
        pl.BlockSpec((1, H, hd), q_map),
        pl.BlockSpec((1, ps, K, hd), kv_map),
        pl.BlockSpec((1, ps, K, hd), kv_map),
    ]
    out_specs = [pl.BlockSpec((1, H, hd), q_map)]
    out_shape = [jax.ShapeDtypeStruct((B, H, hd), q.dtype)]
    operands = [q, k, v]
    scratch = [
        pltpu.VMEM((K, G), jnp.float32),      # m (running max, per head)
        pltpu.VMEM((K, G), jnp.float32),      # l (running sum, per head)
        pltpu.VMEM((K, G, hd), jnp.float32),  # acc
    ]
    aliases = {}
    if fused:
        in_specs += [pl.BlockSpec((1, K, hd), q_map),
                     pl.BlockSpec((1, K, hd), q_map)]
        operands += [new_k, new_v]
        out_specs += [pl.BlockSpec((1, ps, K, hd), kv_map),
                      pl.BlockSpec((1, ps, K, hd), kv_map)]
        out_shape += [jax.ShapeDtypeStruct(k.shape, k.dtype),
                      jax.ShapeDtypeStruct(v.shape, v.dtype)]
        # pool in-place: operand indices count the 3 scalar-prefetch
        # args (pt, pos, win), so k/v sit at 4/5; pages the grid never
        # maps keep their input bits.
        aliases = {4: 1, 5: 2}

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, nb),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch,
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
    )(pt, pos, win, *operands)
    return tuple(out) if fused else out[0]


def paged_decode_attention(q, k, v, page_table, pos, window=0, *,
                           logit_cap: float = 0.0, interpret: bool = False):
    """Paged decode attention; the pool already holds the new KV row.

    q (B,H,hd); k/v pools (P,ps,K,hd); page_table (B,nb) i32; pos (B,)
    i32 -> o (B,H,hd)."""
    return _call(q, k, v, page_table, pos, window, None, None,
                 logit_cap=logit_cap, fused=False, interpret=interpret)


def paged_decode_attention_fused(q, k, v, new_k, new_v, page_table, pos,
                                 window=0, *, logit_cap: float = 0.0,
                                 interpret: bool = False):
    """Fused through-the-page-table KV scatter + paged decode attention.

    Writes ``new_k/new_v`` (B,K,hd) into each row's boundary page at
    ``pos[b] % ps`` inside the launch (aliased pools) and attends
    ``k_idx <= pos[b]``.  Returns (o, k_pool, v_pool).  Precondition:
    every live row's boundary page is private to that row (the
    engine's CoW-at-admission rule).
    """
    return _call(q, k, v, page_table, pos, window, new_k, new_v,
                 logit_cap=logit_cap, fused=True, interpret=interpret)
