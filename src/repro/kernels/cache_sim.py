"""Pallas TPU kernels: trace-driven set-associative LRU cache simulator.

This is the paper's GPGPU-Sim replacement hot loop (DESIGN.md §3): iso-area
DRAM-access counts need cache-miss simulation at capacities that don't
exist in hardware. Two kernels share the LRU semantics:

``cache_sim`` (per-point, the seed path retained as the parity baseline):
SETS are embarrassingly parallel (grid over set tiles, tag/LRU-age state
lives in VMEM scratch); the TRACE is sequential (fori_loop). Each set tile
scans the full trace and handles only accesses that map to one of its sets
via masked vectorized updates — O(sets_tile x ways) vector work per access
on the VPU, no serialized per-way branching.

``cache_sim_ladder`` (batched engine): one launch whose grid spans
(workload traces x capacity-ladder set tiles). Each grid cell owns one set
tile of one ladder rung, derives set ids / tags from the raw line trace
and its rung's set count in-kernel, and touches only the one (1, ways)
LRU row an access maps to (dynamic-slice read/modify/write) — O(ways)
work per access instead of O(sets_tile x ways), which is what makes the
whole-ladder batch beat the per-point loop (BENCH_cachesim.json).

Inputs: per-point takes set_ids/tags (T,) int32 precomputed from line
addresses; the ladder engine takes raw line traces (W, T) int32 plus the
static per-rung set counts. Outputs: [hits, misses] counts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

EMPTY = -1  # empty-way tag sentinel


def _cachesim_kernel(setid_ref, tag_ref, out_ref, tags_scr, age_scr,
                     cnt_scr, *, sets_tile: int, ways: int, trace_len: int):
    s0 = pl.program_id(0) * sets_tile

    tags_scr[...] = jnp.full(tags_scr.shape, EMPTY, tags_scr.dtype)
    age_scr[...] = jnp.zeros_like(age_scr)
    cnt_scr[...] = jnp.zeros_like(cnt_scr)

    set_ids = setid_ref[...]
    tags_in = tag_ref[...]

    def step(t, _):
        sid = set_ids[t] - s0                       # local set row
        tag = tags_in[t]
        in_tile = (sid >= 0) & (sid < sets_tile)
        row = jnp.where(in_tile, sid, 0)
        row_mask = (jax.lax.broadcasted_iota(jnp.int32, (sets_tile, ways), 0)
                    == row) & in_tile               # (sets, ways)
        tags = tags_scr[...]
        ages = age_scr[...]
        hit_mask = row_mask & (tags == tag)
        hit = jnp.any(hit_mask)
        # LRU victim within the row: max age
        row_ages = jnp.where(row_mask, ages, -1)
        victim_flat = jnp.argmax(row_ages.reshape(-1))
        victim_mask = (jax.lax.broadcasted_iota(
            jnp.int32, (sets_tile * ways,), 0) == victim_flat
        ).reshape(sets_tile, ways) & row_mask
        write_mask = jnp.where(hit, hit_mask, victim_mask)
        tags_scr[...] = jnp.where(write_mask, tag, tags)
        # age: touched line -> 0; other lines in the row -> +1
        age_scr[...] = jnp.where(write_mask, 0,
                                 jnp.where(row_mask, ages + 1, ages))
        cnt_scr[0] = cnt_scr[0] + jnp.where(in_tile & hit, 1, 0)
        cnt_scr[1] = cnt_scr[1] + jnp.where(in_tile & ~hit, 1, 0)
        return 0

    jax.lax.fori_loop(0, trace_len, step, 0)
    out_ref[0] = cnt_scr[...]


def cache_sim(set_ids, tags, *, num_sets: int, ways: int,
              sets_tile: int = 128, interpret: bool = False):
    """Simulate an LRU set-associative cache over an access trace.

    Returns (hits, misses) totals.
    """
    T = set_ids.shape[0]
    assert num_sets % sets_tile == 0, (num_sets, sets_tile)
    n_tiles = num_sets // sets_tile
    kernel = functools.partial(_cachesim_kernel, sets_tile=sets_tile,
                               ways=ways, trace_len=T)
    counts = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((T,), lambda i: (0,)),
            pl.BlockSpec((T,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, 2), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((sets_tile, ways), jnp.int32),
            pltpu.VMEM((sets_tile, ways), jnp.int32),
            pltpu.VMEM((2,), jnp.int32),
        ],
        interpret=interpret,
    )(set_ids.astype(jnp.int32), tags.astype(jnp.int32))
    total = counts.sum(axis=0)
    return total[0], total[1]


def _ladder_kernel(ns_ref, base_ref, trace_ref, out_ref, tags_scr, age_scr,
                   *, sets_tile: int, ways: int, trace_len: int):
    ns = ns_ref[0]                               # this tile's rung set count
    s0 = base_ref[0]                             # first set owned by the tile
    tags_scr[...] = jnp.full(tags_scr.shape, EMPTY, tags_scr.dtype)
    age_scr[...] = jnp.zeros_like(age_scr)

    trace = trace_ref[0, :]
    set_ids = trace % ns
    tags_in = trace // ns
    way_iota = jax.lax.broadcasted_iota(jnp.int32, (1, ways), 1)

    def step(t, carry):
        hits, misses = carry
        sid = set_ids[t] - s0                    # local set row
        tag = tags_in[t]
        in_tile = (sid >= 0) & (sid < sets_tile)
        row = jnp.where(in_tile, sid, 0)
        row_tags = tags_scr[pl.ds(row, 1), :]    # (1, ways)
        row_ages = age_scr[pl.ds(row, 1), :]
        hit_way = jnp.min(jnp.where(row_tags == tag, way_iota, ways))
        hit = hit_way < ways
        victim = jnp.argmax(row_ages)            # LRU way: max age wins
        way = jnp.where(hit, hit_way, victim)
        touched = way_iota == way
        # touched way -> age 0; rest of the row ages by one
        new_tags = jnp.where(touched, tag, row_tags)
        new_ages = jnp.where(touched, 0, row_ages + 1)
        keep = ~in_tile                          # foreign access: no-op write
        tags_scr[pl.ds(row, 1), :] = jnp.where(keep, row_tags, new_tags)
        age_scr[pl.ds(row, 1), :] = jnp.where(keep, row_ages, new_ages)
        return (hits + jnp.where(in_tile & hit, 1, 0),
                misses + jnp.where(in_tile & ~hit, 1, 0))

    h, m = jax.lax.fori_loop(0, trace_len, step,
                             (jnp.int32(0), jnp.int32(0)))
    out_ref[0, 0, 0] = h
    out_ref[0, 0, 1] = m


def ladder_tiles(num_sets_ladder, sets_tile: int):
    """Static (tile set-count, tile base, rung id) triples covering a ladder.

    One entry per grid cell of ``cache_sim_ladder``: rung ``l`` with ``ns``
    sets contributes ``ceil(ns / tile)`` tiles (no divisibility requirement —
    the kernel masks accesses outside ``[base, base + tile)``).
    """
    ladder = tuple(int(n) for n in num_sets_ladder)
    if not ladder or min(ladder) < 1:
        raise ValueError(f"bad set-count ladder {ladder!r}")
    tile = min(int(sets_tile), max(ladder))
    ns_of, base_of, rung_of = [], [], []
    for l, ns in enumerate(ladder):
        for base in range(0, ns, tile):
            ns_of.append(ns)
            base_of.append(base)
            rung_of.append(l)
    return tile, tuple(ns_of), tuple(base_of), tuple(rung_of)


def cache_sim_ladder(traces, num_sets_ladder, *, ways: int,
                     sets_tile: int = 2048, interpret: bool = False):
    """Simulate every (trace, ladder rung) pair in one Pallas launch.

    ``traces`` is (W, T) int32 line ids; ``num_sets_ladder`` a static tuple
    of per-rung set counts. Returns (W, L, 2) int32 [hits, misses].
    """
    traces = jnp.asarray(traces, jnp.int32)
    W, T = traces.shape
    tile, ns_of, base_of, rung_of = ladder_tiles(num_sets_ladder, sets_tile)
    G = len(ns_of)
    kernel = functools.partial(_ladder_kernel, sets_tile=tile, ways=ways,
                               trace_len=T)
    counts = pl.pallas_call(
        kernel,
        grid=(W, G),
        in_specs=[
            pl.BlockSpec((1,), lambda w, g: (g,)),
            pl.BlockSpec((1,), lambda w, g: (g,)),
            pl.BlockSpec((1, T), lambda w, g: (w, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 2), lambda w, g: (w, g, 0)),
        out_shape=jax.ShapeDtypeStruct((W, G, 2), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((tile, ways), jnp.int32),
            pltpu.VMEM((tile, ways), jnp.int32),
        ],
        interpret=interpret,
    )(jnp.asarray(ns_of, jnp.int32), jnp.asarray(base_of, jnp.int32), traces)
    # tile -> rung reduction (pure bookkeeping; rung ids are static)
    seg = jnp.asarray(rung_of, jnp.int32)
    per_rung = jax.ops.segment_sum(counts.transpose(1, 0, 2), seg,
                                   num_segments=len(num_sets_ladder))
    return per_rung.transpose(1, 0, 2)
