"""Pallas TPU kernel: trace-driven set-associative LRU cache simulator.

This is the paper's GPGPU-Sim replacement hot loop (DESIGN.md §3): iso-area
DRAM-access counts need cache-miss simulation at capacities that don't
exist in hardware. The TPU-native decomposition: SETS are embarrassingly
parallel (grid over set tiles, tag/LRU-age state lives in VMEM scratch);
the TRACE is sequential (fori_loop). Each set tile scans the full trace
and handles only accesses that map to one of its sets via masked
vectorized updates — O(sets_tile x ways) vector work per access on the
VPU, no serialized per-way branching.

Inputs: set_ids (T,) int32, tags (T,) int32 (precomputed from line
addresses). Output: per-set-tile [hits, misses] counts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

EMPTY = -1  # empty-way tag sentinel


def _cachesim_kernel(setid_ref, tag_ref, out_ref, tags_scr, age_scr,
                     cnt_scr, *, sets_tile: int, ways: int, trace_len: int):
    s0 = pl.program_id(0) * sets_tile

    tags_scr[...] = jnp.full(tags_scr.shape, EMPTY, tags_scr.dtype)
    age_scr[...] = jnp.zeros_like(age_scr)
    cnt_scr[...] = jnp.zeros_like(cnt_scr)

    set_ids = setid_ref[...]
    tags_in = tag_ref[...]

    def step(t, _):
        sid = set_ids[t] - s0                       # local set row
        tag = tags_in[t]
        in_tile = (sid >= 0) & (sid < sets_tile)
        row = jnp.where(in_tile, sid, 0)
        row_mask = (jax.lax.broadcasted_iota(jnp.int32, (sets_tile, ways), 0)
                    == row) & in_tile               # (sets, ways)
        tags = tags_scr[...]
        ages = age_scr[...]
        hit_mask = row_mask & (tags == tag)
        hit = jnp.any(hit_mask)
        # LRU victim within the row: max age
        row_ages = jnp.where(row_mask, ages, -1)
        victim_flat = jnp.argmax(row_ages.reshape(-1))
        victim_mask = (jax.lax.broadcasted_iota(
            jnp.int32, (sets_tile * ways,), 0) == victim_flat
        ).reshape(sets_tile, ways) & row_mask
        write_mask = jnp.where(hit, hit_mask, victim_mask)
        tags_scr[...] = jnp.where(write_mask, tag, tags)
        # age: touched line -> 0; other lines in the row -> +1
        age_scr[...] = jnp.where(write_mask, 0,
                                 jnp.where(row_mask, ages + 1, ages))
        cnt_scr[0] = cnt_scr[0] + jnp.where(in_tile & hit, 1, 0)
        cnt_scr[1] = cnt_scr[1] + jnp.where(in_tile & ~hit, 1, 0)
        return 0

    jax.lax.fori_loop(0, trace_len, step, 0)
    out_ref[0] = cnt_scr[...]


def cache_sim(set_ids, tags, *, num_sets: int, ways: int,
              sets_tile: int = 128, interpret: bool = False):
    """Simulate an LRU set-associative cache over an access trace.

    Returns (hits, misses) totals.
    """
    T = set_ids.shape[0]
    assert num_sets % sets_tile == 0, (num_sets, sets_tile)
    n_tiles = num_sets // sets_tile
    kernel = functools.partial(_cachesim_kernel, sets_tile=sets_tile,
                               ways=ways, trace_len=T)
    counts = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((T,), lambda i: (0,)),
            pl.BlockSpec((T,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, 2), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((sets_tile, ways), jnp.int32),
            pltpu.VMEM((sets_tile, ways), jnp.int32),
            pltpu.VMEM((2,), jnp.int32),
        ],
        interpret=interpret,
    )(set_ids.astype(jnp.int32), tags.astype(jnp.int32))
    total = counts.sum(axis=0)
    return total[0], total[1]
