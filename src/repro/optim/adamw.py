"""AdamW with optional f32 master weights, global-norm clipping.

Functional, pytree-based (no optax dependency). Optimizer state carries the
same logical axes as the parameters so FSDP sharding extends to m/v/master.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    master_weights: bool = True

    def init(self, params) -> Dict[str, Any]:
        zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        state = {
            "m": jax.tree.map(zeros32, params),
            "v": jax.tree.map(zeros32, params),
            "count": jnp.zeros((), jnp.int32),
        }
        if self.master_weights:
            # jnp.array(copy=True): with f32 params, astype would return
            # the SAME buffer and donating {params, master} through a
            # jitted step then aborts with "donate the same buffer twice"
            state["master"] = jax.tree.map(
                lambda p: jnp.array(p, jnp.float32, copy=True), params)
        return state

    def abstract_state(self, params) -> Dict[str, Any]:
        f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
        state = {
            "m": jax.tree.map(f32, params),
            "v": jax.tree.map(f32, params),
            "count": jax.ShapeDtypeStruct((), jnp.int32),
        }
        if self.master_weights:
            state["master"] = jax.tree.map(f32, params)
        return state

    def state_axes(self, param_axes) -> Dict[str, Any]:
        state = {
            "m": param_axes,
            "v": param_axes,
            "count": (),
        }
        if self.master_weights:
            state["master"] = param_axes
        return state

    def update(self, grads, state, params
               ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9)) \
            if self.clip_norm else 1.0
        grads = jax.tree.map(lambda g: g * scale, grads)

        count = state["count"] + 1
        lr = self.lr(count)
        b1c = 1 - self.b1 ** count.astype(jnp.float32)
        b2c = 1 - self.b2 ** count.astype(jnp.float32)

        new_m = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g,
                             state["m"], grads)
        new_v = jax.tree.map(lambda v, g: self.b2 * v + (1 - self.b2) * g * g,
                             state["v"], grads)

        base = state["master"] if self.master_weights else params

        def step(p, m, v):
            upd = (m / b1c) / (jnp.sqrt(v / b2c) + self.eps)
            return (p.astype(jnp.float32)
                    - lr * (upd + self.weight_decay * p.astype(jnp.float32)))

        new_base = jax.tree.map(step, base, new_m, new_v)
        new_params = jax.tree.map(
            lambda b, p: b.astype(p.dtype), new_base, params)
        new_state = {"m": new_m, "v": new_v, "count": count}
        if self.master_weights:
            new_state["master"] = new_base
        return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))
