"""Error-feedback int8 gradient compression for data-parallel all-reduce.

1-bit-Adam-family technique: per-leaf int8 quantization with a shared
absolute-max scale, the quantization error carried in an error-feedback
buffer so the compression bias vanishes over steps. The all-reduce itself
sums int32-accumulated int8 payloads (8x less link traffic than f32; the
scale exchange is O(1) per leaf).

``compressed_psum`` is the shard_map/vmap-axis building block (a true SUM
by default; pass ``mean=True`` for the data-parallel gradient-mean
convention); ``wrap_optimizer`` adds error feedback around any repro.optim
optimizer, carrying the error buffer inside the optimizer state so it
checkpoints, reshards, and donates with the rest of the train state.  The
fused train window (train/trainer.py) consumes both: per-shard gradients
combine through ``compressed_psum`` under a named data axis, and the
wrapped optimizer keeps the int8 path unbiased over steps.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _unzip_pairs(pairs):
    """Split a pytree of (a, b) leaf tuples into two pytrees."""
    is_pair = lambda x: isinstance(x, tuple)
    return (jax.tree.map(lambda p: p[0], pairs, is_leaf=is_pair),
            jax.tree.map(lambda p: p[1], pairs, is_leaf=is_pair))


def compressed_psum(tree, axis_name: str, *, mean: bool = False):
    """psum a pytree of f32 grads with int8 payload over ``axis_name``.

    Must run under a named mapped axis (shard_map/pmap/vmap). Accumulation
    is int32 (safe for up to ~2^23 shards); the per-leaf scale is
    max-reduced first so all shards quantize against a common scale
    (required for correct summation).

    This is a true SUM (matching its name and ``jax.lax.psum``); the seed
    implementation silently divided by the shard count.  Data-parallel
    gradient averaging is the explicit ``mean=True`` contract.
    """
    # the unused residual is dead-code-eliminated under jit
    return compressed_psum_ef(tree, axis_name, mean=mean)[0]


def compressed_psum_ef(tree, axis_name: str, *, mean: bool = False):
    """``compressed_psum`` that also returns each shard's local residual.

    Returns ``(combined, err)``: ``combined`` is the int8-payload
    sum/mean over ``axis_name`` and ``err`` the THIS-shard quantization
    residual ``x - dequant(quant(x))`` — exactly what error-feedback DP
    banks per worker before the all-reduce, so the combine-stage
    compression bias vanishes over steps instead of accumulating.
    """
    def one(x):
        xf = x.astype(jnp.float32)
        scale = jax.lax.pmax(jnp.max(jnp.abs(xf)), axis_name) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int32)
        local_deq = q.astype(jnp.float32) * scale
        s = jax.lax.psum(q, axis_name).astype(jnp.float32) * scale
        if mean:
            s = s / jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return s.astype(x.dtype), xf - local_deq

    return _unzip_pairs(jax.tree.map(one, tree))


def init_error_state(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def apply_error_feedback(grads, err_state):
    """Returns (compressed grads incl. carried error, new error state)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = quantize(corrected)
        deq = dequantize(q, scale)
        return deq, corrected - deq

    return _unzip_pairs(jax.tree.map(one, grads, err_state))


@dataclasses.dataclass(frozen=True)
class CompressedOptimizer:
    """Error-feedback int8 wrapper around a repro.optim optimizer.

    State is ``{"inner": <inner opt state>, "err": <f32 error buffers>}``,
    so the error feedback checkpoints/reshards/donates exactly like the
    Adam moments.  Each gradient is quantized exactly ONCE and its
    residual banked where the quantization happened:

      * ``shards == 1`` — ``update`` adds the carried error to the
        incoming (already-reduced) gradient, int8-quantizes it, feeds the
        dequantized value to the inner optimizer, and banks the residual;
      * ``shards > 1`` — ``update`` takes PER-SHARD-group gradients
        (stacked on a leading ``(shards,)`` axis; error buffers carry the
        same axis, i.e. per-worker EF state, data-axis-sharded on a real
        mesh) and combines them through ``compressed_psum_ef(mean=True)``
        under a named data axis, banking each shard's own residual BEFORE
        the reduce — the 1-bit-Adam-family schedule; the combined
        gradient goes to the inner optimizer un-re-quantized.
    """

    inner: Any
    shards: int = 1

    def _err_like(self, p):
        shape = ((self.shards,) + tuple(p.shape) if self.shards > 1
                 else tuple(p.shape))
        return shape

    def init(self, params) -> Dict[str, Any]:
        return {"inner": self.inner.init(params),
                "err": jax.tree.map(
                    lambda p: jnp.zeros(self._err_like(p), jnp.float32),
                    params)}

    def abstract_state(self, params) -> Dict[str, Any]:
        return {"inner": self.inner.abstract_state(params),
                "err": jax.tree.map(
                    lambda p: jax.ShapeDtypeStruct(self._err_like(p),
                                                   jnp.float32),
                    params)}

    def state_axes(self, param_axes) -> Dict[str, Any]:
        err_axes = param_axes
        if self.shards > 1:  # leading per-shard dim lives on the data axis
            err_axes = jax.tree.map(
                lambda a: ("batch",) + tuple(a), param_axes,
                is_leaf=lambda x: isinstance(x, (tuple, list)))
        return {"inner": self.inner.state_axes(param_axes),
                "err": err_axes}

    def update(self, grads, state, params):
        """``grads``: reduced gradients (``shards == 1``) or per-shard
        stacked gradients on a leading ``(shards,)`` axis."""
        if self.shards == 1:
            comp, err = apply_error_feedback(grads, state["err"])
        else:
            def one_shard(g, e):
                corrected = jax.tree.map(
                    lambda gl, el: gl.astype(jnp.float32) + el, g, e)
                return compressed_psum_ef(corrected, "dp", mean=True)

            comp, err = jax.vmap(one_shard, axis_name="dp")(
                grads, state["err"])
            comp = jax.tree.map(lambda x: x[0], comp)  # replicated rows
        new_params, new_inner, metrics = self.inner.update(
            comp, state["inner"], params)
        return new_params, {"inner": new_inner, "err": err}, metrics


def wrap_optimizer(opt, shards: int = 1) -> CompressedOptimizer:
    """Error-feedback int8 compression around ``opt`` (see class above)."""
    if shards < 1:
        raise ValueError("shards must be >= 1")
    return CompressedOptimizer(inner=opt, shards=shards)
