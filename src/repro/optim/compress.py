"""Error-feedback int8 gradient compression for data-parallel all-reduce.

1-bit-Adam-family technique: per-leaf int8 quantization with a shared
absolute-max scale, the quantization error carried in an error-feedback
buffer so the compression bias vanishes over steps. The all-reduce itself
sums int32-accumulated int8 payloads (8x less link traffic than f32; the
scale exchange is O(1) per leaf).

``compressed_psum`` is the shard_map building block; ``wrap_optimizer``
adds error feedback around any repro.optim optimizer.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(tree, axis_name: str):
    """psum a pytree of f32 grads with int8 payload over ``axis_name``.

    Must run inside shard_map/pmap. Accumulation is int32 (safe for up to
    ~2^23 shards); the per-leaf scale is max-reduced first so all shards
    quantize against a common scale (required for correct summation).
    """
    def one(x):
        xf = x.astype(jnp.float32)
        scale = jax.lax.pmax(jnp.max(jnp.abs(xf)), axis_name) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int32)
        s = jax.lax.psum(q, axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return (s.astype(jnp.float32) * scale / n).astype(x.dtype)

    return jax.tree.map(one, tree)


def init_error_state(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def apply_error_feedback(grads, err_state):
    """Returns (compressed grads incl. carried error, new error state)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = quantize(corrected)
        deq = dequantize(q, scale)
        return deq, corrected - deq

    pairs = jax.tree.map(one, grads, err_state)
    comp = jax.tree.map(lambda p: p[0], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda p: p[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return comp, err
