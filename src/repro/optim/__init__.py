from repro.optim.adamw import AdamW, global_norm
from repro.optim.compress import CompressedOptimizer, wrap_optimizer
from repro.optim.schedule import constant, warmup_cosine

__all__ = ["AdamW", "global_norm", "constant", "warmup_cosine",
           "CompressedOptimizer", "wrap_optimizer"]
