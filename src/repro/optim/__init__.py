from repro.optim.adamw import AdamW, global_norm
from repro.optim.schedule import constant, warmup_cosine

__all__ = ["AdamW", "global_norm", "constant", "warmup_cosine"]
