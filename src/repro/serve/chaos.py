"""Deterministic fault injection for the serve stack (DESIGN.md §16).

A ``FaultPlan`` is a seeded list of ``Fault``s bound to an engine via
``Engine(fault_plan=...)``.  The engine calls ``plan.on_site(site, eng)``
at three named sites every ``step()``; the plan counts visits per site
and fires each fault inside its ``[at, at + count)`` visit window.

Fault-site catalog (the full catalog, including the driver-level faults
the harness injects itself, is in DESIGN.md §16):

    site "pre_admit"     — before admission plans page reservations
        pool_exhaust : steal up to ``pages`` free pages for ``hold``
                       admission rounds (the pool really runs dry; the
                       stolen refs are reported by ``held_refs()`` so
                       conservation checks stay exact)
        cow_storm    : force ``pages`` extra CoW device copies from
                       random live pages (transient alloc+copy+release)
    site "pre_window"    — after page-table upload, before the window
        nan_logits   : set the engine's poison operand for one slot —
                       that row's logits become NaN for one window
        kv_corrupt   : overwrite one slot's state with NaN directly in
                       device cache (positioned KV banks: position 0 of
                       the slot row; positionless recurrent/enc banks:
                       the whole row; paged: the slot's first page,
                       which may be tree-shared)
    site "window_launch" — inside the watchdog's primary attempt
        window_stall : raise ``InjectedFault`` before the jitted call
                       (donated buffers stay alive, so the watchdog
                       retry/degrade path is exercised for real)

Only written-and-attended KV positions are corrupted (position 0 is
always both), so a fault deterministically surfaces as non-finite
logits in the window health check rather than depending on how a
kernel masks garbage lanes it never reads.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

SITES = ("pre_admit", "pre_window", "window_launch")

KIND_SITE = {
    "pool_exhaust": "pre_admit",
    "cow_storm": "pre_admit",
    "nan_logits": "pre_window",
    "kv_corrupt": "pre_window",
    "window_stall": "window_launch",
}


class InjectedFault(RuntimeError):
    """Raised by ``window_stall`` faults; the watchdog absorbs it."""


@dataclasses.dataclass
class Fault:
    """One fault: ``kind`` fires on site visits ``[at, at + count)``.

    ``slot`` pins nan_logits/kv_corrupt to a slot (None = random live
    slot); ``pages`` sizes pool_exhaust steals and cow_storm copies
    (0 = everything free / a default burst); ``hold`` is how many
    pre_admit rounds a pool_exhaust steal is held before release.
    """
    kind: str
    at: int = 0
    count: int = 1
    slot: Optional[int] = None
    pages: int = 0
    hold: int = 2

    def __post_init__(self):
        if self.kind not in KIND_SITE:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; known: "
                f"{sorted(KIND_SITE)}")
        if self.at < 0 or self.count < 1 or self.hold < 0:
            raise ValueError(
                f"fault {self.kind}: need at >= 0, count >= 1, hold >= 0")


class FaultPlan:
    """Seeded, visit-counted fault schedule attached to one engine run.

    Deterministic by construction: site visit counters (not wall time)
    decide when faults fire, and the only randomness (picking a victim
    slot / CoW sources) comes from the plan's own seeded generator.
    ``injected`` counts fires per kind; ``log`` records (kind, site,
    visit) tuples; ``held_refs()`` exposes pages the plan is currently
    holding so ``PagePool.check`` conservation stays exact mid-chaos;
    ``release_held()`` returns them (the harness calls it before final
    conservation-at-rest checks).
    """

    def __init__(self, faults, seed: int = 0):
        self.faults: List[Fault] = list(faults)
        self.rng = np.random.default_rng(seed)
        self.visits: Counter = Counter()
        self.injected: Counter = Counter()
        self.log: List[tuple] = []
        self._holds: List[dict] = []   # {"pages", "pool", "release_at"}

    # ---- accounting -----------------------------------------------------
    def held_refs(self) -> Counter:
        """page -> refs currently held by pool_exhaust steals."""
        c: Counter = Counter()
        for h in self._holds:
            c.update(h["pages"])
        return c

    def release_held(self) -> int:
        """Return every stolen page to its pool; returns pages freed."""
        n = 0
        for h in self._holds:
            for p in h["pages"]:
                h["pool"].release(p)
                n += 1
        self._holds.clear()
        return n

    # ---- engine hook ----------------------------------------------------
    def on_site(self, site: str, engine) -> None:
        v = self.visits[site]
        self.visits[site] += 1
        if site == "pre_admit":
            self._release_due(v)
        for f in self.faults:
            if KIND_SITE[f.kind] != site:
                continue
            if not (f.at <= v < f.at + f.count):
                continue
            self.injected[f.kind] += 1
            self.log.append((f.kind, site, v))
            getattr(self, f"_do_{f.kind}")(f, engine)

    def _release_due(self, visit: int) -> None:
        due = [h for h in self._holds if h["release_at"] <= visit]
        for h in due:
            for p in h["pages"]:
                h["pool"].release(p)
            self._holds.remove(h)

    # ---- injectors ------------------------------------------------------
    def _pick_slot(self, f: Fault, engine) -> Optional[int]:
        live = [s for s, r in enumerate(engine.slot_req) if r is not None]
        if not live:
            return None
        if f.slot is not None:
            return f.slot if engine.slot_req[f.slot] is not None else live[0]
        return live[int(self.rng.integers(len(live)))]

    def _do_nan_logits(self, f: Fault, engine) -> None:
        s = self._pick_slot(f, engine)
        if s is not None:
            engine._poison_host[s] = True

    def _do_kv_corrupt(self, f: Fault, engine) -> None:
        s = self._pick_slot(f, engine)
        if s is None:
            return
        if hasattr(engine, "_slot_pages"):            # paged cache
            pages = engine._slot_pages[s]
            if not pages:
                return
            page = int(pages[0])
            engine.cache = {
                k: v.at[:, page, :1].set(jnp.nan)
                for k, v in engine.cache.items()}
        else:                                         # dense slot banks
            banks = getattr(engine, "_banks", {})
            cache = dict(engine.cache)
            for k, v in cache.items():
                if not jnp.issubdtype(v.dtype, jnp.floating):
                    continue        # e.g. ring position rows (int32)
                b = banks.get(k)
                ba = b.batch_axis if b is not None else 1
                idx = [slice(None)] * v.ndim
                idx[ba] = s
                if b is not None and b.seq_axis is not None:
                    # positioned bank: only position 0 (always written
                    # and attended) so the fault surfaces deterministically
                    idx[b.seq_axis] = slice(0, 1)
                # positionless recurrent/enc banks: the whole row is read
                # every tick, so poison it all
                cache[k] = v.at[tuple(idx)].set(jnp.nan)
            engine.cache = cache

    def _do_pool_exhaust(self, f: Fault, engine) -> None:
        pool = getattr(engine, "pool", None)
        if pool is None:                              # dense engine: no-op
            return
        n = pool.free_pages if f.pages <= 0 else min(f.pages,
                                                     pool.free_pages)
        if n == 0:
            return
        pages = pool.alloc(n)
        self._holds.append({
            "pages": pages, "pool": pool,
            "release_at": self.visits["pre_admit"] + f.hold})

    def _do_cow_storm(self, f: Fault, engine) -> None:
        pool = getattr(engine, "pool", None)
        if pool is None:
            return
        live = [p for sp in engine._slot_pages for p in sp]
        n = min(f.pages if f.pages > 0 else 2, pool.free_pages)
        if n == 0 or not live:
            return
        # copy live page contents into scratch pages, then free them:
        # real device CoW traffic (and cow_copies accounting) with no
        # net allocation — pure pressure on the copy path
        scratch = pool.alloc(n)
        srcs = [live[int(self.rng.integers(len(live)))] for _ in range(n)]
        engine.cache = engine._cow_jit(
            engine.cache, jnp.asarray(srcs, jnp.int32),
            jnp.asarray(scratch, jnp.int32))
        engine.stats["cow_copies"] += n
        pool.cow_copies += n
        for p in scratch:
            pool.release(p)

    def _do_window_stall(self, f: Fault, engine) -> None:
        raise InjectedFault(
            f"injected window stall "
            f"(launch visit {self.visits['window_launch'] - 1})")
