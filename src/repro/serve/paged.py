"""Host-side bookkeeping for the paged KV cache (DESIGN.md §15).

Two pieces, both pure-Python/numpy (no jax): a reference-counted
``PagePool`` over a fixed set of physical KV pages, and a
path-compressed ``RadixTree`` of previously served prompts whose nodes
pin the pages covering their prefix.  The serve engine maps a new
request's shared prefix straight out of the tree (bumping refcounts),
prefills only the unshared suffix, and copy-on-writes the boundary
page when the suffix starts mid-page.

Conventions shared with the device side (``models/attention.py`` and
``kernels/paged_attention.py``):

- physical pages are indexed ``0 .. num_pages-1``; the *device* pool
  has one extra trailing page (index ``num_pages``) reserved as the
  TRASH page — never allocated here, used as the scatter target for
  masked/inactive rows so writes are race-free without predication.
- a page holds ``page_size`` consecutive token positions; a slot's
  page table maps logical page ``i`` (positions ``[i*ps, (i+1)*ps)``)
  to a physical page.
"""
from __future__ import annotations

from collections import Counter

import numpy as np


def pages_for(tokens: int, page_size: int) -> int:
    """Number of pages covering positions ``[0, tokens)``."""
    if tokens <= 0:
        return 0
    return -(-int(tokens) // int(page_size))


class PagePoolExhausted(RuntimeError):
    """``alloc`` could not satisfy a request; carries the shortfall.

    The engine converts this into a shed-or-defer decision at admission
    (never head-of-line blocking); the chaos harness injects it on
    purpose by stealing pages.
    """

    def __init__(self, requested: int, free: int, num_pages: int):
        self.requested = int(requested)
        self.free = int(free)
        self.num_pages = int(num_pages)
        super().__init__(
            f"page pool exhausted: requested {requested} pages, "
            f"{free} free of {num_pages}")


class PagePool:
    """Reference-counted allocator over ``num_pages`` physical pages.

    Invariants (checked by ``check()`` and the hypothesis suite):
    every page is either on the free list with refcount 0 or allocated
    with refcount >= 1; ``alloc`` never hands out a live page; a page
    returns to the free list exactly when its refcount hits 0.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages <= 0 or page_size <= 0:
            raise ValueError("num_pages and page_size must be positive")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.refcount = np.zeros(self.num_pages, np.int64)
        # pop() hands out ascending page ids (cosmetic, aids debugging)
        self._free = list(range(self.num_pages - 1, -1, -1))
        self.hwm = 0            # pages-in-use high-water mark
        self.cow_copies = 0     # bumped by the engine per CoW copy

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.num_pages - len(self._free)

    def alloc(self, n: int) -> list[int]:
        """Claim ``n`` free pages (refcount 1 each).

        Raises ``PagePoolExhausted`` (with requested/free counts) when
        short — callers that can defer catch it; nothing downstream has
        to special-case a bare ``None``.
        """
        if n < 0:
            raise ValueError("cannot allocate a negative page count")
        if len(self._free) < n:
            raise PagePoolExhausted(n, len(self._free), self.num_pages)
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            if self.refcount[p] != 0:
                raise AssertionError(f"free list held live page {p}")
            self.refcount[p] = 1
        self.hwm = max(self.hwm, self.in_use)
        return pages

    def share(self, page: int) -> None:
        """Add a reference to an already-live page."""
        if self.refcount[page] <= 0:
            raise ValueError(f"share() on dead page {page}")
        self.refcount[page] += 1

    def release(self, page: int) -> None:
        """Drop one reference; the page frees when the count hits 0."""
        if self.refcount[page] <= 0:
            raise ValueError(f"release() on dead page {page}")
        self.refcount[page] -= 1
        if self.refcount[page] == 0:
            self._free.append(int(page))

    def check(self, external_refs: Counter | None = None) -> None:
        """Assert pool invariants; with ``external_refs`` (page -> count
        held by slots + radix nodes) also assert exact conservation."""
        free = set(self._free)
        if len(free) != len(self._free):
            raise AssertionError("duplicate page on the free list")
        for p in range(self.num_pages):
            rc = int(self.refcount[p])
            if rc < 0:
                raise AssertionError(f"negative refcount on page {p}")
            if (rc == 0) != (p in free):
                raise AssertionError(
                    f"page {p}: refcount {rc} vs free-list {p in free}")
        if external_refs is not None:
            for p in range(self.num_pages):
                if int(self.refcount[p]) != external_refs.get(p, 0):
                    raise AssertionError(
                        f"page {p}: refcount {int(self.refcount[p])} != "
                        f"{external_refs.get(p, 0)} external refs")


class _Node:
    __slots__ = ("edge", "children", "pages", "depth", "last_used")

    def __init__(self, edge, depth, pages):
        self.edge = tuple(edge)         # tokens from parent to here
        self.children = {}              # first edge token -> _Node
        self.pages = tuple(pages)       # pages covering positions [0, depth)
        self.depth = int(depth)
        self.last_used = 0


def _lcp(a, b) -> int:
    n = min(len(a), len(b))
    i = 0
    while i < n and a[i] == b[i]:
        i += 1
    return i


class RadixTree:
    """Path-compressed trie of served prompts pinning their KV pages.

    Each node holds one pool reference per page in its own ``pages``
    tuple (symmetric register/release — refcounts are inflated along a
    root-to-leaf chain but exactly conserved, which is what the
    hypothesis suite checks).  ``match`` walks greedily, including
    partway down an edge; a partial match returns the child's pages
    truncated to the matched coverage — the boundary page may contain
    the *original* branch's tokens past the match point, which is safe
    because the engine CoWs mid-page boundaries and attention masks
    every position past a row's own depth.
    """

    def __init__(self, pool: PagePool):
        self.pool = pool
        self._root = _Node((), 0, ())
        self._clock = 0

    # -- internals -----------------------------------------------------------

    def _touch(self, node: _Node) -> None:
        self._clock += 1
        node.last_used = self._clock

    def _register(self, node: _Node) -> None:
        for p in node.pages:
            self.pool.share(p)

    def _nodes(self):
        stack = [self._root]
        while stack:
            n = stack.pop()
            if n is not self._root:
                yield n
            stack.extend(n.children.values())

    # -- queries -------------------------------------------------------------

    @property
    def num_nodes(self) -> int:
        return sum(1 for _ in self._nodes())

    def held_refs(self) -> Counter:
        """page -> number of references held by tree nodes."""
        c = Counter()
        for n in self._nodes():
            c.update(n.pages)
        return c

    def match(self, tokens) -> tuple[int, list[int]]:
        """Longest stored prefix of ``tokens``: (matched_len, pages).

        ``pages`` covers positions ``[0, matched_len)`` (caller bumps
        refcounts when it maps them).  Touches every node on the path
        for LRU.
        """
        tokens = tuple(tokens)
        cur, depth = self._root, 0
        pages: tuple = ()
        while depth < len(tokens):
            child = cur.children.get(tokens[depth])
            if child is None:
                break
            common = _lcp(child.edge, tokens[depth:])
            if common == 0:
                break
            depth += common
            self._touch(child)
            pages = child.pages
            if common < len(child.edge):
                break
            cur = child
        matched = min(depth, len(tokens))
        return matched, list(pages[:pages_for(matched, self.pool.page_size)])

    # -- updates -------------------------------------------------------------

    def insert(self, tokens, pages) -> int:
        """Register ``tokens`` whose KV lives in ``pages`` (covering
        ``[0, len(tokens))``).  Returns the number of new nodes; every
        new node takes its own pool reference on each page it covers.
        """
        tokens = tuple(tokens)
        pages = tuple(pages)
        ps = self.pool.page_size
        if len(pages) != pages_for(len(tokens), ps):
            raise ValueError(
                f"insert(): {len(pages)} pages cannot cover "
                f"{len(tokens)} tokens at page_size={ps}")
        cur, depth, created = self._root, 0, 0
        while depth < len(tokens):
            rest = tokens[depth:]
            child = cur.children.get(rest[0])
            if child is None:
                leaf = _Node(rest, len(tokens), pages)
                self._register(leaf)
                self._touch(leaf)
                cur.children[rest[0]] = leaf
                return created + 1
            common = _lcp(child.edge, rest)
            if common == len(child.edge):
                depth += common
                self._touch(child)
                cur = child
                continue
            # split child's edge at the divergence point
            mid = _Node(child.edge[:common], depth + common,
                        child.pages[:pages_for(depth + common, ps)])
            self._register(mid)
            self._touch(mid)
            child.edge = child.edge[common:]
            mid.children[child.edge[0]] = child
            cur.children[mid.edge[0]] = mid
            created += 1
            depth += common
            cur = mid
        return created

    def evict(self, need_free: int) -> int:
        """LRU-evict leaves until the pool has ``need_free`` free pages
        (or nothing is left to evict).  Returns pages actually freed.
        A freed leaf may expose its parent as the next LRU leaf.
        """
        freed = 0
        while self.pool.free_pages < need_free:
            leaf, parent = None, None
            stack = [(self._root, None)]
            while stack:
                n, par = stack.pop()
                if n is not self._root and not n.children:
                    if leaf is None or n.last_used < leaf.last_used:
                        leaf, parent = n, par
                stack.extend((c, n) for c in n.children.values())
            if leaf is None:
                break
            before = self.pool.free_pages
            for p in leaf.pages:
                self.pool.release(p)
            del parent.children[leaf.edge[0]]
            freed += self.pool.free_pages - before
        return freed

    def clear(self) -> None:
        for n in list(self._nodes()):
            for p in n.pages:
                self.pool.release(p)
        self._root = _Node((), 0, ())
