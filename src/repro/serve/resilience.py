"""Resilience primitives for the serve stack (DESIGN.md §16).

Three pieces, all host-side and dependency-free so both engines and the
chaos harness (serve/chaos.py) can share them:

* **Terminal states.**  Every ``Request`` ends in exactly one of
  ``DONE / SHED / TIMED_OUT / FAILED`` (the canonical statement of the
  semantics lives on ``Request`` itself, serve/engine.py).  ``DONE`` is
  the only state that sets ``Request.done`` — telemetry percentiles keep
  meaning "served to completion" — while the other three are *served
  outcomes* too: a shed request was handled (rejected), not lost, so
  drain loops and ``run_arrivals`` treat any terminal request as
  finished work.

* **ShedPolicy.**  Deadline-aware admission control with queue-depth
  backpressure: ``max_queue_depth`` sheds at submit time (the client
  gets an immediate reject instead of an unbounded queue), deadlines are
  enforced both while queued (expired requests never admit) and while
  running (mid-decode timeouts release the slot and keep the partial
  output), ``max_retries`` bounds health-check quarantine retries, and
  ``max_defers`` converts page-pool-exhausted admission deferrals into
  sheds instead of head-of-line blocking forever.

* **WindowWatchdog.**  Bounded retry + exponential backoff around the
  jitted decode window: a poisoned compile or injected stall retries
  ``max_attempts`` times and then *degrades* to the eager reference
  path via the caller's fallback instead of hanging ``run()``.  An
  optional ``timeout_s`` runs each attempt on a daemon thread and
  abandons it on expiry (the thread cannot be killed, but the engine
  stops waiting on it).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Optional

# ---- terminal states --------------------------------------------------------

PENDING = "PENDING"      # created, not yet submitted
QUEUED = "QUEUED"        # in an engine's admission queue
RUNNING = "RUNNING"      # admitted into a slot, decoding

DONE = "DONE"            # served to completion (the only state with done=True)
SHED = "SHED"            # rejected by admission control (backpressure/defers)
TIMED_OUT = "TIMED_OUT"  # deadline expired (queued or mid-decode)
FAILED = "FAILED"        # malformed request or retry budget exhausted

TERMINAL_STATES = frozenset({DONE, SHED, TIMED_OUT, FAILED})


@dataclasses.dataclass
class ShedPolicy:
    """Admission-control knobs for the serve engines.

    The default policy is permissive — no backpressure, no defer cap —
    but still honors per-request deadlines (setting ``Request.deadline``
    is an explicit opt-in) and bounds quarantine retries, so an engine
    without an explicit policy behaves exactly like the pre-resilience
    engine on deadline-free traffic.
    """
    max_queue_depth: Optional[int] = None   # submit-time backpressure
    enforce_deadlines: bool = True          # queued AND mid-decode expiry
    max_retries: int = 2                    # health-check quarantine retries
    max_defers: Optional[int] = None        # pool-exhausted defers before SHED


class WatchdogError(RuntimeError):
    """Raised when every watchdog attempt failed and no fallback exists."""


@dataclasses.dataclass
class WindowWatchdog:
    """Bounded retry + backoff wrapper for one hazardous callable.

    ``call`` runs ``primary`` up to ``max_attempts`` times, sleeping
    ``backoff_s * backoff_factor**attempt`` between failures; when every
    attempt fails it runs ``fallback`` (the degrade path) or raises
    ``WatchdogError`` chaining the last error.  With ``timeout_s`` set,
    each attempt runs on a daemon thread and an attempt that outlives
    the budget is abandoned and counted as a failure — a stalled device
    call stops blocking the engine loop even though the thread itself
    cannot be interrupted.
    """
    max_attempts: int = 3
    backoff_s: float = 0.01
    backoff_factor: float = 2.0
    timeout_s: Optional[float] = None

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.backoff_s < 0 or self.backoff_factor < 1.0:
            raise ValueError("backoff_s must be >= 0 and factor >= 1")

    def call(self, primary: Callable, fallback: Optional[Callable] = None,
             label: str = "", on_retry: Optional[Callable] = None):
        delay = self.backoff_s
        last: Optional[BaseException] = None
        for attempt in range(self.max_attempts):
            try:
                return self._attempt(primary)
            except Exception as e:   # noqa: BLE001 - bounded, re-raised below
                last = e
                if on_retry is not None:
                    on_retry(attempt, e)
                if attempt + 1 < self.max_attempts and delay > 0:
                    time.sleep(delay)
                    delay *= self.backoff_factor
        if fallback is not None:
            return fallback()
        raise WatchdogError(
            f"{label or 'watchdog'}: all {self.max_attempts} attempts "
            f"failed ({last!r})") from last

    def _attempt(self, fn: Callable):
        if self.timeout_s is None:
            return fn()
        box: dict = {}

        def runner():
            try:
                box["value"] = fn()
            except BaseException as e:   # noqa: BLE001 - re-raised on caller
                box["error"] = e

        t = threading.Thread(target=runner, daemon=True)
        t.start()
        t.join(self.timeout_s)
        if t.is_alive():
            raise WatchdogError(
                f"attempt exceeded timeout {self.timeout_s}s "
                "(thread abandoned)")
        if "error" in box:
            raise box["error"]
        return box["value"]
