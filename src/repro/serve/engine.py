"""Device-resident continuous-batching serve engine.

Every piece of per-slot decode state — last token, write position,
temperature, active flag, remaining-token budget — lives as a (slots,)
device array that never leaves the device between host syncs, and one
jitted, cache-donating window fuses K engine ticks (decode + sampling +
termination + slot-free masking).  The state machine (DESIGN.md §11):

  admit  (host, at sync points): free slots x queued requests -> ONE
         batched chunked prefill through ``model.prefill``; whole prompt
         KV blocks land in the assigned cache rows via a masked scatter
         that leaves every other row bit-identical.  (The seed path
         prefilled one token at a time and broadcast each token's KV into
         EVERY slot's cache at that position — the corruption regression-
         tested in tests/test_serve_engine.py.)  The same program samples
         each request's first token from its last prompt position's
         logits and writes the admitted rows of the slot-state arrays.
  decode (device, K fused ticks): ``jax.lax.scan`` over ticks inside one
         jit; each tick decodes all slots at their OWN positions
         (attention.decode_attention), samples greedy/temperature,
         advances budgets, and masks finished slots — a finished row
         emits -1 and stops mutating its state.  Cache and state are
         donated through the window, so they stay device-resident.
  drain  (host, every K ticks): the (K, slots) token/finish buffers come
         back in one transfer; outputs append, finished slots free, new
         requests admit.

The engine also closes the loop to the paper: the compiled tick's roofline
terms (launch/roofline.py) accumulate into dry-run-shaped records
(``serve_records``) so ``core.crosslayer.analyze_serve`` scores SRAM vs
STT/SOT-MRAM tiers on the engine's REAL decode traffic — decode is the
memory-bound regime where DeepNVM++ predicts MRAM pays off most.

``EngineReference`` keeps the seed per-tick path (per-token prefill, one
host round-trip per tick) as the correctness oracle and benchmark baseline.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model, serve_families
from repro.serve.paged import (PagePool, PagePoolExhausted, RadixTree,
                               pages_for)
from repro.serve.resilience import (DONE, FAILED, PENDING, QUEUED, RUNNING,
                                    SHED, TERMINAL_STATES, TIMED_OUT,
                                    ShedPolicy, WindowWatchdog)


class UnsupportedFamilyError(ValueError):
    """Raised at ENGINE CONSTRUCTION for a model family the engine cannot
    serve, naming the family and the supported set (DESIGN.md §17) —
    instead of a generic ValueError deep inside a forward pass
    mid-request.  Subclasses ValueError so pre-existing callers that
    catch broadly keep working."""

    def __init__(self, family: str, supported, engine: str,
                 detail: str = ""):
        self.family = family
        self.supported = tuple(sorted(supported))
        msg = (f"{engine} does not support model family {family!r} "
               f"(supported families: {', '.join(self.supported)})")
        if detail:
            msg += f"; {detail}"
        super().__init__(msg)


def _where_rows(mask, new, old, axis):
    """Row-masked merge: keep ``new`` where ``mask`` (a (B,) bool over the
    bank's slot axis ``axis``) else ``old``, other axes broadcast."""
    m = mask.reshape(tuple(-1 if d == axis else 1
                           for d in range(old.ndim)))
    return jnp.where(m, new, old)


def _reset_rows(cache, mask, banks, resets):
    """Re-initialize the GUARDED (recurrent/ring) bank rows selected by
    ``mask``; kv/enc banks and every unselected row stay bitwise intact.
    ``resets[name]`` is the bank's init fill value (e.g. -1 for the ring
    position bank, 0 elsewhere)."""
    out = dict(cache)
    for n, b in banks.items():
        if b.kind not in ("recurrent", "ring"):
            continue
        out[n] = _where_rows(mask, jnp.full_like(out[n], resets[n]),
                             out[n], b.batch_axis)
    return out


@dataclasses.dataclass
class Request:
    """One serve request, carrying its own latency record.

    Tick-domain semantics (canonical for BOTH engines; parity-enforced in
    tests/test_serve_engine.py so tick-domain TTFT/TPOT is comparable
    across ``Engine`` and ``EngineReference``):

      * ``engine.ticks`` counts completed DECODE ticks since reset.
        Admission (prefill) happens at host sync points and does not
        advance the tick clock.
      * A request admitted at tick ``T`` gets ``admit_tick = T``.  Its
        prefill-sampled first token t0 is emitted at tick ``T`` as well
        (``first_token_tick = T``): the admission sync point and the
        window's first decode tick share a tick, exactly as in the seed
        per-tick ``step()``.
      * Decode token ``i`` (0-indexed in ``output``, ``i >= 1``) is
        emitted at tick ``T + i - 1``, so ``done_tick`` — the tick of the
        FINAL emitted token — is ``T + len(output) - 2`` for multi-token
        outputs and ``T`` for a request that terminates at prefill
        (``max_new_tokens == 1``, immediate eos, or a full cache).

    Wall-clock stamps (``*_time``, ``time.perf_counter`` seconds) are
    taken when the host actually OBSERVES the event: ``first_token_time``
    when the admission prefill's tokens land on the host, ``done_time``
    at the drain that surfaces the final token — so wall-clock TTFT/TPOT
    include the K-tick drain cadence a client would really see.
    ``arrival`` is the intended arrival time in ticks for traffic-
    generator workloads (``serve/workload.py``); tick-domain latencies
    are measured from it when set, else from ``submit_tick``.

    Terminal-state semantics (canonical; DESIGN.md §16).  ``state``
    walks ``PENDING -> QUEUED -> RUNNING`` and ends in EXACTLY one of:

      * ``DONE`` — served to completion.  The only state that sets
        ``done=True``; ``output`` is the full bitwise-deterministic
        greedy answer.
      * ``SHED`` — rejected by admission control: queue-depth
        backpressure at submit, or page-pool defers past
        ``ShedPolicy.max_defers``.  ``output`` is empty.
      * ``TIMED_OUT`` — ``deadline`` (absolute engine tick) expired
        while queued (empty output) or mid-decode (``output`` is a
        prefix of the request's reference output — greedy decoding is
        schedule-independent, so partial work is still exact).
      * ``FAILED`` — malformed at submit (``_check_request``) or the
        health-check quarantine retry budget ran out.

    A terminal request never transitions again (``_finalize`` is
    idempotent); ``done_tick``/``done_time`` stamp the tick/wall time
    the terminal state was reached, whatever it was, and ``reason``
    says why for the non-DONE states.  Requeued work (quarantine
    retries, preemption, crash-resubmission) resumes from
    ``prompt + output``: recomputation from a clean prefix is invisible
    in the final tokens.
    """
    uid: int
    prompt: List[int]
    max_new_tokens: int
    temperature: float = 0.0
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    done_tick: Optional[int] = None   # engine tick of the final token
    arrival: Optional[float] = None   # intended arrival (ticks; traffic gen)
    submit_tick: Optional[int] = None
    submit_time: Optional[float] = None
    admit_tick: Optional[int] = None
    admit_time: Optional[float] = None
    first_token_tick: Optional[int] = None
    first_token_time: Optional[float] = None
    done_time: Optional[float] = None
    state: str = PENDING
    reason: Optional[str] = None      # why SHED / TIMED_OUT / FAILED
    deadline: Optional[float] = None  # absolute engine tick; opt-in
    retries: int = 0                  # health-check quarantine requeues
    preemptions: int = 0              # preempt_slot requeues
    defers: int = 0                   # pool-exhausted admission defers

    @property
    def terminal(self) -> bool:
        return self.state in TERMINAL_STATES

    def _mark_admitted(self, tick: int, now: float) -> None:
        """Stamp admission == first-token emission (see class docstring);
        both engines route through here so the tick domains cannot drift.
        Stamps only the FIRST admission: a requeued request (retry /
        preemption) keeps its original TTFT."""
        self.state = RUNNING
        if self.admit_tick is None:
            self.admit_tick = self.first_token_tick = tick
            self.admit_time = self.first_token_time = now

    def _finalize(self, state: str, tick: int, now: float,
                  reason: Optional[str] = None) -> None:
        """Enter a terminal state exactly once (later calls are no-ops).
        ``done_tick``/``done_time`` stamp the terminal event for every
        state; ``done`` flips only for DONE so telemetry percentiles
        keep meaning served-to-completion."""
        if self.terminal:
            return
        self.state = state
        self.reason = reason
        if state == DONE:
            self.done = True
        self.done_tick = tick
        self.done_time = now

    def _mark_done(self, tick: int, now: float) -> None:
        self._finalize(DONE, tick, now)


def _sample_tokens(logits: jax.Array, temps: jax.Array,
                   key: jax.Array) -> jax.Array:
    """Greedy / temperature sampling over (B, V) f32 logits -> (B,) i32."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits / jnp.maximum(temps[:, None], 1e-6)
    sampled = jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)
    return jnp.where(temps > 0, sampled, greedy)


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _check_request(req: Request, max_len: int) -> None:
    if not req.prompt:
        raise ValueError(f"request {req.uid}: empty prompt")
    if len(req.prompt) > max_len:
        raise ValueError(
            f"request {req.uid}: prompt length {len(req.prompt)} exceeds "
            f"max_len {max_len}")
    if req.max_new_tokens < 1:
        raise ValueError(f"request {req.uid}: max_new_tokens must be >= 1")


def _unfinished(engine) -> int:
    """Requests not yet done: still queued or still occupying a slot."""
    return len(engine._queue) + sum(
        r is not None for r in engine.slot_req)


def _soft_submit(engine, req: Request) -> bool:
    """Shared submit path: NEVER raises for a bad request.  A malformed
    request is marked ``FAILED`` with the validation message as its
    ``reason`` and the engine keeps serving (the caller's loop cannot be
    wedged by one bad client); queue-depth backpressure sheds instead of
    queueing unboundedly.  Returns True iff the request was queued."""
    now = time.perf_counter()
    try:
        _check_request(req, engine.max_len)
    except ValueError as e:
        req._finalize(FAILED, engine.ticks, now, reason=str(e))
        engine._rstats["failed"] += 1
        return False
    if req.submit_tick is None:
        req.submit_tick = engine.ticks
        req.submit_time = now
    pol = engine.shed_policy
    if (pol.max_queue_depth is not None
            and len(engine._queue) >= pol.max_queue_depth):
        req._finalize(
            SHED, engine.ticks, now,
            reason=(f"queue depth {len(engine._queue)} at limit "
                    f"{pol.max_queue_depth}"))
        engine._rstats["shed"] += 1
        return False
    req.state = QUEUED
    engine._queue.append(req)
    return True


def _drop_expired(engine) -> None:
    """Shed queued requests whose deadline already passed — they would
    only waste prefill work to time out mid-decode anyway."""
    if not engine._queue or not engine.shed_policy.enforce_deadlines:
        return
    keep: Deque[Request] = collections.deque()
    now = time.perf_counter()
    while engine._queue:
        r = engine._queue.popleft()
        if r.deadline is not None and engine.ticks > r.deadline:
            r._finalize(
                TIMED_OUT, engine.ticks, now,
                reason=(f"deadline {r.deadline:g} expired in queue at "
                        f"tick {engine.ticks}"))
            engine._rstats["timed_out"] += 1
        else:
            keep.append(r)
    engine._queue = keep


def _drain_until_done(engine, max_ticks: int) -> int:
    """Shared run loop: step until queue + slots are empty or the tick
    budget is spent (both engines share exit semantics by construction).

    The budget is K-granular and NEVER overshoots: a window only runs if
    its full ``ticks_per_sync`` ticks fit inside ``max_ticks`` (the seed
    checked only at window boundaries, so ``run(max_ticks)`` could spend
    up to ``ticks_per_sync - 1`` extra ticks and then return silently
    with unfinished work).  When K does not divide ``max_ticks`` the last
    partial window is NOT run — at most ``floor(max_ticks / K) * K``
    ticks are spent.  Returns the number of unfinished requests.
    """
    start = engine.ticks
    k = engine.ticks_per_sync
    while engine._queue or any(r is not None for r in engine.slot_req):
        if engine.ticks - start + k > max_ticks:
            break
        n = engine.step()
        if n == 0:
            if not engine._queue:
                break
            if engine._last_admitted == 0:
                # resource stall: no slot active and nothing admissible
                # (e.g. chaos-held page pool).  Advance the tick clock so
                # deadlines can expire and the budget check above fires —
                # run() always terminates instead of spinning forever.
                engine.ticks += k
    return _unfinished(engine)


class Engine:
    """Fused continuous-batching engine (see module docstring).

    ``ticks_per_sync`` (K) is the drain cadence: larger K amortizes host
    round-trips over more decode ticks but delays slot reuse to window
    boundaries.  K=1 reproduces the seed's per-tick admission schedule
    (used by the tick-parity tests).  ``record_traffic`` compiles each
    executable a second time to harvest roofline terms for
    ``serve_records``/``nvm_verdicts``.
    """

    DECODE_ATTN_IMPLS = ("xla", "pallas_decode")
    SAMPLE_IMPLS = ("xla", "pallas")

    def __init__(self, model: Model, params, *, slots: int, max_len: int,
                 eos_id: Optional[int] = None, seed: int = 0,
                 ticks_per_sync: int = 8, record_traffic: bool = True,
                 prefill_attn_impl: str = "naive",
                 attn_impl: str = "xla", tracer=None,
                 sample_impl: str = "xla",
                 charge_prefill_ticks: bool = False,
                 shed_policy: Optional[ShedPolicy] = None,
                 watchdog: Optional[WindowWatchdog] = None,
                 fault_plan=None, health_check: bool = True):
        if "dense" not in model.serve_modes:
            raise UnsupportedFamilyError(
                model.cfg.family, serve_families("dense"), "Engine")
        if attn_impl == "pallas_decode" \
                and model.cfg.family not in ("dense", "moe", "vlm"):
            raise ValueError(
                "attn_impl='pallas_decode' requires a stacked-KV decoder "
                f"family (dense/moe/vlm); family {model.cfg.family!r} "
                "decodes through its state banks on the XLA path")
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.seed = seed
        self.ticks_per_sync = int(ticks_per_sync)
        if self.ticks_per_sync < 1:
            raise ValueError("ticks_per_sync must be >= 1")
        self.record_traffic = record_traffic
        # admission chunks are short (P <= max_len); the O(P^2) reference
        # attention beats the flash-scan machinery there, and parity is on
        # greedy argmax, not bitwise logits
        self.prefill_attn_impl = prefill_attn_impl
        # decode-tick attention: "xla" = jnp decode_attention (full-cache
        # broadcast; the parity oracle), "pallas_decode" = blocked Pallas
        # kernel with fused in-launch KV scatter (DESIGN.md §13)
        if attn_impl not in self.DECODE_ATTN_IMPLS:
            raise ValueError(
                f"attn_impl {attn_impl!r} not in {self.DECODE_ATTN_IMPLS}")
        self.attn_impl = attn_impl
        # token sampling: "xla" = argmax + jax.random.categorical (the
        # parity oracle), "pallas" = one-launch fused kernel
        # (kernels/sampling.py; greedy rows bitwise == argmax)
        if sample_impl not in self.SAMPLE_IMPLS:
            raise ValueError(
                f"sample_impl {sample_impl!r} not in {self.SAMPLE_IMPLS}")
        self.sample_impl = sample_impl
        # opt-in tick-domain prefill accounting: each admission charges
        # ceil(prefilled_tokens / slots) ticks BEFORE stamping the admitted
        # requests, so tick-domain TTFT reflects prompt-processing cost
        # (benchmarks enable it on both legs to expose prefix-sharing wins)
        self.charge_prefill_ticks = bool(charge_prefill_ticks)
        # optional serve.telemetry.Tracer: records prefill / decode-window
        # / host-drain spans for chrome://tracing export (DESIGN.md §14)
        self.tracer = tracer
        # resilience layer (DESIGN.md §16): admission control, bounded
        # window retry, per-slot output health checks, and an optional
        # chaos FaultPlan whose on_site() hooks fire at the named sites
        self.shed_policy = shed_policy if shed_policy is not None \
            else ShedPolicy()
        self.watchdog = watchdog if watchdog is not None else WindowWatchdog()
        self.fault_plan = fault_plan
        self.health_check = bool(health_check)
        self._vocab = int(model.cfg.vocab_size)
        self._decode_attn_impl = (
            "pallas_decode" if attn_impl == "pallas_decode" else "chunked")
        # state-bank metadata (DESIGN.md §17): the per-bank slot/seq axes
        # drive the generic masked scatter, the guarded set names the
        # banks (recurrent/ring) whose rows must be merged under the
        # active mask every tick and re-initialized on slot admit/free
        self._banks = model.state_banks()
        defs = model.cache_defs(slots, max_len)
        self._bank_reset = {
            n: (d.const if d.init == "const" else 0)
            for n, d in defs.items()}
        self._guarded = frozenset(
            n for n, b in self._banks.items()
            if b.kind in ("recurrent", "ring"))
        self._window_jit = jax.jit(self._window, donate_argnums=(1, 2))
        self._deact_jit = jax.jit(
            lambda st, m: dict(st, active=st["active"] & ~m))
        self._prefill_jit = jax.jit(self._prefill_prog,
                                    donate_argnums=(1, 2))
        if self._guarded:
            self._reset_jit = jax.jit(
                lambda c, m: _reset_rows(c, m, self._banks,
                                         self._bank_reset),
                donate_argnums=(0,))
        if model.cfg.family == "encdec":
            # standalone fixed-shape encoder program: BOTH engines call it
            # with (slots, max_len) tokens so the compiled executable — and
            # therefore each row's enc/out bank content — is bitwise
            # identical across Engine and EngineReference
            self._encode_jit = jax.jit(
                lambda p, t, l: model.encode_prompt(p, t, l))
        self._traffic: Dict[str, object] = {"decode": None, "prefill": {}}
        self.reset()

    # ---- state ----------------------------------------------------------
    def _fresh_cache(self):
        """Cache buffers for ``reset`` (PagedEngine swaps in page pools)."""
        return self.model.init_cache(self.slots, self.max_len)

    def reset(self, seed: Optional[int] = None) -> None:
        """Clear cache, slot state, and queue (compiled fns are kept)."""
        self.cache = self._fresh_cache()
        self.key = jax.random.PRNGKey(self.seed if seed is None else seed)
        self.slot_req: List[Optional[Request]] = [None] * self.slots
        self._queue: Deque[Request] = collections.deque()
        self._state = {            # device-resident (slots,) slot state
            "last": jnp.zeros(self.slots, jnp.int32),
            "pos": jnp.zeros(self.slots, jnp.int32),
            "active": jnp.zeros(self.slots, bool),
            "remaining": jnp.zeros(self.slots, jnp.int32),
            "temps": jnp.zeros(self.slots, jnp.float32),
        }
        self.ticks = 0
        self._counts = {"decode_ticks": 0, "prefill_calls": {}}
        self._poison_host = np.zeros(self.slots, bool)   # chaos NaN operand
        self._degraded = False      # sticky eager-window fallback mode
        self._last_admitted = 0     # run-loop stall detection
        self._rstats = {"failed": 0, "shed": 0, "timed_out": 0,
                        "quarantined": 0, "retried": 0, "preempted": 0,
                        "window_retries": 0, "window_fallbacks": 0}

    # ---- device programs ------------------------------------------------
    def _sample_batch(self, lg, temps, sub):
        """Traced sampling dispatch: the two-step XLA path or the fused
        one-launch Pallas kernel (greedy rows bitwise-equal; temperature
        rows same distribution, different draw — kernels/sampling.py)."""
        if self.sample_impl == "pallas":
            from repro.kernels import ops as kernel_ops
            return kernel_ops.fused_sample(lg, temps, sub)
        return _sample_tokens(lg, temps, sub)

    def _decode_kwargs(self, extra) -> dict:
        """Extra ``decode_step`` kwargs built from ``_extra_window_args``
        operands (PagedEngine threads its page table through here)."""
        return {}

    def _window(self, params, cache, state, key, poison, *extra):
        """K fused engine ticks: decode + sample + terminate + mask.

        ``poison`` is a (slots,) bool chaos operand: True rows get their
        logits replaced with NaN for this window (``jnp.where`` with an
        all-False mask is a bitwise no-op, so clean runs are unchanged).
        The per-tick ``ok`` output is the window health check — finite
        logits per row — that the host drain uses to quarantine only the
        offending slots (DESIGN.md §16)."""
        eos_id, max_len = self.eos_id, self.max_len
        decode_kw = self._decode_kwargs(extra)

        def tick(carry, _):
            cache, last, pos, active, remaining, temps, key = carry
            safe_pos = jnp.clip(pos, 0, max_len - 1)
            logits, new_cache = self.model.decode_step(
                params, cache, {"tokens": last[:, None]}, safe_pos,
                attn_impl=self._decode_attn_impl, **decode_kw)
            if self._guarded:
                # recurrent/ring banks advance every step regardless of
                # position, so freeze inactive rows explicitly (KV banks
                # need no merge: reads are position-guarded).  Uses the
                # PRE-update active mask: a row finishing THIS tick keeps
                # this tick's state, matching the reference engine.
                new_cache = {
                    n: (_where_rows(active, new_cache[n], cache[n],
                                    self._banks[n].batch_axis)
                        if n in self._guarded else new_cache[n])
                    for n in new_cache}
            cache = new_cache
            lg = logits[:, -1].astype(jnp.float32)
            lg = jnp.where(poison[:, None], jnp.float32(jnp.nan), lg)
            ok = jnp.isfinite(lg).all(axis=-1)
            key, sub = jax.random.split(key)
            tok = self._sample_batch(lg, temps, sub)
            fin = (remaining - 1 <= 0) | (pos + 1 >= max_len)
            if eos_id is not None:
                fin = fin | (tok == eos_id)
            fin = active & fin
            emit = jnp.where(active, tok, -1)
            last = jnp.where(active, tok, last)
            pos = jnp.where(active, pos + 1, pos)
            remaining = jnp.where(active, remaining - 1, remaining)
            active = active & ~fin
            carry = (cache, last, pos, active, remaining, temps, key)
            return carry, (emit, fin, ok)

        carry = (cache, state["last"], state["pos"], state["active"],
                 state["remaining"], state["temps"], key)
        carry, (toks, fins, oks) = jax.lax.scan(
            tick, carry, None, length=self.ticks_per_sync)
        cache, last, pos, active, remaining, temps, key = carry
        state = {"last": last, "pos": pos, "active": active,
                 "remaining": remaining, "temps": temps}
        return cache, state, key, toks, fins, oks

    def _scatter_bank(self, name, old, new, valid):
        """Masked scatter of a prefill bank: write ``new`` (seq length P)
        into ``old`` where ``valid[row, col]``, along the bank's declared
        batch/seq axes.  Rows not being admitted — in particular rows
        mid-decode — are preserved bit-exactly.  Relies on the StateBank
        contract ``batch_axis < seq_axis`` so the (B, P) mask reshapes
        into the bank's layout directly."""
        bank = self._banks[name]
        ba, sa = bank.batch_axis, bank.seq_axis
        P = new.shape[sa]
        mask = valid.reshape(tuple(
            old.shape[d] if d == ba else (P if d == sa else 1)
            for d in range(old.ndim)))
        idx = tuple(slice(0, P) if d == sa else slice(None)
                    for d in range(old.ndim))
        return old.at[idx].set(
            jnp.where(mask, new.astype(old.dtype), old[idx]))

    def _prefill_scan(self, params, cache, tokens, lens, admit):
        """Masked per-token decode scan: the prefill path for recurrent
        families (ssm/hybrid), whose positionless banks cannot scatter a
        full-sequence prefill cache.  Admitted rows' guarded banks reset
        to init, then every prompt token runs one decode step and the
        result merges ONLY into rows still inside their prompt
        (``admit & (t < lens)``) — other slots, including rows
        mid-decode, stay bitwise untouched, and the final state left in
        each admitted slot is exactly what the reference engine's
        per-token loop computes (rows are computationally independent).
        Returns (cache, last_lg) with each admitted row's logits captured
        at its last prompt position."""
        B, P = tokens.shape
        cache = _reset_rows(cache, admit, self._banks, self._bank_reset)
        lg0 = jnp.zeros((B, self._vocab), jnp.float32)

        def body(carry, xs):
            cache, lg_keep = carry
            tok_t, t = xs
            pos = jnp.full((B,), t, jnp.int32)
            logits, new = self.model.decode_step(
                params, cache, {"tokens": tok_t[:, None]}, pos,
                attn_impl=self._decode_attn_impl)
            live = admit & (t < lens)
            cache = {n: _where_rows(live, new[n], cache[n],
                                    self._banks[n].batch_axis)
                     for n in cache}
            lg = logits[:, -1].astype(jnp.float32)
            lg_keep = jnp.where((t == lens - 1)[:, None], lg, lg_keep)
            return (cache, lg_keep), None

        (cache, last_lg), _ = jax.lax.scan(
            body, (cache, lg0),
            (tokens.T, jnp.arange(P, dtype=jnp.int32)))
        return cache, last_lg

    def _prefill_tail(self, cache, state, lens, admit, max_new, temps_in,
                      key, last_lg):
        """Shared prefill epilogue: sample each admitted row's first
        token, apply the immediate-termination rule, and write the
        admitted rows of the slot state (shared by the dense scatter,
        recurrent scan, and paged suffix paths)."""
        ok0 = jnp.isfinite(last_lg).all(axis=-1)
        key, sub = jax.random.split(key)
        t0 = self._sample_batch(last_lg, temps_in, sub)
        done0 = (max_new - 1 <= 0) | (lens >= self.max_len)
        if self.eos_id is not None:
            done0 = done0 | (t0 == self.eos_id)
        state = {
            "last": jnp.where(admit, t0, state["last"]),
            "pos": jnp.where(admit, lens, state["pos"]),
            "active": jnp.where(admit, ~done0, state["active"]),
            "remaining": jnp.where(admit, max_new - 1, state["remaining"]),
            "temps": jnp.where(admit, temps_in, state["temps"]),
        }
        return cache, state, key, t0, done0, ok0

    def _prefill_prog(self, params, cache, state, tokens, lens, admit,
                      max_new, temps_in, key, *extra):
        """Batched prefill into assigned slots, dispatched per family.

        tokens: (slots, P) right-padded prompts (rows not being admitted
        carry zeros and a False ``admit`` flag).  KV families run ONE
        full-sequence ``model.prefill`` whose banks scatter where
        ``admit[row] & (col < lens[row])``; encdec additionally writes
        the admitted rows of the ``enc/out`` bank from the pre-computed
        encoder operand in ``extra`` before prefilling against it;
        recurrent families (ssm/hybrid) run the masked per-token scan
        (``_prefill_scan``).  In every case non-admitted cache rows —
        in particular rows mid-decode — are preserved bit-exactly.
        Returns (cache, state, key, t0, done0, ok0) — ``ok0`` is the
        admission-time health verdict (finite last-position logits), the
        prefill leg of the window health check."""
        fam = self.model.cfg.family
        if fam in ("ssm", "hybrid"):
            cache, last_lg = self._prefill_scan(
                params, cache, tokens, lens, admit)
            return self._prefill_tail(cache, state, lens, admit, max_new,
                                      temps_in, key, last_lg)
        batch = {"tokens": tokens}
        if fam == "encdec":
            cache = dict(cache)
            cache["enc/out"] = _where_rows(
                admit, extra[0].astype(cache["enc/out"].dtype),
                cache["enc/out"], self._banks["enc/out"].batch_axis)
            batch["enc_out"] = cache["enc/out"]
        P = tokens.shape[1]
        logits, fresh = self.model.prefill(
            params, batch, attn_impl=self.prefill_attn_impl)
        valid = admit[:, None] & (jnp.arange(P)[None, :] < lens[:, None])
        cache = {name: (self._scatter_bank(name, cache[name], fresh[name],
                                           valid)
                        if name in fresh else cache[name])
                 for name in cache}
        idx = jnp.clip(lens - 1, 0, P - 1)
        last_lg = jnp.take_along_axis(
            logits, idx[:, None, None], axis=1)[:, 0].astype(jnp.float32)
        return self._prefill_tail(cache, state, lens, admit, max_new,
                                  temps_in, key, last_lg)

    # ---- traffic accounting --------------------------------------------
    def _analyze(self, jitted, *args):
        """Roofline terms of the compiled executable.  Failures degrade to
        None (the engine keeps serving) but warn loudly — a silently empty
        ``serve_records()`` would erase the NVM-verdict handoff while CI
        stays green."""
        if not self.record_traffic:
            return None
        try:
            from repro.launch import roofline as rf
            return rf.analyze(jitted.lower(*args).compile())
        except Exception as e:  # pragma: no cover - backend-dependent
            import warnings
            warnings.warn(
                f"serve traffic analysis failed ({e!r}); serve_records() "
                "will omit this phase", RuntimeWarning, stacklevel=2)
            return None

    # ---- admission ------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Queue a request; never raises.  Malformed requests finalize as
        ``FAILED`` (reason on the request), backpressure sheds — see
        ``_soft_submit``.  Returns True iff queued."""
        return _soft_submit(self, req)

    def _admit(self) -> int:
        """Admit queued requests into free slots with one batched prefill.

        Requeued requests (quarantine retries, preemptions, crash
        resubmissions) resume from ``prompt + output``: the effective
        prompt re-prefills their already-emitted tokens, and the decode
        budget shrinks by what was already produced — greedy decoding is
        schedule-independent, so the continuation is bitwise what an
        uninterrupted run would have emitted."""
        self._last_admitted = 0
        _drop_expired(self)
        free = [i for i in range(self.slots) if self.slot_req[i] is None]
        take = min(len(free), len(self._queue))
        if take == 0:
            return 0
        pairs = [(free[i], self._queue.popleft()) for i in range(take)]
        eff = {s: list(r.prompt) + list(r.output) for s, r in pairs}
        P = min(self.max_len,
                _next_pow2(max(len(e) for e in eff.values())))
        tokens = np.zeros((self.slots, P), np.int32)
        lens = np.zeros(self.slots, np.int32)
        admit = np.zeros(self.slots, bool)
        max_new = np.ones(self.slots, np.int32)
        temps = np.zeros(self.slots, np.float32)
        for s, r in pairs:
            tokens[s, :len(eff[s])] = eff[s]
            lens[s] = len(eff[s])
            admit[s] = True
            max_new[s] = r.max_new_tokens - len(r.output)
            temps[s] = r.temperature
        extra = ()
        if self.model.cfg.family == "encdec":
            # encoder rows for the enc/out bank: ALWAYS padded to max_len
            # (never the per-wave pow2 P) so the encoder executable — and
            # each row's output — is identical across admission waves and
            # across engines (see Model.encode_prompt)
            toks_full = np.zeros((self.slots, self.max_len), np.int32)
            for s, r in pairs:
                toks_full[s, :len(eff[s])] = eff[s]
            extra = (self._encode_jit(self.params, jnp.asarray(toks_full),
                                      jnp.asarray(lens)),)
        args = (self.params, self.cache, self._state, jnp.asarray(tokens),
                jnp.asarray(lens), jnp.asarray(admit), jnp.asarray(max_new),
                jnp.asarray(temps), self.key, *extra)
        if P not in self._traffic["prefill"]:
            self._traffic["prefill"][P] = self._analyze(
                self._prefill_jit, *args)
        t_launch = time.perf_counter()
        self.cache, self._state, self.key, t0, done0, ok0 = \
            self._prefill_jit(*args)
        self._counts["prefill_calls"][P] = \
            self._counts["prefill_calls"].get(P, 0) + 1
        t0, done0, ok0 = np.asarray(t0), np.asarray(done0), np.asarray(ok0)
        now = time.perf_counter()   # t0/done0 observed on the host
        if self.tracer is not None:
            self.tracer.span(f"prefill P={P}", "prefill", t_launch, now,
                             args={"tick": self.ticks, "admitted": take,
                                   "padded_len": P})
        if self.charge_prefill_ticks:
            self.ticks += -(-int(lens.sum()) // self.slots)
        bad0: Dict[int, int] = {}
        for s, r in pairs:
            self.slot_req[s] = r
            r._mark_admitted(self.ticks, now)
            if self.health_check and not ok0[s]:
                bad0[s] = 0      # poisoned prefill: discard t0, requeue
                continue
            r.output.append(int(t0[s]))
            if done0[s]:
                r._mark_done(self.ticks, now)
                self._release_slot(s)
                self.slot_req[s] = None
        self._last_admitted = take
        if bad0:
            self._quarantine(bad0, now)
        return take

    def _release_slot(self, s: int) -> None:
        """Hook called when slot ``s``'s request finishes, just before the
        slot frees (PagedEngine returns the slot's page references).
        Guarded (recurrent/ring) banks re-initialize ONLY that slot's
        rows — positioned KV needs no reset (reads are pos-guarded), but
        positionless state would otherwise leak into the next occupant's
        prefill scan."""
        if self._guarded:
            mask = np.zeros(self.slots, bool)
            mask[s] = True
            self.cache = self._reset_jit(self.cache, jnp.asarray(mask))

    def _pre_window(self) -> None:
        """Hook called right before a decode window launches (PagedEngine
        uploads a dirty page table and measures page sharing)."""

    def _extra_window_args(self) -> tuple:
        """Extra device operands for ``_window`` (PagedEngine: the page
        table)."""
        return ()

    # ---- resilience -----------------------------------------------------
    def _fire_faults(self, site: str) -> None:
        """Chaos hook: let the attached FaultPlan act at a named site."""
        if self.fault_plan is not None:
            self.fault_plan.on_site(site, self)

    def _deactivate_slots(self, slots) -> None:
        """Clear the device active flag for ``slots`` (quarantine /
        preemption / mid-decode timeout) without touching other rows."""
        mask = np.zeros(self.slots, bool)
        mask[list(slots)] = True
        self._state = self._deact_jit(self._state, jnp.asarray(mask))

    def _stash_prefix(self, s: int, req: Request) -> None:
        """Hook before a preempted slot releases: PagedEngine re-inserts
        the already-written prefix into the radix tree so the requeued
        request re-admits cheaply."""

    def _after_quarantine(self, n: int) -> None:
        """Hook after ``n`` slots were quarantined (PagedEngine flushes
        the radix tree — shared-KV provenance is suspect)."""

    def preempt_slot(self, s: int) -> Request:
        """Kick the request in slot ``s`` back to the FRONT of the queue,
        freeing the slot for other work.  The request resumes from
        ``prompt + output`` on re-admission, so no emitted token is lost
        and greedy continuations stay bitwise-deterministic."""
        r = self.slot_req[s]
        if r is None:
            raise ValueError(f"slot {s} is not occupied")
        self._stash_prefix(s, r)
        self._deactivate_slots([s])
        self._release_slot(s)
        self.slot_req[s] = None
        r.preemptions += 1
        self._rstats["preempted"] += 1
        r.state = QUEUED
        self._queue.appendleft(r)
        return r

    def resilience_stats(self) -> dict:
        """Terminal-state / retry / watchdog counters since reset."""
        return dict(self._rstats, degraded=self._degraded)

    def _launch_window(self, args):
        """Run the decode window under the watchdog: the jitted window
        retries with backoff (an injected stall or poisoned compile
        raises BEFORE the jit call consumes its donated buffers, so the
        operands stay alive), then degrades to the eager interpreted
        window — sticky, because a launch path that failed
        ``max_attempts`` times is not worth re-probing every window."""
        if self._degraded:
            return self._window(*args)

        def primary():
            self._fire_faults("window_launch")
            return self._window_jit(*args)

        def fallback():
            self._rstats["window_fallbacks"] += 1
            self._degraded = True
            return self._window(*args)

        def on_retry(attempt, err):
            self._rstats["window_retries"] += 1

        return self.watchdog.call(primary, fallback=fallback,
                                  label="decode_window", on_retry=on_retry)

    def _quarantine(self, bad: dict, now: float) -> None:
        """Requeue (or fail) slots whose window output flunked the health
        check.  Tokens from the bad tick on were already discarded by the
        drain, so the request's ``output`` is a clean prefix and the
        retry re-prefills it — recomputed greedy tokens are bitwise
        identical, so a retried request's final answer matches an
        unfaulted run."""
        hit = []
        for s in sorted(bad):
            r = self.slot_req[s]
            if r is None:     # finished on a tick before the fault
                continue
            hit.append(s)
            self._rstats["quarantined"] += 1
            self._release_slot(s)
            self.slot_req[s] = None
            r.retries += 1
            if r.retries > self.shed_policy.max_retries:
                r._finalize(
                    FAILED, self.ticks, now,
                    reason=(f"window health check failed {r.retries} "
                            "times (retry budget exhausted)"))
                self._rstats["failed"] += 1
            else:
                self._rstats["retried"] += 1
                r.state = QUEUED
                self._queue.appendleft(r)
        if hit:
            self._deactivate_slots(hit)
            self._after_quarantine(len(hit))

    def _expire_running(self, now: float) -> None:
        """Mid-decode deadline enforcement: release slots whose request
        ran past its deadline, keeping the partial output (a prefix of
        the reference answer)."""
        if not self.shed_policy.enforce_deadlines:
            return
        hit = []
        for s, r in enumerate(self.slot_req):
            if r is None or r.deadline is None or self.ticks <= r.deadline:
                continue
            hit.append(s)
            self._release_slot(s)
            self.slot_req[s] = None
            r._finalize(
                TIMED_OUT, self.ticks, now,
                reason=(f"deadline {r.deadline:g} expired mid-decode at "
                        f"tick {self.ticks}"))
            self._rstats["timed_out"] += 1
        if hit:
            self._deactivate_slots(hit)

    # ---- engine loop ----------------------------------------------------
    def step(self) -> int:
        """One sync window: admit + K fused ticks + drain.  Returns the
        number of sequences active during the window."""
        self._fire_faults("pre_admit")
        self._admit()
        n_active = sum(r is not None for r in self.slot_req)
        if n_active == 0:
            return 0
        self._pre_window()
        self._fire_faults("pre_window")
        # copy before transfer: on CPU jnp.asarray may alias the numpy
        # buffer, and the one-shot clear below would race the async
        # window launch, silently dropping the injected poison
        poison = jnp.asarray(np.array(self._poison_host))
        extra = self._extra_window_args()
        args = (self.params, self.cache, self._state, self.key, poison,
                *extra)
        if self._traffic["decode"] is None and self.record_traffic:
            self._traffic["decode"] = self._analyze(self._window_jit, *args)
        t_launch = time.perf_counter()
        self.cache, self._state, self.key, toks, fins, oks = \
            self._launch_window(args)
        if self._poison_host.any():
            self._poison_host[:] = False   # chaos poison is one-shot
        toks, fins = np.asarray(toks), np.asarray(fins)   # ONE host sync
        oks = np.asarray(oks)
        now = time.perf_counter()   # window results observed on the host
        self._counts["decode_ticks"] += self.ticks_per_sync
        # window health check: first tick per slot whose emitted token is
        # untrustworthy (non-finite logits or out-of-vocab sample)
        bad: Dict[int, int] = {}
        if self.health_check:
            for s in range(self.slots):
                if self.slot_req[s] is None:
                    continue
                for t in range(self.ticks_per_sync):
                    if toks[t, s] < 0:
                        continue
                    if not oks[t, s] or toks[t, s] >= self._vocab:
                        bad[s] = t
                        break
        for t in range(self.ticks_per_sync):
            for s in range(self.slots):
                r = self.slot_req[s]
                if r is None or toks[t, s] < 0:
                    continue
                if s in bad and t >= bad[s]:
                    continue    # discard everything from the bad tick on
                r.output.append(int(toks[t, s]))
                if fins[t, s]:
                    # tick domain keeps the in-window position; the wall
                    # clock is the drain that surfaced the token (Request
                    # docstring)
                    r._mark_done(self.ticks + t, now)
                    self._release_slot(s)
                    self.slot_req[s] = None
        if self.tracer is not None:
            t_end = time.perf_counter()
            self.tracer.span(
                "decode_window", "decode", t_launch, now,
                args={"tick": self.ticks, "K": self.ticks_per_sync,
                      "active": n_active})
            self.tracer.span("host_drain", "host", now, t_end,
                             args={"tick": self.ticks})
            self.tracer.counter("active_slots", {"active": n_active},
                                t_launch)
        self.ticks += self.ticks_per_sync
        if bad:
            self._quarantine(bad, now)
        self._expire_running(now)
        return n_active

    def run(self, max_ticks: int = 10_000) -> int:
        """Run to completion within a K-granular tick budget; returns the
        number of unfinished requests (0 when everything completed)."""
        return _drain_until_done(self, max_ticks)

    # ---- serve-mode NVM verdicts ---------------------------------------
    def serve_records(self, mesh: Optional[str] = None) -> List[dict]:
        """Dry-run-shaped records of the engine's measured traffic: one
        record per serve phase with PER-TICK (decode) / PER-CALL (prefill)
        roofline terms of the compiled executables, consumable by
        ``core.crosslayer.analyze_serve`` — the serve-mode answer to the
        paper's "would an MRAM tier help THIS workload" question."""
        mesh = mesh or f"{jax.device_count()}dev"
        arch = self.model.cfg.arch
        fam = self.model.cfg.family

        def terms(rl, div):
            return {"flops_per_device": rl.flops_per_device / div,
                    "bytes_per_device": rl.bytes_per_device / div,
                    "collective_bytes": rl.collective_bytes / div,
                    "compute_s": rl.compute_s / div,
                    "memory_s": rl.memory_s / div,
                    "collective_s": rl.collective_s / div}

        # Recurrent-bank traffic is write-heavier than KV decode: every
        # tick rewrites the full conv/SSD/RG-LRU state in place, where KV
        # decode appends one row and *reads* the rest.  Tag ssm/hybrid
        # records with their own read/write split so analyze_serve scores
        # the write-asymmetric NVM tiers on the bank regime they actually
        # see (ISSUE 10 tentpole (d)).
        extra: dict = {"family": fam}
        if fam in ("ssm", "hybrid"):
            from repro.core.crosslayer import RECURRENT_READ_FRACTION
            extra["read_fraction"] = RECURRENT_READ_FRACTION

        recs = []
        rl = self._traffic["decode"]
        if rl is not None and self._counts["decode_ticks"]:
            recs.append({
                "arch": arch, "mesh": mesh, "kind": "decode",
                "shape": f"serve_{fam}_decode_b{self.slots}_l{self.max_len}",
                "attn_impl": self.attn_impl,
                "ticks": self._counts["decode_ticks"],
                "roofline": terms(rl, self.ticks_per_sync), **extra})
        for P, rl in sorted(self._traffic["prefill"].items()):
            calls = self._counts["prefill_calls"].get(P, 0)
            if rl is None or not calls:
                continue
            recs.append({
                "arch": arch, "mesh": mesh, "kind": "prefill",
                "shape": f"serve_{fam}_prefill_p{P}_b{self.slots}",
                "calls": calls, "roofline": terms(rl, 1), **extra})
        return recs

    def nvm_verdicts(self, tier_mb: Optional[float] = None):
        """SRAM/STT/SOT tier verdicts on the engine's measured traffic."""
        from repro.core.crosslayer import analyze_serve
        kw = {} if tier_mb is None else {"tier_mb": tier_mb}
        return analyze_serve(self.serve_records(), **kw)


class PagedEngine(Engine):
    """Paged-KV continuous-batching engine with radix-tree prefix sharing
    (DESIGN.md §15).

    Device KV lives in per-layer physical page pools of shape
    ``(num_pages + 1, page_size, K, hd)`` — the trailing page is TRASH,
    the scatter sink for masked/inactive rows — and every slot carries a
    ``(nb,)`` row of one shared ``(slots, nb)`` int32 page table
    (``nb = max_len // page_size``).  Host-side bookkeeping is
    ``serve/paged.py``: a refcounted ``PagePool`` plus a path-compressed
    ``RadixTree`` of served prompts pinning the pages that hold their KV.

    Admission walks the tree for the longest stored prefix of each
    prompt (capped at ``len(prompt) - 1`` so at least one suffix token
    always prefills and produces t0 logits), maps the shared full pages
    by bumping refcounts, copy-on-writes the boundary page when the
    suffix starts mid-page, and reserves the slot's FULL page span
    ``ceil(min(L + max_new, max_len) / page_size)`` up front — decode
    never allocates mid-flight.  Only the unshared suffix runs through
    the (batched, masked) paged prefill; finished prompts insert into
    the tree so later requests can share them.  When the pool runs
    short, LRU tree leaves evict; if still short, admission defers to a
    later sync point (deadlock-free: a lone request needs at most
    ``nb`` pages and full eviction frees everything).

    Decode runs the same fused K-tick window as ``Engine`` with the page
    table as an extra operand: ``attn_impl="xla"`` takes the jnp
    gather path (the parity oracle), ``"pallas_paged"`` the Pallas
    kernel with the table as a scalar-prefetch operand and fused KV
    append (kernels/paged_attention.py).  Greedy outputs are bitwise
    equal to ``Engine``/``EngineReference`` on the same request set
    (tests/test_paged_cache.py).

    ``serve_records()`` annotates the decode record with the measured
    ``unique_page_fraction`` — unique physical pages read per window
    over total mapped page reads — which
    ``core.crosslayer.analyze_serve`` uses to scale KV traffic: shared
    pages are one physical working set, so the NVM verdicts see the
    paged engine's REAL (deduplicated) decode traffic.
    """

    DECODE_ATTN_IMPLS = ("xla", "pallas_paged")

    def __init__(self, model: Model, params, *, slots: int, max_len: int,
                 page_size: int = 8, num_pages: Optional[int] = None, **kw):
        if "paged" not in model.serve_modes:
            raise UnsupportedFamilyError(
                model.cfg.family, serve_families("paged"), "PagedEngine",
                detail="the paged engine is KV-decoder-only by design: "
                       "pages hold positioned KV rows, and recurrent/ring/"
                       "encoder banks have no page-addressable layout — "
                       "use Engine for this family")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if max_len % page_size != 0:
            raise ValueError(
                f"max_len {max_len} must be a multiple of page_size "
                f"{page_size}")
        self.page_size = int(page_size)
        self.nb = max_len // self.page_size
        # default pool = dense capacity (slots x nb); prefix sharing then
        # strictly lowers pages-in-use.  TRASH is the extra device page at
        # index num_pages, never managed by the host pool.
        self.num_pages = int(num_pages) if num_pages is not None \
            else slots * self.nb
        if self.num_pages < self.nb:
            raise ValueError(
                f"num_pages {self.num_pages} cannot hold one full-length "
                f"request ({self.nb} pages)")
        self.trash = self.num_pages
        super().__init__(model, params, slots=slots, max_len=max_len, **kw)
        # decode through the paged branch of attention_block: plain jnp
        # gather under "xla", fused Pallas kernel under "pallas_paged"
        self._decode_attn_impl = (
            "pallas_paged" if self.attn_impl == "pallas_paged" else "xla")
        self._cow_jit = jax.jit(
            lambda c, src, dst: {
                k: v.at[:, dst].set(v[:, src]) for k, v in c.items()},
            donate_argnums=(0,))
        self._scrub_jit = jax.jit(
            lambda c, idx: {
                k: v.at[:, idx].set(0) for k, v in c.items()},
            donate_argnums=(0,))

    # ---- state ----------------------------------------------------------
    def _fresh_cache(self):
        return self.model.init_paged_cache(self.num_pages + 1,
                                           self.page_size)

    def reset(self, seed: Optional[int] = None) -> None:
        super().reset(seed)
        self.pool = PagePool(self.num_pages, self.page_size)
        self.tree = RadixTree(self.pool)
        self._slot_pages: List[List[int]] = [[] for _ in range(self.slots)]
        self._pt_host = np.full((self.slots, self.nb), self.trash, np.int32)
        self._pt_dev = jnp.asarray(self._pt_host)
        self._pt_dirty = False
        self.stats = {"prefix_hits": 0, "prefix_tokens": 0,
                      "prompt_tokens": 0, "cow_copies": 0, "deferred": 0,
                      "evicted_pages": 0, "inserted_nodes": 0,
                      "tree_flushes": 0}
        self._last_shortage = (0, 0)   # (pages wanted, pages free)
        self._upf_sum = 0.0
        self._upf_windows = 0

    def paged_stats(self) -> dict:
        """Counters + pool gauges for launch printouts and benchmarks."""
        pt = max(1, self.stats["prompt_tokens"])
        return {**self.stats,
                "pages_hwm": self.pool.hwm,
                "pages_in_use": self.pool.in_use,
                "free_pages": self.pool.free_pages,
                "radix_nodes": self.tree.num_nodes,
                "prefix_hit_rate": self.stats["prefix_tokens"] / pt}

    # ---- window plumbing -------------------------------------------------
    def _decode_kwargs(self, extra) -> dict:
        return {"page_table": extra[0]}

    def _extra_window_args(self) -> tuple:
        return (self._pt_dev,)

    def _pre_window(self) -> None:
        if self._pt_dirty:
            self._pt_dev = jnp.asarray(self._pt_host)
            self._pt_dirty = False
        # unique-page fraction of this window's decode reads: row b at
        # position p reads its first ceil((p+1)/ps) mapped pages
        mapped: List[int] = []
        for s, r in enumerate(self.slot_req):
            if r is None:
                continue
            pos = len(r.prompt) + len(r.output) - 1
            n = pages_for(min(pos + 1, self.max_len), self.page_size)
            mapped.extend(self._pt_host[s, :n].tolist())
        if mapped:
            frac = len(set(mapped)) / len(mapped)
            self._upf_sum += frac
            self._upf_windows += 1
            if self.tracer is not None:
                self.tracer.instant(
                    "page_gather", "paged", time.perf_counter(),
                    args={"tick": self.ticks, "mapped": len(mapped),
                          "unique": len(set(mapped)),
                          "unique_page_fraction": frac})

    def _release_slot(self, s: int) -> None:
        for p in self._slot_pages[s]:
            self.pool.release(p)
        self._slot_pages[s] = []
        self._pt_host[s] = self.trash
        self._pt_dirty = True

    # ---- resilience -----------------------------------------------------
    def _stash_prefix(self, s: int, req: Request) -> None:
        """Preemption keeps the work: the slot's already-written KV —
        positions ``[0, L + len(output) - 1)``, i.e. the effective prompt
        minus the not-yet-written last token — goes into the radix tree
        under its token string, so the requeued request's next ``_plan``
        matches it and re-admission prefills only one suffix token."""
        written = len(req.prompt) + len(req.output) - 1
        if written < 1:
            return
        toks = (list(req.prompt) + list(req.output))[:written]
        self.stats["inserted_nodes"] += self.tree.insert(
            toks, self._slot_pages[s][:pages_for(written, self.page_size)])

    def _after_quarantine(self, n: int) -> None:
        # a health-check failure means some KV content is untrustworthy,
        # and shared prefix pages could re-poison every retry: flush the
        # tree (conservative — only costs re-prefill on the next misses)
        self.stats["tree_flushes"] += 1
        self.tree.clear()
        # scrub the now-free pages on device: a recycled page is only
        # partially overwritten by its next prefill (rows past the new
        # occupant's length keep old bytes), and corrupt residue there
        # can leak into attention — zeroing restores the fresh-cache
        # contract for everything the flush just released
        free = sorted(self.pool._free)
        if free:
            self.cache = self._scrub_jit(
                self.cache, jnp.asarray(free, jnp.int32))

    # ---- admission ------------------------------------------------------
    def _plan(self, req: Request) -> Optional[dict]:
        """Reserve every page request ``req`` will ever touch, sharing
        tree-held prefix pages.  Returns None (nothing mutated net) when
        the pool stays short even after LRU eviction — the shortfall is
        kept in ``_last_shortage`` so the shed path can say how many
        pages were missing.  Requeued requests plan against their
        effective prompt ``prompt + output`` (resume, not restart)."""
        ps = self.page_size
        prompt = list(req.prompt) + list(req.output)
        L = len(prompt)
        remaining = req.max_new_tokens - len(req.output)
        # cap the match one token short of the prompt: the suffix must be
        # non-empty so the admission prefill computes t0 logits
        matched, shared = self.tree.match(prompt[:L - 1])
        n_full = matched // ps
        boundary = matched % ps != 0
        held = shared[:n_full + (1 if boundary else 0)]
        for p in held:            # pin before eviction can free them
            self.pool.share(p)
        total = pages_for(min(L + remaining, self.max_len), ps)
        need = total - n_full     # boundary page is CoW'd, so it's "new"
        if self.pool.free_pages < need:
            self.stats["evicted_pages"] += self.tree.evict(need)
        try:
            new = self.pool.alloc(need)
        except PagePoolExhausted as e:
            for p in held:        # roll back the pins; admission defers
                self.pool.release(p)
            self._last_shortage = (e.requested, e.free)
            return None
        self.stats["prompt_tokens"] += L
        self.stats["prefix_tokens"] += matched
        self.stats["prefix_hits"] += 1 if matched else 0
        cow = None
        if boundary:
            # suffix starts mid-page: private copy of the shared boundary
            # page (new[0] covers logical page n_full), pin released after
            # the device copy in _admit
            cow = (held[n_full], new[0])
            self.stats["cow_copies"] += 1
            self.pool.cow_copies += 1
        return {"matched": matched, "L": L, "prompt": prompt, "cow": cow,
                "pages": shared[:n_full] + new, "total": total,
                "boundary_pin": held[n_full] if boundary else None}

    def _admit(self) -> int:
        """Paged admission is a shed-or-defer scan, never head-of-line
        blocking: a request whose page reservation cannot be met steps
        aside (keeping its queue position) so later requests that DO fit
        can run, and sheds outright once it has been passed over
        ``ShedPolicy.max_defers`` times.  Combined with the run-loop
        stall guard this makes pool exhaustion a latency event, not a
        deadlock."""
        self._last_admitted = 0
        _drop_expired(self)
        free = [s for s in range(self.slots) if self.slot_req[s] is None]
        pol = self.shed_policy
        pairs = []
        deferred: List[Request] = []
        while free and self._queue:
            r = self._queue.popleft()
            plan = self._plan(r)
            if plan is None:
                self.stats["deferred"] += 1
                r.defers += 1
                if pol.max_defers is not None and r.defers > pol.max_defers:
                    want, have = self._last_shortage
                    r._finalize(
                        SHED, self.ticks, time.perf_counter(),
                        reason=(f"page pool exhausted on {r.defers} "
                                f"admission attempts (last shortfall: "
                                f"wanted {want} pages, {have} free)"))
                    self._rstats["shed"] += 1
                else:
                    deferred.append(r)
                continue
            pairs.append((free.pop(0), r, plan))
        for r in reversed(deferred):
            self._queue.appendleft(r)
        if not pairs:
            return 0
        t_admit = time.perf_counter()
        if self.tracer is not None:
            self.tracer.begin("admit", "prefill", t_admit,
                              args={"tick": self.ticks,
                                    "admitted": len(pairs)})
        # batched CoW device copies, then drop the boundary pins
        cows = [p["cow"] for _, _, p in pairs if p["cow"] is not None]
        if cows:
            srcs, dsts = zip(*cows)
            self.cache = self._cow_jit(self.cache,
                                       jnp.asarray(srcs, jnp.int32),
                                       jnp.asarray(dsts, jnp.int32))
            for _, _, p in pairs:
                if p["boundary_pin"] is not None:
                    self.pool.release(p["boundary_pin"])
                if self.tracer is not None and p["cow"] is not None:
                    self.tracer.instant(
                        "cow_copy", "paged", time.perf_counter(),
                        args={"src": int(p["cow"][0]),
                              "dst": int(p["cow"][1])})
        # page tables: the slot holds one reference per mapped page
        for s, r, p in pairs:
            self._slot_pages[s] = list(p["pages"])
            row = np.full(self.nb, self.trash, np.int32)
            row[:p["total"]] = p["pages"]
            self._pt_host[s] = row
        self._pt_dev = jnp.asarray(self._pt_host)
        self._pt_dirty = False
        # batched suffix prefill (only unshared tokens run the model)
        S = min(self.max_len,
                _next_pow2(max(p["L"] - p["matched"] for _, _, p in pairs)))
        tokens = np.zeros((self.slots, S), np.int32)
        mask = np.zeros((self.slots, S), bool)
        starts = np.zeros(self.slots, np.int32)
        suf_lens = np.zeros(self.slots, np.int32)
        full_lens = np.zeros(self.slots, np.int32)
        admit = np.zeros(self.slots, bool)
        max_new = np.ones(self.slots, np.int32)
        temps = np.zeros(self.slots, np.float32)
        for s, r, p in pairs:
            suf = p["prompt"][p["matched"]:]
            tokens[s, :len(suf)] = suf
            mask[s, :len(suf)] = True
            starts[s] = p["matched"]
            suf_lens[s] = len(suf)
            full_lens[s] = p["L"]
            admit[s] = True
            max_new[s] = r.max_new_tokens - len(r.output)
            temps[s] = r.temperature
        args = (self.params, self.cache, self._state, jnp.asarray(tokens),
                self._pt_dev, jnp.asarray(starts), jnp.asarray(suf_lens),
                jnp.asarray(full_lens), jnp.asarray(admit),
                jnp.asarray(max_new), jnp.asarray(temps), self.key,
                jnp.asarray(mask))
        if S not in self._traffic["prefill"]:
            self._traffic["prefill"][S] = self._analyze(
                self._prefill_jit, *args)
        t_launch = time.perf_counter()
        self.cache, self._state, self.key, t0, done0, ok0 = \
            self._prefill_jit(*args)
        self._counts["prefill_calls"][S] = \
            self._counts["prefill_calls"].get(S, 0) + 1
        t0, done0, ok0 = np.asarray(t0), np.asarray(done0), np.asarray(ok0)
        now = time.perf_counter()
        if self.tracer is not None:
            self.tracer.span(
                f"prefill_chunk S={S}", "prefill", t_launch, now,
                args={"tick": self.ticks, "admitted": len(pairs),
                      "padded_len": S,
                      "suffix_tokens": int(suf_lens.sum()),
                      "shared_tokens": int((full_lens - suf_lens).sum())})
        if self.charge_prefill_ticks:
            self.ticks += -(-int(suf_lens.sum()) // self.slots)
        bad0: Dict[int, int] = {}
        for s, r, p in pairs:
            self.slot_req[s] = r
            r._mark_admitted(self.ticks, now)
            if self.health_check and not ok0[s]:
                # poisoned prefill (a shared or recycled page carried
                # corrupt KV): discard t0 and requeue via quarantine —
                # the tree flush + page scrub below cleans the source
                bad0[s] = 0
                continue
            r.output.append(int(t0[s]))
            # register the full effective prompt's pages so later prompts
            # share them (the tree takes its own references; safe even if
            # this slot keeps decoding into the boundary page at rows
            # >= L, which the tree never vouches for)
            self.stats["inserted_nodes"] += self.tree.insert(
                p["prompt"], p["pages"][:pages_for(p["L"], self.page_size)])
            if done0[s]:
                r._mark_done(self.ticks, now)
                self._release_slot(s)
                self.slot_req[s] = None
        if self.tracer is not None:
            self.tracer.end(time.perf_counter(),
                            args={"pages_in_use": self.pool.in_use})
        self._last_admitted = len(pairs)
        if bad0:
            self._quarantine(bad0, now)
        return len(pairs)

    def _prefill_prog(self, params, cache, state, tokens, pt, starts,
                      suf_lens, full_lens, admit, max_new, temps_in, key,
                      mask):
        """Batched paged SUFFIX prefill: decode-mode forward with S > 1
        tokens per row starting at each row's ``starts`` (= matched
        prefix length).  ``mask`` routes every non-suffix write to the
        TRASH page, so rows mid-decode and the shared prefix pages stay
        bit-identical; per-row causal masking makes the suffix KV
        independent of other rows.  Samples t0 from each admitted row's
        last suffix position."""
        S = tokens.shape[1]
        logits, cache = self.model.decode_step(
            params, cache, {"tokens": tokens}, starts, attn_impl="xla",
            page_table=pt, kv_write_mask=mask)
        idx = jnp.clip(suf_lens - 1, 0, S - 1)
        last_lg = jnp.take_along_axis(
            logits, idx[:, None, None], axis=1)[:, 0].astype(jnp.float32)
        ok0 = jnp.isfinite(last_lg).all(axis=-1)
        key, sub = jax.random.split(key)
        t0 = self._sample_batch(last_lg, temps_in, sub)
        done0 = (max_new - 1 <= 0) | (full_lens >= self.max_len)
        if self.eos_id is not None:
            done0 = done0 | (t0 == self.eos_id)
        state = {
            "last": jnp.where(admit, t0, state["last"]),
            "pos": jnp.where(admit, full_lens, state["pos"]),
            "active": jnp.where(admit, ~done0, state["active"]),
            "remaining": jnp.where(admit, max_new - 1, state["remaining"]),
            "temps": jnp.where(admit, temps_in, state["temps"]),
        }
        return cache, state, key, t0, done0, ok0

    # ---- serve-mode NVM verdicts ---------------------------------------
    def serve_records(self, mesh: Optional[str] = None) -> List[dict]:
        """Engine records plus the measured ``unique_page_fraction`` on
        the decode record — ``analyze_serve`` scales KV-bound traffic by
        it, so the SRAM/STT/SOT verdicts see prefix sharing's traffic
        reduction (DESIGN.md §15)."""
        recs = super().serve_records(mesh)
        upf = (self._upf_sum / self._upf_windows
               if self._upf_windows else 1.0)
        for rec in recs:
            if rec["kind"] == "decode":
                rec["unique_page_fraction"] = upf
        return recs


class EngineReference:
    """The seed per-tick serving path, kept as the correctness oracle and
    benchmark baseline for ``Engine`` (DESIGN.md §11): prompts prefill one
    token at a time through ``decode_step``, every decode tick round-trips
    logits to the host, and sampling/termination run in per-request python.

    Two seed bugs are fixed so this is actually an oracle:
      * per-row position vectors replace the shared ``max(slot_pos)``
        scalar, so slots at different depths decode correctly;
      * prefill restores every non-target cache row after each token step
        instead of broadcasting the prefilling request's KV into ALL rows
        (``jnp.full((slots, 1), token)`` in the seed ``_step_slot``).
    Greedy outputs are parity-enforced against ``Engine`` in
    tests/test_serve_engine.py and benchmarks/serve_engine.py.

    Family support matches ``Engine`` (every ``serve_modes``-dense
    family): recurrent/ring banks get a per-row reset at admission and a
    bank-aware row restore during prefill, and encdec rows are encoded
    through the same fixed-shape program as ``Engine._encode_jit`` so
    enc/out content is bitwise identical across engines.
    """

    ticks_per_sync = 1   # per-tick engine: every step is its own window

    def __init__(self, model: Model, params, *, slots: int, max_len: int,
                 eos_id: Optional[int] = None, seed: int = 0,
                 shed_policy: Optional[ShedPolicy] = None):
        if "dense" not in model.serve_modes:
            raise UnsupportedFamilyError(
                model.cfg.family, serve_families("dense"),
                "EngineReference")
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.seed = seed
        self.shed_policy = shed_policy if shed_policy is not None \
            else ShedPolicy()
        self._banks = model.state_banks()
        defs = model.cache_defs(slots, max_len)
        self._bank_reset = {n: (d.const if d.init == "const" else 0)
                            for n, d in defs.items()}
        self._guarded = frozenset(
            n for n, b in self._banks.items()
            if b.kind in ("recurrent", "ring"))
        self._decode = jax.jit(
            lambda p, c, b, pos: model.decode_step(p, c, b, pos))
        if model.cfg.family == "encdec":
            # the SAME fixed-shape encoder program as Engine._encode_jit,
            # so both engines' enc/out rows are bitwise identical
            self._encode = jax.jit(
                lambda p, t, l: model.encode_prompt(p, t, l))
        self.reset()

    def reset(self, seed: Optional[int] = None) -> None:
        self.cache = self.model.init_cache(self.slots, self.max_len)
        self.key = jax.random.PRNGKey(self.seed if seed is None else seed)
        self.slot_req: List[Optional[Request]] = [None] * self.slots
        self._queue: Deque[Request] = collections.deque()
        self._last = np.zeros(self.slots, np.int32)
        self._pos = np.zeros(self.slots, np.int32)
        self._active = np.zeros(self.slots, bool)
        self._remaining = np.zeros(self.slots, np.int32)
        self._temps = np.zeros(self.slots, np.float32)
        self.ticks = 0
        self._last_admitted = 0
        self._rstats = {"failed": 0, "shed": 0, "timed_out": 0,
                        "quarantined": 0, "retried": 0, "preempted": 0,
                        "window_retries": 0, "window_fallbacks": 0}

    # ---- admission ------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Same soft-fail semantics as ``Engine.submit``."""
        return _soft_submit(self, req)

    def resilience_stats(self) -> dict:
        return dict(self._rstats, degraded=False)

    def _admit(self) -> None:
        self._last_admitted = 0
        _drop_expired(self)
        for i in range(self.slots):
            if self.slot_req[i] is None and self._queue:
                self._prefill(i, self._queue.popleft())
                self._last_admitted += 1

    def _sample(self, logits_row: np.ndarray, temp: float) -> int:
        if temp > 0:
            self.key, sub = jax.random.split(self.key)
            scaled = jnp.asarray(logits_row, jnp.float32) / max(temp, 1e-6)
            return int(jax.random.categorical(sub, scaled))
        return int(np.argmax(logits_row))

    def _prefill(self, slot: int, req: Request) -> None:
        """Per-token prefill (the seed loop), slot-isolated.  Requeued
        requests (e.g. crash resubmission) resume from their effective
        prompt ``prompt + output``, mirroring ``Engine._admit``."""
        self.slot_req[slot] = req
        eff = list(req.prompt) + list(req.output)
        sel = (jnp.arange(self.slots) == slot)
        if self._guarded:
            # recurrent/ring banks keep the PREVIOUS occupant's state in
            # this row (no position guard to mask it out) — reset the
            # admitted row before replaying the prompt, exactly like
            # Engine._prefill_scan
            self.cache = _reset_rows(self.cache, sel, self._banks,
                                     self._bank_reset)
        if self.model.cfg.family == "encdec":
            toks_full = np.zeros((self.slots, self.max_len), np.int32)
            toks_full[slot, :len(eff)] = eff
            lens = np.zeros(self.slots, np.int32)
            lens[slot] = len(eff)
            enc = self._encode(self.params, jnp.asarray(toks_full),
                               jnp.asarray(lens))
            cache = dict(self.cache)
            cache["enc/out"] = _where_rows(
                sel, enc.astype(cache["enc/out"].dtype),
                cache["enc/out"], self._banks["enc/out"].batch_axis)
            self.cache = cache
        lg = None
        for t, tok in enumerate(eff):
            toks = self._last.copy()
            toks[slot] = tok
            pos = np.clip(self._pos, 0, self.max_len - 1)
            pos[slot] = t
            old = self.cache
            logits, new = self._decode(
                self.params, old, {"tokens": jnp.asarray(toks[:, None])},
                jnp.asarray(pos))
            # only the target row may change (the seed broadcast every
            # prefill token's KV into all rows here); banks carry their
            # own batch axis, so route the row select through it
            self.cache = {
                n: _where_rows(sel, new[n], old[n],
                               self._banks[n].batch_axis)
                for n in new}
            lg = logits
        t0 = self._sample(np.asarray(lg)[slot, -1].astype(np.float32),
                          req.temperature)
        req._mark_admitted(self.ticks, time.perf_counter())
        req.output.append(t0)
        self._last[slot] = t0
        self._pos[slot] = len(eff)
        self._remaining[slot] = req.max_new_tokens - len(req.output)
        self._temps[slot] = req.temperature
        done = (self._remaining[slot] <= 0
                or (self.eos_id is not None and t0 == self.eos_id)
                or self._pos[slot] >= self.max_len)
        if done:
            req._mark_done(self.ticks, time.perf_counter())
            self.slot_req[slot] = None
            self._active[slot] = False
        else:
            self._active[slot] = True

    # ---- engine loop ----------------------------------------------------
    def step(self) -> int:
        """One engine tick: admit + one batched decode + host sampling."""
        self._admit()
        active = np.nonzero(self._active)[0]
        if len(active) == 0:
            return 0
        pos = np.clip(self._pos, 0, self.max_len - 1)
        logits, self.cache = self._decode(
            self.params, self.cache,
            {"tokens": jnp.asarray(self._last[:, None])}, jnp.asarray(pos))
        lg = np.asarray(logits)[:, -1].astype(np.float32)
        for s in active:
            r = self.slot_req[s]
            tok = self._sample(lg[s], self._temps[s])
            r.output.append(tok)
            self._last[s] = tok
            self._pos[s] += 1
            self._remaining[s] -= 1
            done = (self._remaining[s] <= 0
                    or (self.eos_id is not None and tok == self.eos_id)
                    or self._pos[s] >= self.max_len)
            if done:
                r._mark_done(self.ticks, time.perf_counter())
                self.slot_req[s] = None
                self._active[s] = False
        self.ticks += 1
        return len(active)

    def run(self, max_ticks: int = 10_000) -> int:
        """Run to completion within the tick budget; returns the number of
        unfinished requests (0 when everything completed)."""
        return _drain_until_done(self, max_ticks)


# The seed engine's per-tick path lives on under this name (parity oracle
# + benchmark baseline), matching the *_reference convention of the sweep /
# cachesim / traffic engines.
engine_reference = EngineReference
