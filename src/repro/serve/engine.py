"""Batched serving engine: prefill + decode with continuous batching.

The engine owns a fixed-capacity KV cache (slots = max concurrent
sequences); requests are admitted into free slots, prefilled (padded to the
model max), then stepped together by one fused decode step per tick.
Finished sequences free their slot immediately (continuous batching).
Sampling: greedy or temperature.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int
    temperature: float = 0.0
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, model: Model, params, *, slots: int, max_len: int,
                 eos_id: Optional[int] = None, seed: int = 0):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.key = jax.random.PRNGKey(seed)
        self.cache = model.init_cache(slots, max_len)
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.slot_pos = np.zeros(slots, np.int32)   # next write position
        self._decode = jax.jit(
            lambda p, c, b, pos: model.decode_step(p, c, b, pos))
        self._queue: List[Request] = []

    # ---- admission -------------------------------------------------------
    def submit(self, req: Request):
        self._queue.append(req)

    def _admit(self):
        for i in range(self.slots):
            if self.slot_req[i] is None and self._queue:
                req = self._queue.pop(0)
                self._prefill(i, req)

    def _prefill(self, slot: int, req: Request):
        """Single-sequence prefill into one slot (per-token decode loop —
        portable; a production engine fuses this into a batched prefill)."""
        self.slot_req[slot] = req
        self.slot_pos[slot] = 0
        for tok in req.prompt:
            self._step_slot(slot, tok)

    def _step_slot(self, slot: int, token: int) -> int:
        batch = {"tokens": jnp.full((self.slots, 1), token, jnp.int32)}
        pos = int(self.slot_pos[slot])
        logits, self.cache = self._decode(self.params, self.cache, batch,
                                          pos)
        self.slot_pos[slot] = pos + 1
        return int(jnp.argmax(logits[slot, -1]))

    # ---- decode tick -----------------------------------------------------
    def _sample(self, logits: jax.Array, temps: np.ndarray) -> np.ndarray:
        self.key, sub = jax.random.split(self.key)
        greedy = jnp.argmax(logits, axis=-1)
        scaled = logits / jnp.maximum(
            jnp.asarray(temps)[:, None], 1e-6)
        sampled = jax.random.categorical(sub, scaled, axis=-1)
        return np.asarray(jnp.where(jnp.asarray(temps) > 0, sampled, greedy))

    def step(self) -> int:
        """One engine tick: admit + one batched decode step. Returns the
        number of active sequences stepped."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        last = np.zeros((self.slots, 1), np.int32)
        temps = np.zeros(self.slots, np.float32)
        for i in active:
            r = self.slot_req[i]
            seq = r.prompt + r.output
            last[i, 0] = seq[-1] if seq else 0
            temps[i] = r.temperature
        # NOTE: per-slot positions differ; the fused step uses the max and
        # each slot's cache validity is tracked by its own position mask.
        pos = int(max(self.slot_pos[i] for i in active))
        logits, self.cache = self._decode(
            self.params, self.cache, {"tokens": jnp.asarray(last)}, pos)
        nxt = self._sample(logits[:, -1], temps)
        for i in active:
            r = self.slot_req[i]
            tok = int(nxt[i])
            r.output.append(tok)
            self.slot_pos[i] += 1
            if (len(r.output) >= r.max_new_tokens
                    or (self.eos_id is not None and tok == self.eos_id)
                    or self.slot_pos[i] >= self.max_len):
                r.done = True
                self.slot_req[i] = None   # free slot (continuous batching)
        return len(active)

    def run(self, max_ticks: int = 10_000) -> None:
        ticks = 0
        while (self._queue or any(self.slot_req)) and ticks < max_ticks:
            self.step()
            ticks += 1
