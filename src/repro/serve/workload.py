"""Mixed serve workloads + staggered-arrival drivers.

Shared by tests/test_serve_engine.py, benchmarks/serve_engine.py, and
launch/serve.py so "the mixed workload" (staggered arrivals, uneven
prompt/output lengths, eos exits) means the same thing everywhere parity
is enforced.  With correct slot isolation a request's greedy output
depends only on its own prompt, so outputs are scheduling-independent —
the same request set must decode identically under any arrival pattern,
any ticks_per_sync, and under ``EngineReference``.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.serve.engine import Request


def mixed_requests(n: int, *, seed: int = 0, vocab: int = 512,
                   prompt_lens: Tuple[int, int] = (2, 10),
                   max_new: Tuple[int, int] = (3, 10),
                   temperature: float = 0.0,
                   temperature_every: int = 0) -> List[Request]:
    """n requests with uneven prompt/output lengths (inclusive ranges).

    ``temperature_every`` = j > 0 gives every j-th request ``temperature``
    (the rest greedy) — parity suites keep it 0 so all requests are greedy.
    """
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(prompt_lens[0], prompt_lens[1] + 1))
        prompt = [int(t) for t in rng.integers(1, vocab, size=plen)]
        temp = (temperature if temperature_every and
                (i + 1) % temperature_every == 0 else 0.0)
        reqs.append(Request(
            uid=i, prompt=prompt,
            max_new_tokens=int(rng.integers(max_new[0], max_new[1] + 1)),
            temperature=temp))
    return reqs


def run_staggered(engine, groups: Sequence[Sequence[Request]],
                  max_ticks: int = 10_000) -> Dict[int, List[int]]:
    """Submit request groups with one engine step between arrivals, then
    run to completion.  Returns {uid: output tokens}."""
    for i, group in enumerate(groups):
        for r in group:
            engine.submit(r)
        if i + 1 < len(groups):
            engine.step()
    engine.run(max_ticks=max_ticks)
    reqs = [r for g in groups for r in g]
    missing = [r.uid for r in reqs if not r.done]
    if missing:
        raise RuntimeError(f"requests {missing} did not finish "
                           f"within {max_ticks} ticks")
    return {r.uid: list(r.output) for r in reqs}


def staggered_groups(reqs: Sequence[Request],
                     group_size: int) -> List[List[Request]]:
    """Chop a request list into arrival groups of ``group_size``."""
    return [list(reqs[i:i + group_size])
            for i in range(0, len(reqs), group_size)]
