"""Serve traffic generation + arrival drivers (fixed groups and Poisson).

Shared by tests/test_serve_engine.py, benchmarks/serve_engine.py, and
launch/serve.py so "the mixed workload" (staggered arrivals, uneven
prompt/output lengths, eos exits) means the same thing everywhere parity
is enforced.  With correct slot isolation a request's greedy output
depends only on its own prompt, so outputs are scheduling-independent —
the same request set must decode identically under any arrival pattern,
any ticks_per_sync, and under ``EngineReference``.

Beyond the fixed-group drivers the module is a real traffic generator
(DESIGN.md §14): ``poisson_requests`` draws request arrival times from a
(possibly burst-modulated) Poisson process in the engine's TICK domain
and prompt/output lengths from clipped lognormals (heavy-tailed, like
real traffic), and ``run_arrivals`` drives an engine by those arrival
times — a request is submitted at the first host sync point at or after
its arrival tick, never as a pre-chunked group — which is what makes the
TTFT/TPOT/p50/p99 numbers in ``serve/telemetry.py`` mean something under
bursty load.
"""
from __future__ import annotations

import collections
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.engine import Request, _unfinished


def mixed_requests(n: int, *, seed: int = 0, vocab: int = 512,
                   prompt_lens: Tuple[int, int] = (2, 10),
                   max_new: Tuple[int, int] = (3, 10),
                   temperature: float = 0.0,
                   temperature_every: int = 0) -> List[Request]:
    """n requests with uneven prompt/output lengths (inclusive ranges).

    ``temperature_every`` = j > 0 gives every j-th request ``temperature``
    (the rest greedy) — parity suites keep it 0 so all requests are greedy.
    """
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(prompt_lens[0], prompt_lens[1] + 1))
        prompt = [int(t) for t in rng.integers(1, vocab, size=plen)]
        temp = (temperature if temperature_every and
                (i + 1) % temperature_every == 0 else 0.0)
        reqs.append(Request(
            uid=i, prompt=prompt,
            max_new_tokens=int(rng.integers(max_new[0], max_new[1] + 1)),
            temperature=temp))
    return reqs


def run_staggered(engine, groups: Sequence[Sequence[Request]],
                  max_ticks: int = 10_000) -> Dict[int, List[int]]:
    """Submit request groups with one engine step between arrivals, then
    run to completion.  Returns {uid: output tokens}."""
    for i, group in enumerate(groups):
        for r in group:
            engine.submit(r)
        if i + 1 < len(groups):
            engine.step()
    engine.run(max_ticks=max_ticks)
    reqs = [r for g in groups for r in g]
    missing = [r.uid for r in reqs if not r.done]
    if missing:
        raise RuntimeError(f"requests {missing} did not finish "
                           f"within {max_ticks} ticks")
    return {r.uid: list(r.output) for r in reqs}


def staggered_groups(reqs: Sequence[Request],
                     group_size: int) -> List[List[Request]]:
    """Chop a request list into arrival groups of ``group_size``."""
    return [list(reqs[i:i + group_size])
            for i in range(0, len(reqs), group_size)]


def shared_prefix_requests(n: int, *, seed: int = 0, vocab: int = 512,
                           num_templates: int = 4, template_len: int = 42,
                           suffix_lens: Tuple[int, int] = (2, 8),
                           max_new: Tuple[int, int] = (3, 10),
                           temperature: float = 0.0,
                           temperature_every: int = 0) -> List[Request]:
    """n requests over ``num_templates`` shared system-prompt templates:
    request i's prompt is a round-robin template of ``template_len``
    tokens plus a private random suffix (inclusive ``suffix_lens``
    bounds) — the workload radix-tree prefix sharing is built for
    (DESIGN.md §15).  A ``template_len`` that is NOT a page-size
    multiple forces boundary CoW copies in the paged engine, which is
    why the default is 42 (42 % 8 == 6).
    """
    if num_templates < 1 or template_len < 1:
        raise ValueError("need >= 1 template of >= 1 token")
    rng = np.random.default_rng(seed)
    templates = [[int(t) for t in rng.integers(1, vocab, size=template_len)]
                 for _ in range(num_templates)]
    reqs = []
    for i in range(n):
        slen = int(rng.integers(suffix_lens[0], suffix_lens[1] + 1))
        suffix = [int(t) for t in rng.integers(1, vocab, size=slen)]
        temp = (temperature if temperature_every and
                (i + 1) % temperature_every == 0 else 0.0)
        reqs.append(Request(
            uid=i, prompt=templates[i % num_templates] + suffix,
            max_new_tokens=int(rng.integers(max_new[0], max_new[1] + 1)),
            temperature=temp))
    return reqs


# ---- Poisson / bursty traffic generation ------------------------------------


def poisson_arrivals(n: int, *, rate: float, rng: np.random.Generator,
                     burst_amp: float = 0.0,
                     burst_period: float = 64.0) -> np.ndarray:
    """n arrival times (float ticks, strictly increasing) from a Poisson
    process with instantaneous rate

        lambda(t) = rate * (1 + burst_amp * sin(2 pi t / burst_period))

    ``burst_amp = 0`` is a homogeneous process (mean inter-arrival gap
    ``1 / rate``); ``0 < burst_amp <= 1`` gives a diurnal/bursty rate that
    swings between ``rate * (1 - amp)`` and ``rate * (1 + amp)`` with the
    given period.  Sampled exactly by Lewis–Shedler thinning of a
    homogeneous process at the peak rate.
    """
    if rate <= 0:
        raise ValueError(f"arrival rate must be > 0, got {rate}")
    if not 0.0 <= burst_amp <= 1.0:
        raise ValueError(f"burst_amp must be in [0, 1], got {burst_amp}")
    if burst_amp > 0 and burst_period <= 0:
        raise ValueError(f"burst_period must be > 0, got {burst_period}")
    lam_max = rate * (1.0 + burst_amp)
    out, t = [], 0.0
    while len(out) < n:
        t += rng.exponential(1.0 / lam_max)
        lam_t = rate * (1.0 + burst_amp
                        * math.sin(2.0 * math.pi * t / burst_period))
        if rng.random() * lam_max <= lam_t:
            out.append(t)
    return np.asarray(out, np.float64)


def lognormal_lengths(n: int, *, rng: np.random.Generator, log_mean: float,
                      sigma: float, bounds: Tuple[int, int]) -> np.ndarray:
    """n heavy-tailed integer lengths: round(lognormal(log_mean, sigma))
    clipped to the inclusive ``bounds`` — the standard stand-in for real
    prompt/output length distributions (a few giants, many shorts)."""
    lo, hi = bounds
    if not 1 <= lo <= hi:
        raise ValueError(f"bad length bounds {bounds}")
    raw = np.round(rng.lognormal(log_mean, sigma, size=n))
    return np.clip(raw, lo, hi).astype(np.int64)


def poisson_requests(n: int, *, seed: int = 0, vocab: int = 512,
                     arrival_rate: float = 0.25, burst_amp: float = 0.0,
                     burst_period: float = 64.0,
                     prompt_bounds: Tuple[int, int] = (2, 32),
                     prompt_log_mean: float = 2.0,
                     prompt_sigma: float = 0.6,
                     new_bounds: Tuple[int, int] = (1, 16),
                     new_log_mean: float = 1.4, new_sigma: float = 0.7,
                     temperature: float = 0.0,
                     temperature_every: int = 0,
                     deadline_ticks: Optional[float] = None) -> List[Request]:
    """n requests with Poisson/bursty tick-domain arrivals (``.arrival``)
    and lognormal prompt / output-budget lengths.  Seeded and fully
    reproducible; uids follow arrival order.  ``deadline_ticks`` gives
    every request an absolute deadline ``arrival + deadline_ticks`` —
    the engines' ShedPolicy then sheds/time-outs work that cannot meet
    it (DESIGN.md §16)."""
    rng = np.random.default_rng(seed)
    arrivals = poisson_arrivals(n, rate=arrival_rate, rng=rng,
                                burst_amp=burst_amp,
                                burst_period=burst_period)
    plens = lognormal_lengths(n, rng=rng, log_mean=prompt_log_mean,
                              sigma=prompt_sigma, bounds=prompt_bounds)
    nnew = lognormal_lengths(n, rng=rng, log_mean=new_log_mean,
                             sigma=new_sigma, bounds=new_bounds)
    reqs = []
    for i in range(n):
        prompt = [int(t) for t in rng.integers(1, vocab, size=int(plens[i]))]
        temp = (temperature if temperature_every and
                (i + 1) % temperature_every == 0 else 0.0)
        reqs.append(Request(
            uid=i, prompt=prompt, max_new_tokens=int(nnew[i]),
            temperature=temp, arrival=float(arrivals[i]),
            deadline=(None if deadline_ticks is None
                      else float(arrivals[i]) + float(deadline_ticks))))
    return reqs


def run_arrivals(engine, reqs: Sequence[Request],
                 max_ticks: int = 100_000,
                 strict: bool = True) -> Dict[int, List[int]]:
    """Drive ``engine`` by per-request arrival times instead of fixed
    groups: each request is submitted at the first host sync point whose
    tick clock has reached its ``arrival`` (requests without one arrive
    at tick 0).  When the engine goes idle before the next arrival, the
    tick clock fast-forwards to it — idle ticks decode nothing but still
    count against ``max_ticks``.  Returns {uid: output tokens}; with
    ``strict`` (default) raises if any request failed to reach a
    terminal state in budget (shed / timed-out / failed requests ARE
    terminal: admission control resolving a request is a served
    outcome, not a hang — DESIGN.md §16).
    """
    order = sorted(reqs, key=lambda r: (r.arrival or 0.0, r.uid))
    pending = collections.deque(order)
    start = engine.ticks
    k = engine.ticks_per_sync
    while True:
        while pending and (pending[0].arrival or 0.0) <= engine.ticks:
            engine.submit(pending.popleft())
        if engine._queue or any(r is not None for r in engine.slot_req):
            if engine.ticks - start + k > max_ticks:
                break
            n = engine.step()
            if (n == 0 and engine._queue
                    and getattr(engine, "_last_admitted", 1) == 0):
                # resource stall (nothing active, nothing admissible):
                # advance the clock so deadlines expire and the budget
                # check terminates the loop — never spin forever
                engine.ticks += k
        elif pending:
            nxt = max(engine.ticks, int(math.ceil(pending[0].arrival or 0.0)))
            if nxt - start > max_ticks:
                break
            engine.ticks = nxt   # idle fast-forward to the next arrival
        else:
            break
    stuck = [r for r in reqs if not r.terminal]
    if strict and stuck:
        hist = collections.Counter(r.state for r in reqs)
        missing = sorted(r.uid for r in stuck)
        raise RuntimeError(f"requests {missing} did not finish "
                           f"within {max_ticks} ticks "
                           f"(terminal states: {dict(hist)})")
    return {r.uid: list(r.output) for r in reqs if r.done}
