from repro.serve.engine import (Engine, EngineReference, PagedEngine,
                                Request, engine_reference)
from repro.serve.paged import PagePool, RadixTree, pages_for
from repro.serve.telemetry import (Tracer, latency_summary, percentile,
                                   request_latency, summarize,
                                   validate_chrome_trace)
from repro.serve.workload import (lognormal_lengths, mixed_requests,
                                  poisson_arrivals, poisson_requests,
                                  run_arrivals, run_staggered,
                                  shared_prefix_requests, staggered_groups)

__all__ = ["Engine", "EngineReference", "PagedEngine", "Request",
           "engine_reference",
           "PagePool", "RadixTree", "pages_for",
           "Tracer", "latency_summary", "percentile", "request_latency",
           "summarize", "validate_chrome_trace",
           "lognormal_lengths", "mixed_requests", "poisson_arrivals",
           "poisson_requests", "run_arrivals", "run_staggered",
           "shared_prefix_requests", "staggered_groups"]
