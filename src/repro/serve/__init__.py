from repro.serve.engine import (Engine, EngineReference, Request,
                                engine_reference)
from repro.serve.workload import (mixed_requests, run_staggered,
                                  staggered_groups)

__all__ = ["Engine", "EngineReference", "Request", "engine_reference",
           "mixed_requests", "run_staggered", "staggered_groups"]
