from repro.serve.engine import (Engine, EngineReference, Request,
                                engine_reference)
from repro.serve.telemetry import (Tracer, latency_summary, percentile,
                                   request_latency, summarize,
                                   validate_chrome_trace)
from repro.serve.workload import (lognormal_lengths, mixed_requests,
                                  poisson_arrivals, poisson_requests,
                                  run_arrivals, run_staggered,
                                  staggered_groups)

__all__ = ["Engine", "EngineReference", "Request", "engine_reference",
           "Tracer", "latency_summary", "percentile", "request_latency",
           "summarize", "validate_chrome_trace",
           "lognormal_lengths", "mixed_requests", "poisson_arrivals",
           "poisson_requests", "run_arrivals", "run_staggered",
           "staggered_groups"]
