from repro.serve.chaos import Fault, FaultPlan, InjectedFault
from repro.serve.engine import (Engine, EngineReference, PagedEngine,
                                Request, UnsupportedFamilyError,
                                engine_reference)
from repro.serve.paged import (PagePool, PagePoolExhausted, RadixTree,
                               pages_for)
from repro.serve.resilience import (DONE, FAILED, PENDING, QUEUED, RUNNING,
                                    SHED, TERMINAL_STATES, TIMED_OUT,
                                    ShedPolicy, WatchdogError,
                                    WindowWatchdog)
from repro.serve.telemetry import (Tracer, latency_summary, percentile,
                                   request_latency, summarize,
                                   validate_chrome_trace)
from repro.serve.workload import (lognormal_lengths, mixed_requests,
                                  poisson_arrivals, poisson_requests,
                                  run_arrivals, run_staggered,
                                  shared_prefix_requests, staggered_groups)

__all__ = ["Engine", "EngineReference", "PagedEngine", "Request",
           "UnsupportedFamilyError", "engine_reference",
           "PagePool", "PagePoolExhausted", "RadixTree", "pages_for",
           "Fault", "FaultPlan", "InjectedFault",
           "DONE", "FAILED", "PENDING", "QUEUED", "RUNNING", "SHED",
           "TERMINAL_STATES", "TIMED_OUT",
           "ShedPolicy", "WatchdogError", "WindowWatchdog",
           "Tracer", "latency_summary", "percentile", "request_latency",
           "summarize", "validate_chrome_trace",
           "lognormal_lengths", "mixed_requests", "poisson_arrivals",
           "poisson_requests", "run_arrivals", "run_staggered",
           "shared_prefix_requests", "staggered_groups"]
