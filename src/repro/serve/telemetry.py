"""SLO-grade serve telemetry: per-request latency percentiles + tracing.

Two independent pieces (DESIGN.md §14):

* **Latency accounting.**  ``Request`` (serve/engine.py) carries
  submit/admit/first-token/done stamps in BOTH time domains — engine
  ticks (deterministic, schedule-comparable across engines) and wall
  clock (``time.perf_counter``, what a client actually waits).  This
  module turns a finished request set into the three serving metrics a
  production SLO is written against:

    TTFT  time-to-first-token: first_token − reference point (the
          request's intended ``arrival`` when a traffic generator set
          one, else its submit stamp — so tick-domain TTFT includes the
          up-to-K admission delay of the sync cadence);
    TPOT  time-per-output-token: (done − first_token) / (tokens − 1),
          defined only for multi-token outputs;
    E2E   end-to-end: done − reference point.

  ``latency_summary`` reports p50/p95/p99 (+ mean/max) of each metric in
  each domain.  The percentile math is the standard linear-interpolation
  estimator (numpy's default) implemented here so a hand-computed trace
  can pin it in tests.

* **Chrome-trace export.**  ``Tracer`` collects engine spans — batched
  prefill calls, fused decode windows, host drains — plus an
  active-slots counter track, and serializes them as Trace Event JSON
  (``chrome://tracing`` / Perfetto "X"/"C"/"M" events, microsecond
  timestamps).  The engine calls ``span``/``counter`` only when a tracer
  is attached, so the hot path pays nothing by default.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence

from repro.serve.engine import Request

PERCENTILES = (50.0, 95.0, 99.0)


def percentile(xs: Sequence[float], q: float) -> float:
    """Linear-interpolation percentile (numpy's default method): for
    sorted x of length n, rank ``(n-1) * q/100`` interpolated between the
    two neighbouring order statistics."""
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q={q} outside [0, 100]")
    xs = sorted(float(x) for x in xs)
    if not xs:
        raise ValueError("percentile of an empty sequence")
    rank = (len(xs) - 1) * (q / 100.0)
    lo = int(rank)
    hi = min(lo + 1, len(xs) - 1)
    frac = rank - lo
    return xs[lo] + frac * (xs[hi] - xs[lo])


def summarize(xs: Sequence[float],
              qs: Sequence[float] = PERCENTILES) -> Dict[str, float]:
    """{"p50": ..., "p95": ..., "p99": ..., "mean": ..., "max": ...}."""
    xs = [float(x) for x in xs]
    if not xs:
        return {}
    out = {f"p{q:g}": percentile(xs, q) for q in qs}
    out["mean"] = sum(xs) / len(xs)
    out["max"] = max(xs)
    return out


def request_latency(req: Request) -> Optional[Dict[str, Dict[str, float]]]:
    """Per-request {wall: {ttft_s, tpot_s?, e2e_s}, ticks: {...}} or None
    if the request has not finished (or predates the stamping engine)."""
    if not (req.done and req.done_time is not None
            and req.first_token_time is not None
            and req.submit_time is not None):
        return None
    n = len(req.output)
    wall = {"ttft_s": req.first_token_time - req.submit_time,
            "e2e_s": req.done_time - req.submit_time}
    # tick-domain latencies measure from the intended arrival when the
    # traffic generator set one (charging the sync-cadence admission
    # delay), else from the submit tick
    ref = req.arrival if req.arrival is not None else req.submit_tick
    ticks = {"ttft": req.first_token_tick - ref,
             "e2e": req.done_tick - ref}
    if n > 1:
        wall["tpot_s"] = (req.done_time - req.first_token_time) / (n - 1)
        ticks["tpot"] = (req.done_tick - req.first_token_tick) / (n - 1)
    return {"wall": wall, "ticks": ticks}


def latency_summary(reqs: Iterable[Request],
                    qs: Sequence[float] = PERCENTILES) -> dict:
    """Aggregate TTFT/TPOT/E2E percentiles over finished requests.

    Returns {"n", "completed", "tokens", "wall": {ttft_s/tpot_s/e2e_s ->
    summarize()}, "ticks": {ttft/tpot/e2e -> summarize()}}; requests that
    never finished count in ``n`` but not in the percentiles.
    """
    reqs = list(reqs)
    per = [(r, request_latency(r)) for r in reqs]
    finished = [(r, lat) for r, lat in per if lat is not None]
    states: Dict[str, int] = {}
    for r in reqs:
        states[r.state] = states.get(r.state, 0) + 1
    out = {"n": len(reqs), "completed": len(finished),
           "tokens": sum(len(r.output) for r, _ in finished),
           # terminal-state histogram + degraded-traffic counters
           # (DESIGN.md §16): shed/timed-out/failed requests count in
           # ``n`` and ``states`` but never in the percentiles
           "states": states,
           "shed": states.get("SHED", 0),
           "timed_out": states.get("TIMED_OUT", 0),
           "failed": states.get("FAILED", 0),
           "retries": sum(r.retries for r in reqs),
           "preemptions": sum(r.preemptions for r in reqs),
           "wall": {}, "ticks": {}}
    for domain in ("wall", "ticks"):
        keys = sorted({k for _, lat in finished for k in lat[domain]})
        out[domain] = {
            k: summarize([lat[domain][k] for _, lat in finished
                          if k in lat[domain]], qs)
            for k in keys}
    return out


# ---- chrome://tracing export ------------------------------------------------

_REQUIRED_BY_PHASE = {"X": ("name", "ts", "dur", "pid", "tid"),
                      "B": ("name", "ts", "pid", "tid"),
                      "E": ("ts", "pid", "tid"),
                      "i": ("name", "ts", "pid"),
                      "C": ("name", "ts", "pid"),
                      "M": ("name", "pid")}


class Tracer:
    """Collects engine spans/counters; exports Trace Event Format JSON.

    Wall-clock inputs are ``time.perf_counter`` seconds; the exporter
    rebases them onto the first recorded event and converts to the
    microsecond ``ts``/``dur`` the trace viewers expect.
    """

    def __init__(self, name: str = "serve-engine"):
        self.name = name
        self._spans: List[dict] = []      # (name, cat, t0, t1, tid, args)
        self._counters: List[dict] = []   # (name, values, t, tid)
        self._nested: List[dict] = []     # "B"/"E" duration events, in order
        self._instants: List[dict] = []   # "i" point events
        self._open: List[dict] = []       # begin() stack awaiting end()

    def span(self, name: str, cat: str, start_s: float, end_s: float,
             tid: int = 0, args: Optional[dict] = None) -> None:
        if end_s < start_s:
            raise ValueError(f"span {name!r}: end {end_s} < start {start_s}")
        self._spans.append({"name": name, "cat": cat, "t0": start_s,
                            "t1": end_s, "tid": tid, "args": args or {}})

    # -- nested spans (paged engine: admit > prefill-chunk > CoW ...) --------

    def begin(self, name: str, cat: str, when_s: float, tid: int = 0,
              args: Optional[dict] = None) -> None:
        """Open a nested span ("B" phase); close with ``end()``.  Unlike
        ``span``, begin/end pairs may enclose other spans and instants —
        the viewer stacks them by arrival order per thread."""
        ev = {"ph": "B", "name": name, "cat": cat, "t": when_s, "tid": tid,
              "args": args or {}}
        self._open.append(ev)
        self._nested.append(ev)

    def end(self, when_s: float, tid: int = 0,
            args: Optional[dict] = None) -> None:
        """Close the innermost open ``begin()`` span ("E" phase)."""
        if not self._open:
            raise ValueError("end() without a matching begin()")
        opened = self._open[-1]
        if when_s < opened["t"]:
            # raise BEFORE popping so a rejected end() leaves the span
            # open instead of orphaning its "B" event in the trace
            raise ValueError(
                f"span {opened['name']!r}: end {when_s} < begin "
                f"{opened['t']}")
        self._open.pop()
        self._nested.append({"ph": "E", "name": opened["name"],
                             "cat": opened["cat"], "t": when_s, "tid": tid,
                             "args": args or {}})

    def instant(self, name: str, cat: str, when_s: float, tid: int = 0,
                args: Optional[dict] = None) -> None:
        """Point-in-time event ("i" phase) — CoW copies, page gathers."""
        self._instants.append({"name": name, "cat": cat, "t": when_s,
                               "tid": tid, "args": args or {}})

    def counter(self, name: str, values: Dict[str, float], when_s: float,
                tid: int = 0) -> None:
        self._counters.append({"name": name, "values": dict(values),
                               "t": when_s, "tid": tid})

    def _origin(self) -> float:
        times = ([s["t0"] for s in self._spans]
                 + [c["t"] for c in self._counters]
                 + [e["t"] for e in self._nested]
                 + [e["t"] for e in self._instants])
        return min(times) if times else 0.0

    def to_chrome_trace(self) -> dict:
        if self._open:
            raise ValueError(
                f"unclosed begin() spans: "
                f"{[e['name'] for e in self._open]}")
        origin = self._origin()
        us = lambda t: (t - origin) * 1e6   # noqa: E731
        events: List[dict] = [
            {"ph": "M", "name": "process_name", "pid": 0, "tid": 0,
             "args": {"name": self.name}},
            {"ph": "M", "name": "thread_name", "pid": 0, "tid": 0,
             "args": {"name": "engine"}},
        ]
        for s in self._spans:
            events.append({"ph": "X", "name": s["name"], "cat": s["cat"],
                           "ts": us(s["t0"]), "dur": us(s["t1"]) - us(s["t0"]),
                           "pid": 0, "tid": s["tid"], "args": s["args"]})
        for e in self._nested:   # emitted in call order (B/E pairing)
            events.append({"ph": e["ph"], "name": e["name"], "cat": e["cat"],
                           "ts": us(e["t"]), "pid": 0, "tid": e["tid"],
                           "args": e["args"]})
        for e in self._instants:
            events.append({"ph": "i", "name": e["name"], "cat": e["cat"],
                           "ts": us(e["t"]), "pid": 0, "tid": e["tid"],
                           "s": "t", "args": e["args"]})
        for c in self._counters:
            events.append({"ph": "C", "name": c["name"], "ts": us(c["t"]),
                           "pid": 0, "tid": c["tid"], "args": c["values"]})
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"producer": "repro.serve.telemetry"}}

    def save(self, path) -> Path:
        path = Path(path)
        trace = self.to_chrome_trace()
        validate_chrome_trace(trace)
        path.write_text(json.dumps(trace, indent=1) + "\n")
        return path


def validate_chrome_trace(obj: dict) -> None:
    """Raise ValueError unless ``obj`` is structurally valid Trace Event
    JSON (the subset this exporter emits): a ``traceEvents`` list whose
    events carry a known ``ph``, the per-phase required keys,
    non-negative numeric ``ts``/``dur``, and balanced "B"/"E" nesting
    per (pid, tid) track in list order."""
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("trace must be a dict with a 'traceEvents' list")
    events = obj["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    depth: Dict[tuple, int] = {}
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph not in _REQUIRED_BY_PHASE:
            raise ValueError(f"event {i}: unknown phase {ph!r}")
        missing = [k for k in _REQUIRED_BY_PHASE[ph] if k not in ev]
        if missing:
            raise ValueError(f"event {i} (ph={ph}): missing keys {missing}")
        for k in ("ts", "dur"):
            if k in ev and (not isinstance(ev[k], (int, float))
                            or ev[k] < 0):
                raise ValueError(f"event {i}: {k}={ev[k]!r} must be a "
                                 "non-negative number")
        if ph in ("B", "E"):
            track = (ev.get("pid"), ev.get("tid"))
            d = depth.get(track, 0) + (1 if ph == "B" else -1)
            if d < 0:
                raise ValueError(
                    f"event {i}: 'E' without a matching 'B' on track "
                    f"{track}")
            depth[track] = d
    open_tracks = {t: d for t, d in depth.items() if d}
    if open_tracks:
        raise ValueError(f"unbalanced 'B' spans left open: {open_tracks}")
