from repro.sharding.rules import (activation_sharding, constrain,
                                  default_rules, spec_for, tree_specs,
                                  tree_shardings)

__all__ = ["activation_sharding", "constrain", "default_rules", "spec_for",
           "tree_specs", "tree_shardings"]
