"""Logical-axis sharding rules with divisibility-aware fallback.

Every parameter / activation in the model substrate carries a tuple of
*logical* axis names (e.g. ``("layers", "embed", "heads", "head_dim")``).
This module maps those to mesh ``PartitionSpec``s given a rule table, in
priority order, dropping assignments that fail divisibility or would reuse a
mesh axis within one spec. This is the MaxText-style mechanism that lets a
new architecture get correct sharding from annotations alone.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[str, Tuple[str, ...]]

# (logical axis -> ordered candidate mesh-axis groups, priority)
# Lower priority number is assigned first, so it wins contested mesh axes.
Rule = Tuple[Tuple[MeshAxes, ...], int]


def default_rules(*, fsdp: bool = True, multi_pod: bool = False,
                  seq_parallel: bool = True,
                  strategy: str = "tp") -> Dict[str, Rule]:
    """Rule tables.

    strategy="tp" (default): TP over "model", DP over "data" (x "pod"),
    FSDP param sharding over "data", sequence parallelism (residual
    activations sharded over "model" between TP regions — Korthikanti et
    al.; decode's seq=1 auto-falls back).

    strategy="fsdp": no tensor parallelism — batch is sharded over
    ("data","model") jointly (256-way DP on the single-pod mesh) and
    parameters are ZeRO-3-sharded over the same axes; "pod" stays pure DP.
    Trades TP's per-layer activation collectives for per-layer bf16 param
    all-gathers — the better regime when d_model-scale activations dwarf
    per-layer weights on slow links (§Perf iteration L1).
    """
    if strategy == "fsdp":
        dp2: Tuple[str, ...] = ("data", "model")
        # candidate groups: prefer 256-way ZeRO-3, fall back to 16-way
        fa: Tuple[MeshAxes, ...] = (("data", "model"), ("data",))
        rules: Dict[str, Rule] = {
            "batch": (((dp2),), 0),
            "seq": ((), 50),
            "embed_act": ((), 50),
            "heads": ((), 40),
            "kv_heads": ((), 40),
            "head_dim": ((), 40),
            "qkv_in": (fa, 30),
            "ffn": ((), 40),
            "ffn_in": (fa, 30),
            "experts": ((("model",),), 5),
            "expert_ffn": ((), 40),
            "capacity": ((), 40),
            "vocab": ((), 40),
            "embed": (fa, 30),
            "ssm_inner": (fa, 30),
            "ssm_heads": ((), 40),
            "ssm_state": ((), 40),
            "ssm_head_dim": ((), 40),
            "lru": (fa, 30),
            "conv_w": ((), 50),
            "kv_seq": ((("model",),), 20),
            "layers": ((), 99),
            "stack": ((), 99),
        }
        return rules

    dp: Tuple[str, ...] = ("pod", "data") if multi_pod else ("data",)
    fsdp_axes: Tuple[MeshAxes, ...] = (("data",),) if fsdp else ()
    sp_axes: Tuple[MeshAxes, ...] = ((("model",),) if seq_parallel else ())
    rules: Dict[str, Rule] = {
        # activations
        "batch": ((dp,), 0),
        "seq": (sp_axes, 45),
        "embed_act": ((), 50),
        # attention params
        "heads": ((("model",),), 10),
        "kv_heads": ((("model",),), 10),
        "head_dim": ((), 40),
        "qkv_in": (fsdp_axes, 30),        # fsdp shard of the non-TP dim
        "ffn": ((("model",),), 10),
        "ffn_in": (fsdp_axes, 30),
        "experts": ((("model",),), 5),    # EP first choice for MoE
        "expert_ffn": ((("model",),), 15),  # expert-TP fallback
        "capacity": ((("model",),), 25),  # data-parallel-inside-MoE fallback
        "vocab": ((("model",),), 10),
        "embed": (fsdp_axes, 30),
        # ssm / recurrent params
        "ssm_inner": ((("model",),), 10),
        "ssm_heads": ((("model",),), 12),
        "ssm_state": ((), 40),
        "ssm_head_dim": ((), 40),
        "lru": ((("model",),), 10),
        "conv_w": ((), 50),
        # kv cache (decode): prefer kv_heads, fall back to sequence sharding
        "kv_seq": ((("model",),), 20),
        # scan/stack dims are never sharded
        "layers": ((), 99),
        "stack": ((), 99),
    }
    return rules


def spec_for(
    axes: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh_shape: Dict[str, int],
    rules: Dict[str, Rule],
) -> P:
    """Build a PartitionSpec for one array.

    Dims are assigned in ascending rule priority; a mesh axis is used at most
    once per spec; assignments failing divisibility fall through to the next
    candidate group (or None).
    """
    assert len(axes) == len(shape), (axes, shape)
    order = sorted(
        range(len(axes)),
        key=lambda i: rules.get(axes[i], ((), 100))[1] if axes[i] else 100,
    )
    assigned: list = [None] * len(axes)
    used: set = set()
    for i in order:
        name = axes[i]
        if not name or name not in rules:
            continue
        candidates, _ = rules[name]
        for group in candidates:
            group_t = (group,) if isinstance(group, str) else tuple(group)
            if not group_t:
                continue
            if any(g in used or g not in mesh_shape for g in group_t):
                continue
            n = int(np.prod([mesh_shape[g] for g in group_t]))
            if n <= 1 or shape[i] % n != 0:
                continue
            assigned[i] = group_t[0] if len(group_t) == 1 else group_t
            used.update(group_t)
            break
    while assigned and assigned[-1] is None:
        assigned.pop()
    return P(*assigned)


def tree_specs(axes_tree, shape_tree, mesh: Mesh, rules: Dict[str, Rule]):
    """Map a pytree of logical-axes tuples + shapes to PartitionSpecs."""
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    return jax.tree.map(
        lambda ax, sh: spec_for(ax, sh.shape, mesh_shape, rules),
        axes_tree,
        shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def tree_shardings(axes_tree, shape_tree, mesh: Mesh, rules: Dict[str, Rule]):
    specs = tree_specs(axes_tree, shape_tree, mesh, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Activation sharding constraints.
#
# GSPMD propagation alone mis-shards activations when FSDP param shardings
# leak into the forward pass (e.g. embedding's "data"-sharded embed dim
# propagating into (B,S,D) activations and replicating batch). Models call
# ``constrain(x, logical_axes)`` at layer boundaries; the dry-run/launcher
# installs a sharder built from the active mesh + rules. Outside a mesh
# context (unit tests, CPU smoke runs) ``constrain`` is the identity.
# ---------------------------------------------------------------------------

_ACTIVATION_SHARDER = None


class activation_sharding:
    """Context manager installing an activation sharder for a mesh+rules."""

    def __init__(self, mesh: Mesh, rules: Dict[str, Rule]):
        mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))

        def sharder(x, axes):
            spec = spec_for(axes, x.shape, mesh_shape, rules)
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, spec))

        self._sharder = sharder

    def __enter__(self):
        global _ACTIVATION_SHARDER
        self._prev = _ACTIVATION_SHARDER
        _ACTIVATION_SHARDER = self._sharder
        return self

    def __exit__(self, *exc):
        global _ACTIVATION_SHARDER
        _ACTIVATION_SHARDER = self._prev
        return False


def constrain(x, axes: Sequence[Optional[str]]):
    """Apply the active activation-sharding constraint (identity if none)."""
    if _ACTIVATION_SHARDER is None:
        return x
    return _ACTIVATION_SHARDER(x, tuple(axes))
