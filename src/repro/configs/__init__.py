"""Architecture registry: ``get_config("llama3-8b")``, ``list_archs()``."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (
    ModelConfig,
    ShapeConfig,
    SHAPES,
    TRAIN_4K,
    PREFILL_32K,
    DECODE_32K,
    LONG_500K,
    reduced,
    smoke_shape,
)

_ARCH_MODULES = {
    "whisper-tiny": "whisper_tiny",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "llama3-8b": "llama3_8b",
    "qwen2-7b": "qwen2_7b",
    "phi3-mini-3.8b": "phi3_mini_38b",
    "gemma2-27b": "gemma2_27b",
    "internvl2-26b": "internvl2_26b",
    "mamba2-1.3b": "mamba2_13b",
    "recurrentgemma-2b": "recurrentgemma_2b",
}


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list_archs()}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in list_archs()}


def cells(arch: str) -> List[ShapeConfig]:
    """Runnable (arch x shape) cells, honoring documented skips."""
    cfg = get_config(arch)
    return [s for s in SHAPES.values() if s.name not in cfg.skip_shapes]


__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "TRAIN_4K", "PREFILL_32K",
    "DECODE_32K", "LONG_500K", "reduced", "smoke_shape", "get_config",
    "list_archs", "all_configs", "cells",
]
