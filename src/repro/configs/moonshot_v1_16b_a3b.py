"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (GQA kv=16) d_ff=1408/expert,
vocab=163840, MoE 64 experts top-6 (kimi / Moonlight-16B-A3B).
[hf:moonshotai/Moonlight-16B-A3B]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163840,
    num_experts=64,
    top_k=6,
    rope_theta=50000.0,
    mlp_act="silu",
    gated_mlp=True,
    tie_embeddings=False,
    skip_shapes=("long_500k",),
)
