"""whisper-tiny [audio]: 4L (enc) + 4L (dec), d_model=384, 6H MHA, d_ff=1536,
vocab=51865. Encoder-decoder; conv audio frontend is a STUB — ``input_specs``
feeds precomputed (B, S, 384) frame embeddings. [arXiv:2212.04356]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="whisper-tiny",
    family="encdec",
    num_layers=4,          # per-stack depth (enc_layers/dec_layers below)
    enc_layers=4,
    dec_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    mlp_act="gelu",
    gated_mlp=False,
    rope_theta=0.0,        # whisper uses absolute positions, not RoPE
    tie_embeddings=True,
    scan_layers=False,     # 4+4 small layers — unrolled
    skip_shapes=("long_500k",),
)
