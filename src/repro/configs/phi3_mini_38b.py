"""phi3-mini-3.8b [dense]: 32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064.

RoPE + SwiGLU + (degenerate) GQA == MHA. [arXiv:2404.14219]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="phi3-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=10000.0,
    mlp_act="silu",
    gated_mlp=True,
    tie_embeddings=False,
    skip_shapes=("long_500k",),
)
