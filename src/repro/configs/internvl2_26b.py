"""internvl2-26b [vlm]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.

InternViT frontend is a STUB — ``input_specs`` provides precomputed patch
embeddings that replace the first ``vision_tokens`` positions; the backbone
(InternLM2-20B-class) is implemented in full. [arXiv:2404.16821]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    rope_theta=1000000.0,
    mlp_act="silu",
    gated_mlp=True,
    vision_tokens=256,
    tie_embeddings=False,
    skip_shapes=("long_500k",),
)
