"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512/expert,
vocab=49155, MoE 40 experts top-8. [hf:ibm-granite/granite-3.0 family]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    num_experts=40,
    top_k=8,
    rope_theta=10000.0,
    mlp_act="silu",
    gated_mlp=True,
    tie_embeddings=True,
    skip_shapes=("long_500k",),
)
