"""Config system: model architecture configs, input-shape cells, reduction.

Every assigned architecture is a ``ModelConfig`` in ``src/repro/configs/<id>.py``;
the registry in ``configs/__init__.py`` resolves ``--arch <id>``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Input-shape cells (same four for every LM-family arch, per assignment).
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters + runtime knobs.

    ``family`` controls which block stack is built:
      dense | moe | ssm | hybrid | encdec | vlm
    """

    arch: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # attention details
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    local_window: int = 0           # 0 = global attention
    alt_local_global: bool = False  # gemma2: even layers local, odd global
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    mlp_act: str = "silu"           # silu (SwiGLU) | gelu (GeGLU / plain)
    gated_mlp: bool = True

    # MoE
    num_experts: int = 0
    top_k: int = 0
    moe_capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # hybrid (recurrentgemma): block pattern string, e.g. "RRA" tiled
    block_pattern: str = ""
    lru_width: int = 0

    # enc-dec (whisper)
    enc_layers: int = 0
    dec_layers: int = 0

    # vlm stub
    vision_tokens: int = 0

    # runtime knobs
    dtype: str = "bfloat16"
    scan_layers: bool = True
    remat: str = "full"             # none | dots | full
    fsdp: bool = True               # shard params/opt state over data axis
    tie_embeddings: bool = True

    # which shape cells this arch runs (skips documented in DESIGN.md §4)
    skip_shapes: Tuple[str, ...] = ()

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks)."""
        c = self
        n = c.vocab_size * c.d_model  # embeddings (tied)
        if not c.tie_embeddings:
            n += c.vocab_size * c.d_model
        per_layer = 0
        if c.family == "ssm":
            d_in = c.ssm_expand * c.d_model
            d_xbc = d_in + 2 * c.ssm_state
            per_layer = c.d_model * (d_in + d_xbc + c.ssm_heads)  # in_proj
            per_layer += c.ssm_conv_width * d_xbc                  # conv
            per_layer += d_in * c.d_model                          # out_proj
            per_layer += 3 * c.ssm_heads                           # A, dt_bias, D
            n += c.num_layers * per_layer
            return n
        attn = c.d_model * c.num_heads * c.head_dim * 2
        attn += c.d_model * c.num_kv_heads * c.head_dim * 2
        mlp_in = 2 * c.d_ff if c.gated_mlp else c.d_ff
        if c.is_moe:
            mlp = c.num_experts * (c.d_model * mlp_in + c.d_ff * c.d_model)
            mlp += c.d_model * c.num_experts  # router
        else:
            mlp = c.d_model * mlp_in + c.d_ff * c.d_model
        if c.family == "hybrid":
            # mix of recurrent + attention blocks
            pat = c.block_pattern or "A"
            n_attn = sum(1 for i in range(c.num_layers) if pat[i % len(pat)] == "A")
            n_rec = c.num_layers - n_attn
            rec = c.d_model * c.lru_width * 2 + c.lru_width * c.d_model + 4 * c.lru_width
            n += n_attn * (attn + mlp) + n_rec * (rec + mlp)
            return n
        if c.family == "encdec":
            # encoder: self+mlp, decoder: self+cross+mlp
            n += c.enc_layers * (attn + mlp) + c.dec_layers * (2 * attn + mlp)
            return n
        n += c.num_layers * (attn + mlp)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE uses top_k experts)."""
        if not self.is_moe:
            return self.param_count()
        c = self
        n = c.vocab_size * c.d_model
        attn = c.d_model * (c.num_heads + c.num_kv_heads) * c.head_dim * 2
        mlp_in = 2 * c.d_ff if c.gated_mlp else c.d_ff
        mlp = c.top_k * (c.d_model * mlp_in + c.d_ff * c.d_model)
        return n + c.num_layers * (attn + mlp + c.d_model * c.num_experts)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Smoke-test-sized config of the same family (CPU-runnable)."""
    small = dict(
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        remat="none",
        fsdp=False,
    )
    if cfg.is_moe:
        small.update(num_experts=8, top_k=2, d_ff=64)
    if cfg.family == "ssm":
        small.update(ssm_state=16, ssm_heads=4, ssm_head_dim=32, ssm_chunk=32,
                     num_heads=0, num_kv_heads=0, head_dim=0, d_ff=0)
    if cfg.family == "hybrid":
        small.update(lru_width=64, num_layers=3, local_window=32)
    if cfg.family == "encdec":
        small.update(enc_layers=2, dec_layers=2)
    if cfg.local_window:
        small.update(local_window=min(cfg.local_window, 32))
    if cfg.vision_tokens:
        small.update(vision_tokens=8)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)


def smoke_shape(kind: str = "train") -> ShapeConfig:
    return ShapeConfig(f"smoke_{kind}", 64, 2, kind)
