"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000, RG-LRU + local attention, temporal pattern (R,R,A).

Runs ``long_500k`` (O(1) LRU state, 2048-token local attention window).
[arXiv:2402.19427]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    local_window=2048,
    block_pattern="RRA",
    lru_width=2560,
    mlp_act="gelu",
    gated_mlp=True,
    tie_embeddings=True,
    scan_layers=False,     # heterogeneous block stack — unrolled
)
