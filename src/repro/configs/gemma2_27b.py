"""gemma2-27b [dense]: 46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000.

Local(4096)/global alternating attention with logit soft-capping.
[arXiv:2408.00118]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    rope_theta=10000.0,
    local_window=4096,
    alt_local_global=True,
    attn_softcap=50.0,
    final_softcap=30.0,
    mlp_act="gelu",
    gated_mlp=True,
    tie_embeddings=True,
    skip_shapes=("long_500k",),  # global layers are full attention
)
