"""mamba2-1.3b [ssm]: 48L d_model=2048, attn-free, vocab=50280, ssm_state=128.

SSD (state-space duality): d_inner = 2*d_model = 4096, 64 heads x headdim 64.
Runs ``long_500k`` (O(1) recurrent state). [arXiv:2405.21060]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_heads=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=256,
    tie_embeddings=True,
)
