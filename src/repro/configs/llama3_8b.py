"""llama3-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.

GQA, 128k vocab. [arXiv:2407.21783]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="llama3-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500000.0,
    mlp_act="silu",
    gated_mlp=True,
    tie_embeddings=False,
    skip_shapes=("long_500k",),  # pure full attention — see DESIGN.md §4
)
