"""Top-k MoE layer with sort-based capacity dispatch (GShard/MaxText-style).

Dispatch is sort-based rather than one-hot-einsum-based: assignments are
sorted by expert id, ranked within expert, dropped beyond capacity, gathered
into an (E, C, D) buffer, run through batched expert FFNs, and scattered
back weighted by router gates. This keeps peak memory at O(E*C*D) — the
same order as the expert compute itself — instead of O(T*E*C).

Expert parallelism: the (E, C, D) buffer carries logical axes
("experts", "capacity", ...); the rule engine shards experts over "model"
when divisible (moonshot: 64/16) and falls back to capacity-sharding when
not (granite: 40 experts -> expert weights sharded over expert_ffn).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamDef, ParamDefs, Params, activation
from repro.sharding import constrain


def padded_experts(cfg: ModelConfig) -> int:
    """Experts padded to a multiple of 16 so the EP sharding rule
    ("experts" -> model axis) engages for ragged counts (granite: 40 -> 48;
    the dummy experts are never routed to — §Perf iteration G1). Counts
    already divisible are left alone (moonshot: 64)."""
    E = cfg.num_experts
    return E if E % 16 == 0 else ((E + 15) // 16) * 16


def moe_param_defs(cfg: ModelConfig) -> ParamDefs:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    Ep = padded_experts(cfg)
    defs: ParamDefs = {
        "router": ParamDef((D, E), ("ffn_in", "experts"), scale=D ** -0.5),
        "w_up": ParamDef((Ep, D, F), ("experts", "ffn_in", "expert_ffn")),
        "w_down": ParamDef((Ep, F, D), ("experts", "expert_ffn", "ffn_in")),
    }
    if cfg.gated_mlp:
        defs["w_gate"] = ParamDef((Ep, D, F),
                                  ("experts", "ffn_in", "expert_ffn"))
    return defs


def capacity(cfg: ModelConfig, group_tokens: int) -> int:
    c = int(group_tokens * cfg.top_k * cfg.moe_capacity_factor
            / cfg.num_experts + 0.999)
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU-friendly shapes


def moe_block(cfg: ModelConfig, p: Params, x: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss).

    Dispatch is GROUPED by batch row (GShard-style groups): each row's S
    tokens are sorted/ranked/dropped independently with per-group capacity
    C = S*k*cf/E, so every dispatch buffer carries a leading "batch" dim
    that stays sharded over the data axis — the global-token-count variant
    materializes O(T_global) buffers on every device (measured 280 GiB/dev
    on granite train_4k before this change).
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    C = capacity(cfg, S)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)              # (B, S, E)
    gates, expert_idx = jax.lax.top_k(probs, K)          # (B, S, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style, computed globally)
    me = probs.mean(axis=(0, 1))                         # mean router prob
    ce = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(
        1.0 / (B * S * K))                               # token fraction
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    return _dispatch_combine(cfg, p, x, gates, expert_idx, aux, C)


@jax.named_scope("moe_dispatch")
def _dispatch_combine(cfg, p, x, gates, expert_idx, aux, C):
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    Ep = padded_experts(cfg)

    def one_group(xg, eg, gg):
        """xg: (S, D); eg/gg: (S, K) -> expert buffer + combine metadata.

        Dispatch is GATHER-based: a tiny int32 scatter builds the
        slot -> source-token map, then the (Ep*C, D) buffer is a gather.
        GSPMD partitions gathers with sharded outputs locally, whereas a
        data-dependent (Ep*C, D) scatter forced all-reduce merges of
        per-shard partials (measured 843 GB/device of all-reduce on
        granite train_4k — §Perf iteration G2).
        """
        e_flat = eg.reshape(-1)                          # (S*K,)
        g_flat = gg.reshape(-1)
        t_flat = jnp.repeat(jnp.arange(S), K)
        order = jnp.argsort(e_flat, stable=True)
        e_s, t_s, g_s = e_flat[order], t_flat[order], g_flat[order]
        counts = jnp.zeros((E,), jnp.int32).at[e_s].add(1)
        starts = jnp.cumsum(counts) - counts
        rank = jnp.arange(S * K) - starts[e_s]
        keep = rank < C
        dest = jnp.where(keep, e_s * C + rank, Ep * C)   # Ep*C = drop slot
        src = jnp.full((Ep * C + 1,), S, jnp.int32).at[dest].set(
            t_s.astype(jnp.int32))                       # slot -> token
        xg_pad = jnp.concatenate(
            [xg, jnp.zeros((1, D), xg.dtype)], axis=0)   # token S = zeros
        buf = xg_pad[src[:-1]]                           # (Ep*C, D) gather
        return buf, (dest, t_s, g_s, keep)

    bufs, meta = jax.vmap(one_group)(x, expert_idx, gates)
    bufs = constrain(bufs.reshape(B, Ep, C, D),
                     ("batch", "experts", "capacity", "embed_act"))

    act = activation(cfg.mlp_act)
    up = jnp.einsum("becd,edf->becf", bufs, p["w_up"])
    h = act(jnp.einsum("becd,edf->becf", bufs, p["w_gate"])) * up \
        if cfg.gated_mlp else act(up)
    h = constrain(h, ("batch", "experts", "capacity", "expert_ffn"))
    out_buf = jnp.einsum("becf,efd->becd", h, p["w_down"])
    out_buf = constrain(out_buf, ("batch", "experts", "capacity",
                                  "embed_act"))

    def combine_group(ob, m):
        dest, t_s, g_s, keep = m
        flat = ob.reshape(Ep * C, D)
        picked = jnp.where(keep[:, None],
                           flat[jnp.minimum(dest, Ep * C - 1)], 0)
        weighted = picked.astype(jnp.float32) * g_s[:, None]
        return jnp.zeros((S, D), jnp.float32).at[t_s].add(weighted)

    y = jax.vmap(combine_group)(out_buf, meta)
    return y.astype(x.dtype), aux
