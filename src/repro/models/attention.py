"""GQA attention: flash-style chunked jnp implementation (XLA path) with
causal/local masking, logit soft-capping, RoPE, and KV-cache prefill/decode.

The Pallas TPU kernels in ``repro.kernels.flash_attention`` (prefill
shapes) and ``repro.kernels.decode_attention`` (the batched-serve decode
tick, selected with ``impl="pallas_decode"``) implement the same contracts
for the hardware target; ``repro.kernels.ref`` oracles match this module.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops as kernel_ops
from repro.models.common import ParamDef, ParamDefs, Params, rope, softcap

NEG_INF = -2.0e38


def attn_param_defs(cfg: ModelConfig, cross: bool = False) -> ParamDefs:
    D, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    defs: ParamDefs = {
        "wq": ParamDef((D, H, hd), ("qkv_in", "heads", "head_dim")),
        "wk": ParamDef((D, K, hd), ("qkv_in", "kv_heads", "head_dim")),
        "wv": ParamDef((D, K, hd), ("qkv_in", "kv_heads", "head_dim")),
        "wo": ParamDef((H, hd, D), ("heads", "head_dim", "qkv_in")),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((H, hd), ("heads", "head_dim"), init="zeros")
        defs["bk"] = ParamDef((K, hd), ("kv_heads", "head_dim"), init="zeros")
        defs["bv"] = ParamDef((K, hd), ("kv_heads", "head_dim"), init="zeros")
    return defs


def _mask_bias(q_pos, k_pos, *, causal: bool, window, kv_len) -> jax.Array:
    """Additive mask bias (0 or NEG_INF). q_pos (Sq,), k_pos (Bk,).

    ``window`` may be a python int (0 = global) or a traced scalar (scanned
    stacks with per-layer windows; <= 0 means global).
    """
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if isinstance(window, int):
        if window > 0:
            ok &= k_pos[None, :] > q_pos[:, None] - window
    elif window is not None:
        w = jnp.asarray(window)
        ok &= (w <= 0) | (k_pos[None, :] > q_pos[:, None] - w)
    if kv_len is not None:
        ok &= k_pos[None, :] < kv_len
    ok &= k_pos[None, :] >= 0  # ring-buffer slots may carry pos = -1 (empty)
    return jnp.where(ok, 0.0, NEG_INF)


def _blocked_kv(k, v, kv_block):
    B, Skv, K, hd = k.shape
    nblk = -(-Skv // kv_block)
    pad = nblk * kv_block - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nblk, kv_block, K, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, kv_block, K, hd).transpose(1, 0, 2, 3, 4)
    return kb, vb, nblk


def _flash_fwd_scan(q, k, v, win, qoff, kvlen, causal, logit_cap, kv_block,
                    p_bf16=False):
    """Forward flash scan. win/qoff/kvlen are f32 scalars (may be traced).

    Returns (out f32 (B,Sq,K,G,hd), lse (B,Sq,K,G)).
    """
    B, Sq, H, hd = q.shape
    _, Skv, K, _ = k.shape
    G = H // K
    scale = hd ** -0.5
    qr = q.reshape(B, Sq, K, G, hd).astype(jnp.float32) * scale
    q_pos = qoff + jnp.arange(Sq, dtype=jnp.float32)
    kb, vb, _ = _blocked_kv(k, v, kv_block)

    def body(carry, blk):
        m, l, acc, j = carry
        kj, vj = blk
        logits = jnp.einsum("bskgh,btkh->bskgt", qr, kj.astype(jnp.float32))
        logits = softcap(logits, logit_cap)
        k_pos = j * kv_block + jnp.arange(kv_block, dtype=jnp.float32)
        bias = _mask_bias(q_pos, k_pos, causal=causal, window=win,
                          kv_len=kvlen)
        logits = logits + bias[None, :, None, None, :]
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = p.astype(jnp.bfloat16) if p_bf16 else p
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bskgt,btkh->bskgh", pv, vj.astype(pv.dtype)
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new, j + 1.0), None

    m0 = jnp.full((B, Sq, K, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, K, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, K, G, hd), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(body, (m0, l0, a0, jnp.float32(0)),
                                     (kb, vb))
    lse = m + jnp.log(jnp.maximum(l, 1e-37))
    out = acc / jnp.maximum(l[..., None], 1e-37)
    return out, lse


@partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9))
def flash_attention_jnp(q, k, v, win, qoff, kvlen, causal, logit_cap,
                        kv_block, p_bf16=False):
    """Flash attention with a flash-style backward (blockwise recompute).

    Forward saves only (q, k, v, O, LSE); backward re-streams KV blocks,
    recomputes P, and accumulates dq/dk/dv — the FlashAttention-2 algorithm
    expressed in XLA. The Pallas kernel (repro.kernels.flash_attention) is
    the TPU-native version of this same contract. win/qoff/kvlen are f32
    scalar arrays (traced-safe: per-layer windows and decode positions).
    """
    out, _ = _flash_fwd_scan(q, k, v, win, qoff, kvlen, causal, logit_cap,
                             kv_block, p_bf16)
    B, Sq, H, hd = q.shape
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def _flash_fwd_rule(q, k, v, win, qoff, kvlen, causal, logit_cap, kv_block,
                    p_bf16=False):
    out, lse = _flash_fwd_scan(q, k, v, win, qoff, kvlen, causal, logit_cap,
                               kv_block, p_bf16)
    B, Sq, H, hd = q.shape
    o = out.reshape(B, Sq, H, hd).astype(q.dtype)
    return o, (q, k, v, out, lse, win, qoff, kvlen)


def _flash_bwd_rule(causal, logit_cap, kv_block, p_bf16, res, do):
    q, k, v, out, lse, win, qoff, kvlen = res
    B, Sq, H, hd = q.shape
    _, Skv, K, _ = k.shape
    G = H // K
    scale = hd ** -0.5
    qr = q.reshape(B, Sq, K, G, hd).astype(jnp.float32)
    dor = do.reshape(B, Sq, K, G, hd).astype(jnp.float32)
    delta = jnp.sum(dor * out, axis=-1)                 # (B,Sq,K,G)
    q_pos = qoff + jnp.arange(Sq, dtype=jnp.float32)
    kb, vb, nblk = _blocked_kv(k, v, kv_block)

    def body(carry, blk):
        dq_acc, j = carry
        kj, vj = blk                                    # (B,Bk,K,hd)
        kjf, vjf = kj.astype(jnp.float32), vj.astype(jnp.float32)
        s_raw = jnp.einsum("bskgh,btkh->bskgt", qr * scale, kjf)
        s = softcap(s_raw, logit_cap)
        k_pos = j * kv_block + jnp.arange(kv_block, dtype=jnp.float32)
        bias = _mask_bias(q_pos, k_pos, causal=causal, window=win,
                          kv_len=kvlen)
        p = jnp.exp(s + bias[None, :, None, None, :] - lse[..., None])
        if p_bf16:
            p = p.astype(jnp.bfloat16).astype(jnp.float32)
        dp = jnp.einsum("bskgh,btkh->bskgt", dor, vjf)
        ds = p * (dp - delta[..., None])
        if logit_cap:
            # d softcap(s_raw) = 1 - tanh^2(s_raw/cap)
            t = jnp.tanh(s_raw / logit_cap)
            ds = ds * (1.0 - t * t)
        dq_blk = jnp.einsum("bskgt,btkh->bskgh", ds, kjf) * scale
        dk_blk = jnp.einsum("bskgt,bskgh->btkh", ds, qr) * scale
        dv_blk = jnp.einsum("bskgt,bskgh->btkh", p, dor)
        return (dq_acc + dq_blk, j + 1.0), (dk_blk, dv_blk)

    dq0 = jnp.zeros((B, Sq, K, G, hd), jnp.float32)
    body = jax.checkpoint(body)
    with jax.named_scope("flash_attention_bwd"):
        (dq, _), (dkb, dvb) = jax.lax.scan(body, (dq0, jnp.float32(0)),
                                           (kb, vb))
    dq = dq.reshape(B, Sq, H, hd).astype(q.dtype)
    dk = dkb.transpose(1, 0, 2, 3, 4).reshape(B, nblk * kv_block, K, hd)
    dv = dvb.transpose(1, 0, 2, 3, 4).reshape(B, nblk * kv_block, K, hd)
    dk = dk[:, :Skv].astype(k.dtype)
    dv = dv[:, :Skv].astype(v.dtype)
    zero = jnp.zeros((), jnp.float32)
    return dq, dk, dv, zero, zero, zero


flash_attention_jnp.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def chunked_attention(
    q: jax.Array,          # (B, Sq, H, hd)
    k: jax.Array,          # (B, Skv, K, hd)
    v: jax.Array,          # (B, Skv, K, hd)
    *,
    causal: bool = True,
    window: int = 0,
    logit_cap: float = 0.0,
    q_offset=0,            # int or traced scalar: position of q[0]
    kv_len=None,           # valid prefix length of k/v (decode cache)
    kv_block: int = 512,
    p_bf16: bool = False,  # bf16 probability matrices (halves P traffic)
) -> jax.Array:
    """Flash-style attention (custom-vjp; see flash_attention_jnp)."""
    Skv = k.shape[1]
    win = jnp.asarray(0 if window is None else window, jnp.float32)
    qoff = jnp.asarray(q_offset, jnp.float32)
    kvlen = jnp.asarray(Skv if kv_len is None else kv_len, jnp.float32)
    with jax.named_scope("flash_attention"):
        return flash_attention_jnp(q, k, v, win, qoff, kvlen, causal,
                                   logit_cap, kv_block, p_bf16)


def decode_attention(q, k, v, *, pos, window=0, logit_cap=0.0) -> jax.Array:
    """Single-new-token attention with PER-ROW cache positions (serving).

    q: (B, 1, H, hd); k/v: (B, L, K, hd) full cache buffers; pos: (B,) int32
    — row b attends key indices <= pos[b] (and inside its local window when
    ``window`` > 0; ``window`` may be a python int or a traced per-layer
    scalar). Rows are fully independent: the mask never admits entries past
    a row's own position, so stale KV from freed slots or not-yet-written
    future positions cannot leak into any live sequence — the invariant the
    serve engine's slot isolation rests on (DESIGN.md §11).
    """
    B, Sq, H, hd = q.shape
    L, K = k.shape[1], k.shape[2]
    G = H // K
    qr = q.reshape(B, K, G, hd).astype(jnp.float32) * hd ** -0.5
    logits = jnp.einsum("bkgh,btkh->bkgt", qr, k.astype(jnp.float32))
    logits = softcap(logits, logit_cap)
    k_idx = jnp.arange(L, dtype=jnp.int32)
    ok = k_idx[None, :] <= pos[:, None]
    if isinstance(window, int):
        if window > 0:
            ok &= k_idx[None, :] > pos[:, None] - window
    elif window is not None:
        w = jnp.asarray(window, jnp.int32)
        ok &= (w <= 0) | (k_idx[None, :] > pos[:, None] - w)
    logits = logits + jnp.where(ok, 0.0, NEG_INF)[:, None, None, :]
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgt,btkh->bkgh", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def ring_decode_attention(q, k, v, *, q_pos, k_positions, window=0,
                          logit_cap=0.0) -> jax.Array:
    """Single-new-token attention over PER-ROW ring-buffer caches (the
    hybrid family's batched-serve decode tick).

    q: (B, 1, H, hd); k/v: (B, W, K, hd) ring buffers; q_pos: (B,) int32
    per-row query positions; k_positions: (B, W) int32 per-row slot
    positions (-1 = empty slot).  Row b attends slots with
    ``0 <= k_positions[b, t] <= q_pos[b]`` inside its local window —
    the per-row generalization of ``naive_attention``'s shared
    ``k_positions`` vector, keeping the ring's empty-slot guard
    (``pos >= 0``) so a freshly reset ring contributes nothing.  Rows
    are fully independent, the same slot-isolation invariant as
    ``decode_attention`` (DESIGN.md §11/§17).
    """
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qr = q.reshape(B, K, G, hd).astype(jnp.float32) * hd ** -0.5
    logits = jnp.einsum("bkgh,btkh->bkgt", qr, k.astype(jnp.float32))
    logits = softcap(logits, logit_cap)
    kp = jnp.asarray(k_positions, jnp.int32)
    qp = jnp.asarray(q_pos, jnp.int32)
    ok = (kp <= qp[:, None]) & (kp >= 0)
    if isinstance(window, int):
        if window > 0:
            ok &= kp > qp[:, None] - window
    elif window is not None:
        w = jnp.asarray(window, jnp.int32)
        ok &= (w <= 0) | (kp > qp[:, None] - w)
    logits = logits + jnp.where(ok, 0.0, NEG_INF)[:, None, None, :]
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgt,btkh->bkgh", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def paged_suffix_attention(q, k, v, *, q_pos, window=0,
                           logit_cap=0.0) -> jax.Array:
    """Suffix-prefill attention over a row-linearized paged cache.

    q (B,S,H,hd) suffix queries; k/v (B,L,K,hd) caches gathered through
    each row's page table that ALREADY hold the suffix rows at their
    positions; q_pos (B,S) global query positions — row-varying because
    each suffix starts at that row's shared-prefix length (DESIGN.md
    §15).  Generalizes ``decode_attention`` to S queries per row: query
    (b, s) attends ``k_idx <= q_pos[b, s]`` inside its window, which is
    both the causal mask within the suffix and the guard that hides
    TRASH-page rows past the row's own depth.
    """
    B, S, H, hd = q.shape
    L, K = k.shape[1], k.shape[2]
    G = H // K
    qr = q.reshape(B, S, K, G, hd).astype(jnp.float32) * hd ** -0.5
    logits = jnp.einsum("bskgh,btkh->bskgt", qr, k.astype(jnp.float32))
    logits = softcap(logits, logit_cap)
    k_idx = jnp.arange(L, dtype=jnp.int32)
    q_pos = jnp.asarray(q_pos, jnp.int32)
    ok = k_idx[None, None, :] <= q_pos[:, :, None]
    if isinstance(window, int):
        if window > 0:
            ok &= k_idx[None, None, :] > q_pos[:, :, None] - window
    elif window is not None:
        w = jnp.asarray(window, jnp.int32)
        ok &= (w <= 0) | (k_idx[None, None, :] > q_pos[:, :, None] - w)
    logits = logits + jnp.where(ok, 0.0, NEG_INF)[:, :, None, None, :]
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bskgt,btkh->bskgh", p, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


def naive_attention(q, k, v, *, causal=True, window=0, logit_cap=0.0,
                    q_offset=0, kv_len=None, k_positions=None) -> jax.Array:
    """Reference O(S^2)-memory attention (oracle, tiny smoke configs, and
    ring-buffer decode where key slots carry explicit positions)."""
    B, Sq, H, hd = q.shape
    _, Skv, K, _ = k.shape
    G = H // K
    qr = q.reshape(B, Sq, K, G, hd).astype(jnp.float32) * hd ** -0.5
    logits = jnp.einsum("bskgh,btkh->bskgt", qr, k.astype(jnp.float32))
    logits = softcap(logits, logit_cap)
    k_pos = k_positions if k_positions is not None else jnp.arange(Skv)
    bias = _mask_bias(q_offset + jnp.arange(Sq), k_pos,
                      causal=causal, window=window, kv_len=kv_len)
    logits = logits + bias[None, :, None, None, :]
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bskgt,btkh->bskgh", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def attention_block(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,                      # (B, S, D)
    *,
    positions: jax.Array,              # (B, S) or (S,)
    causal: bool = True,
    window: int = 0,
    cache: Optional[Dict[str, jax.Array]] = None,  # {"k","v"}: (B,Smax,K,hd)
    cache_pos=None,                    # decode: scalar write index
    kv_source: Optional[jax.Array] = None,  # cross-attention source (B,Skv,D)
    return_kv: bool = False,           # prefill: return computed k/v as cache
    impl: str = "chunked",
    page_table=None,                   # paged serve: (B, nb) int32
    kv_write_mask=None,                # paged suffix prefill: (B, S) bool
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """One attention op incl. projections, RoPE, cache handling."""
    B, S, D = x.shape
    src = kv_source if kv_source is not None else x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.rope_theta and kv_source is None:
        pos = positions if positions.ndim > 1 else positions[None, :]
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)

    new_cache = None
    kv_len = None
    q_offset = 0
    if cache is not None and cache_pos is not None \
            and jnp.ndim(cache_pos) >= 1:
        # batched-serve decode: row b writes its k/v at its OWN position
        # cache_pos[b] (never another row's — the seed engine's shared
        # scalar position broadcast every write across all slots) and
        # attends its own prefix via the per-row mask in decode_attention.
        cp = jnp.asarray(cache_pos, jnp.int32)
        if page_table is not None:
            # paged serve (DESIGN.md §15): KV lives in a shared physical
            # page pool (P, ps, K, hd) per layer; row b's logical page i
            # maps to page_table[b, i].  The LAST pool page is the
            # reserved TRASH target — masked/out-of-range writes land
            # there (always finite values, so masked softmax terms stay
            # exact zeros) and the per-row mask keeps it unreadable.
            pt = jnp.asarray(page_table, jnp.int32)
            Pn, ps = cache["k"].shape[0], cache["k"].shape[1]
            nbl = pt.shape[1]
            trash = Pn - 1
            if impl == "pallas_paged":
                if S != 1:
                    raise ValueError(
                        "attn_impl='pallas_paged' is the single-token "
                        "decode kernel; suffix prefill uses the jnp "
                        "gather path (attn_impl='paged')")
                win = jnp.asarray(0 if window is None else window,
                                  jnp.int32)
                o, ck, cv = kernel_ops.paged_decode_attention_fused(
                    q[:, 0], cache["k"], cache["v"],
                    k[:, 0].astype(cache["k"].dtype),
                    v[:, 0].astype(cache["v"].dtype),
                    pt, cp, win, logit_cap=cfg.attn_softcap)
                out = o[:, None]
            else:
                # jnp gather path (= the kernel's parity oracle): scatter
                # this step's S rows through the page table, gather each
                # row's pages into a linear (B, nb*ps) cache, attend with
                # per-row positions.  cp (B,) is each row's FIRST write
                # position (suffix start; decode is the S == 1 case).
                wp = cp[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
                valid = jnp.ones((B, S), bool) if kv_write_mask is None \
                    else jnp.asarray(kv_write_mask, bool)
                valid &= wp < nbl * ps
                rows = jnp.arange(B)[:, None]
                page = jnp.where(
                    valid,
                    pt[rows, jnp.clip(wp // ps, 0, nbl - 1)], trash)
                rowi = wp % ps
                ck = cache["k"].at[page, rowi].set(
                    k.astype(cache["k"].dtype))
                cv = cache["v"].at[page, rowi].set(
                    v.astype(cache["v"].dtype))
                lin_shape = (B, nbl * ps) + cache["k"].shape[2:]
                lin_k = ck[pt].reshape(lin_shape)
                lin_v = cv[pt].reshape(lin_shape)
                if S == 1:
                    out = decode_attention(q, lin_k, lin_v, pos=cp,
                                           window=window,
                                           logit_cap=cfg.attn_softcap)
                else:
                    out = paged_suffix_attention(
                        q, lin_k, lin_v, q_pos=wp, window=window,
                        logit_cap=cfg.attn_softcap)
            y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
            return y, {"k": ck, "v": cv}
        if impl == "pallas_decode":
            # Pallas hot path: the KV scatter happens INSIDE the kernel
            # launch (aliased cache blocks), replacing the separate
            # per-layer .at[rows, cp].set pass; the jnp path below is
            # the parity oracle (kernels run interpret=True on CPU).
            win = jnp.asarray(0 if window is None else window, jnp.int32)
            o, ck, cv = kernel_ops.decode_attention_fused(
                q[:, 0], cache["k"], cache["v"],
                k[:, 0].astype(cache["k"].dtype),
                v[:, 0].astype(cache["v"].dtype),
                cp, win, logit_cap=cfg.attn_softcap)
            out = o[:, None]
        else:
            rows = jnp.arange(B)
            ck = cache["k"].at[rows, cp].set(
                k[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[rows, cp].set(
                v[:, 0].astype(cache["v"].dtype))
            out = decode_attention(q, ck, cv, pos=cp, window=window,
                                   logit_cap=cfg.attn_softcap)
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
        return y, {"k": ck, "v": cv}
    if cache is not None and cache_pos is not None:
        # decode: write this step's k/v at cache_pos, attend over prefix
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(
            cache["k"].dtype), cache_pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(
            cache["v"].dtype), cache_pos, axis=1)
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv
        kv_len = cache_pos + 1
        q_offset = cache_pos
    elif return_kv:
        new_cache = {"k": k, "v": v}  # prefill: engine pads to max_len

    if impl == "pallas_decode":
        raise ValueError(
            "attn_impl='pallas_decode' is the batched-serve decode kernel "
            "(per-row cache_pos vectors); use 'chunked' or 'naive' for "
            "train/prefill/scalar-decode")
    if impl.startswith("chunked"):
        out = chunked_attention(
            q, k, v, causal=causal and kv_source is None, window=window,
            logit_cap=cfg.attn_softcap, q_offset=q_offset, kv_len=kv_len,
            p_bf16=impl.endswith("bf16"))
    else:
        out = naive_attention(
            q, k, v, causal=causal and kv_source is None, window=window,
            logit_cap=cfg.attn_softcap, q_offset=q_offset, kv_len=kv_len)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


def cache_defs(cfg: ModelConfig, batch: int, max_len: int,
               layers: int) -> ParamDefs:
    """KV cache ParamDefs (stacked over layers)."""
    K, hd = cfg.num_kv_heads, cfg.head_dim
    shape = (layers, batch, max_len, K, hd)
    axes = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    return {
        "k": ParamDef(shape, axes, init="zeros"),
        "v": ParamDef(shape, axes, init="zeros"),
    }


def paged_cache_defs(cfg: ModelConfig, num_pages: int, page_size: int,
                     layers: int) -> ParamDefs:
    """Paged KV pool ParamDefs (stacked over layers; DESIGN.md §15).

    One physical pool per layer, ``(num_pages, page_size, K, hd)``;
    slots address it through per-slot page tables held by the serve
    engine.  ``num_pages`` INCLUDES the reserved trailing TRASH page
    (index ``num_pages - 1``) that absorbs masked writes.
    """
    K, hd = cfg.num_kv_heads, cfg.head_dim
    shape = (layers, num_pages, page_size, K, hd)
    axes = ("layers", "kv_pages", "kv_page_rows", "kv_heads", "head_dim")
    return {
        "k": ParamDef(shape, axes, init="zeros"),
        "v": ParamDef(shape, axes, init="zeros"),
    }
