"""Mamba-2 SSD (state-space duality) block: chunked parallel scan.

Faithful to Dao & Gu (arXiv:2405.21060): per-head scalar A, data-dependent
dt (softplus), shared B/C projections (n_groups=1), depthwise short conv on
(x, B, C), gated output. The chunked algorithm splits the sequence into
chunks; intra-chunk terms are quadratic einsums, inter-chunk state is a
lax.scan (TPU-friendly: the Pallas kernel in ``repro.kernels.ssd_scan``
implements the same chunk computation with VMEM-carried state).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamDef, ParamDefs, Params


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    return d_inner, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state


def ssm_param_defs(cfg: ModelConfig) -> ParamDefs:
    D = cfg.d_model
    d_inner, H, P, N = ssm_dims(cfg)
    assert d_inner == H * P, (d_inner, H, P)
    d_xbc = d_inner + 2 * N
    return {
        "w_in_z": ParamDef((D, d_inner), ("ffn_in", "ssm_inner")),
        "w_in_xbc": ParamDef((D, d_xbc), ("ffn_in", "ssm_inner")),
        "w_in_dt": ParamDef((D, H), ("ffn_in", "ssm_heads")),
        "conv_w": ParamDef((cfg.ssm_conv_width, d_xbc), ("conv_w", "ssm_inner"),
                           scale=cfg.ssm_conv_width ** -0.5),
        "conv_b": ParamDef((d_xbc,), ("ssm_inner",), init="zeros"),
        "A_log": ParamDef((H,), ("ssm_heads",), init="const", const=0.0),
        "dt_bias": ParamDef((H,), ("ssm_heads",), init="zeros"),
        "D_skip": ParamDef((H,), ("ssm_heads",), init="ones"),
        "w_out": ParamDef((d_inner, D), ("ssm_inner", "ffn_in")),
        "norm_g": ParamDef((d_inner,), ("ssm_inner",), init="zeros"),
    }


def ssd_chunked(x, dt, A, B, C, chunk: int, initial_state=None):
    """Chunked SSD scan (single lax.scan over chunks, O(one chunk) temps).

    x: (b, S, H, P) values; dt: (b, S, H) positive; A: (H,) negative;
    B, C: (b, S, N). Returns (y (b,S,H,P), final_state (b,H,P,N)).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    nc = S // chunk
    assert nc * chunk == S, (S, chunk)

    dtA = dt * A  # (b,S,H)
    xr = x.reshape(b, nc, chunk, H, P).transpose(1, 0, 2, 3, 4)
    dtr = dt.reshape(b, nc, chunk, H).transpose(1, 0, 2, 3)
    ar = dtA.reshape(b, nc, chunk, H).transpose(1, 0, 2, 3)
    Br = B.reshape(b, nc, chunk, N).transpose(1, 0, 2, 3)
    Cr = C.reshape(b, nc, chunk, N).transpose(1, 0, 2, 3)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    s0 = (initial_state if initial_state is not None
          else jnp.zeros((b, H, P, N), jnp.float32))

    def body(s, inp):
        # Einsums are pre-factored into 2-operand contractions so XLA never
        # materializes a (b,Q,Q,H,P) intermediate — the unfactored 4-operand
        # forms cost 97% of the step's HBM traffic (measured 143 TB/device
        # on mamba2 train_4k; see EXPERIMENTS.md §Perf iteration M1).
        xc, dtc, ac, Bc, Cc = inp          # (b,Q,H,P) (b,Q,H) (b,Q,H) (b,Q,N)
        xf = xc.astype(jnp.float32)
        dtf = dtc.astype(jnp.float32)
        Bf, Cf = Bc.astype(jnp.float32), Cc.astype(jnp.float32)
        cum = jnp.cumsum(ac.astype(jnp.float32), axis=1)      # (b,Q,H)
        # intra-chunk: y_i += sum_{j<=i} (C_i.B_j) exp(cum_i-cum_j) dt_j x_j
        li = cum[:, :, None, :] - cum[:, None, :, :]          # (b,Q,Q,H)
        Ldecay = jnp.where(tri[None, :, :, None], jnp.exp(li), 0.0)
        cb = jnp.einsum("bin,bjn->bij", Cf, Bf)               # (b,Q,Q)
        w = cb[..., None] * Ldecay * dtf[:, None, :, :]       # (b,Q,Q,H)
        y_diag = jnp.einsum("bijh,bjhp->bihp", w, xf)
        # inter-chunk: y_i += exp(cum_i) * (C_i . s_prev)
        y_off = jnp.einsum("bin,bhpn->bihp", Cf, s) \
            * jnp.exp(cum)[..., None]
        # state update: s = exp(cum_Q)*s + sum_j exp(cum_Q-cum_j) dt_j B_j x_j
        dstates = jnp.exp(cum[:, -1:, :] - cum) * dtf         # (b,Q,H)
        xw = xf * dstates[..., None]                          # (b,Q,H,P)
        s_inc = jnp.einsum("bjn,bjhp->bhpn", Bf, xw)
        s_new = s * jnp.exp(cum[:, -1, :])[..., None, None] + s_inc
        return s_new, (y_diag + y_off).astype(x.dtype)

    # checkpoint the chunk body: backward recomputes the (Q,Q,H) intra-chunk
    # tensors per chunk instead of storing them for every chunk (measured
    # 323 GiB/dev on mamba2 train_4k without this)
    body = jax.checkpoint(body)
    with jax.named_scope("ssd_scan"):
        s_final, ys = jax.lax.scan(body, s0.astype(jnp.float32),
                                   (xr, dtr, ar, Br, Cr))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, S, H, P)
    return y, s_final  # state stays f32 across steps


def _gated_rmsnorm(x, z, g, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    x = x * jax.lax.rsqrt(jnp.mean(x * x, -1, keepdims=True) + eps)
    return (x * (1 + g.astype(jnp.float32))).astype(dt)


def ssm_block(
    cfg: ModelConfig,
    p: Params,
    u: jax.Array,                           # (B, S, D)
    *,
    state: Optional[Dict[str, jax.Array]] = None,  # decode: conv+ssm state
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Full mamba2 mixer. In decode mode (S==1) uses the recurrent path."""
    B, S, D = u.shape
    d_inner, H, P, N = ssm_dims(cfg)
    W = cfg.ssm_conv_width

    z = u @ p["w_in_z"]                     # (B,S,d_inner)
    xbc = u @ p["w_in_xbc"]                 # (B,S,d_inner+2N)
    dt_raw = u @ p["w_in_dt"]               # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))

    if state is not None and S == 1:
        # ---- decode: O(1) recurrent update -----------------------------
        window = jnp.concatenate([state["conv"], xbc], axis=1)         # (B,W,d_xbc)
        xbc_t = jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
        xbc_t = jax.nn.silu(xbc_t)[:, None]                            # (B,1,d_xbc)
        x, Bm, Cm = jnp.split(xbc_t, [d_inner, d_inner + N], axis=-1)
        xh = x.reshape(B, H, P)
        dt1 = dt[:, 0]                                                 # (B,H)
        decay = jnp.exp(dt1 * A)                                       # (B,H)
        s = state["ssm"].astype(jnp.float32)                           # (B,H,P,N)
        s = s * decay[..., None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dt1, xh.astype(jnp.float32),
            Bm[:, 0].astype(jnp.float32))
        y = jnp.einsum("bhpn,bn->bhp", s, Cm[:, 0].astype(jnp.float32))
        y = y + p["D_skip"].astype(jnp.float32)[None, :, None] * xh
        y = y.reshape(B, 1, d_inner).astype(u.dtype)
        y = _gated_rmsnorm(y, z, p["norm_g"])
        out = y @ p["w_out"]
        new_state = {"conv": window[:, 1:] if W > 1 else window[:, :0],
                     "ssm": s}  # f32 state
        return out, new_state

    # ---- train / prefill: depthwise causal conv + chunked SSD ----------
    # shifted-slice sum instead of an (B,S,W,d) window gather (W x memory)
    pad = jnp.zeros((B, W - 1, xbc.shape[-1]), xbc.dtype)
    xbc_pad = jnp.concatenate([pad, xbc], axis=1)
    conv_acc = sum(xbc_pad[:, w:w + S] * p["conv_w"][w]
                   for w in range(W))
    xbc_c = jax.nn.silu(conv_acc + p["conv_b"])
    x, Bm, Cm = jnp.split(xbc_c, [d_inner, d_inner + N], axis=-1)
    xh = x.reshape(B, S, H, P)

    init = state["ssm"] if state is not None else None
    y, s_final = ssd_chunked(xh, dt.astype(xh.dtype), A.astype(xh.dtype),
                             Bm, Cm, min(cfg.ssm_chunk, S), initial_state=init)
    y = y + p["D_skip"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(B, S, d_inner)
    y = _gated_rmsnorm(y, z, p["norm_g"])
    out = y @ p["w_out"]

    new_state = None
    if state is not None or S > 1:
        conv_tail = xbc_pad[:, -(W - 1):] if W > 1 else xbc_pad[:, :0]
        new_state = {"conv": conv_tail, "ssm": s_final}
    return out, new_state


def ssm_state_defs(cfg: ModelConfig, batch: int, layers: int) -> ParamDefs:
    d_inner, H, P, N = ssm_dims(cfg)
    d_xbc = d_inner + 2 * N
    W = cfg.ssm_conv_width
    return {
        "conv": ParamDef((layers, batch, W - 1, d_xbc),
                         ("layers", "batch", "conv_w", "ssm_inner"),
                         init="zeros"),
        "ssm": ParamDef((layers, batch, H, P, N),
                        ("layers", "batch", "ssm_heads", "ssm_head_dim",
                         "ssm_state"), init="zeros", dtype="float32"),
    }
