"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    a_t = exp(c * softplus(Lambda) * (-r_t))   with c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Wrapped in the Griffin recurrent block: linear_in -> [gate branch (GeLU)] x
[conv1d(4) -> RG-LRU branch] -> linear_out. The sequence path runs a
lax.scan over time blocks; the Pallas kernel (repro.kernels.rglru_scan)
implements the same recurrence with VMEM-carried state for TPU.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamDef, ParamDefs, Params

_C = 8.0


def rglru_param_defs(cfg: ModelConfig) -> ParamDefs:
    D, R = cfg.d_model, cfg.lru_width
    W = 4  # temporal conv width (fixed in the paper)
    return {
        "w_in_x": ParamDef((D, R), ("ffn_in", "lru")),
        "w_in_gate": ParamDef((D, R), ("ffn_in", "lru")),
        "conv_w": ParamDef((W, R), ("conv_w", "lru"), scale=W ** -0.5),
        "conv_b": ParamDef((R,), ("lru",), init="zeros"),
        "w_a": ParamDef((R, R), ("lru", "ffn_in"), scale=R ** -0.5),
        "b_a": ParamDef((R,), ("lru",), init="zeros"),
        "w_i": ParamDef((R, R), ("lru", "ffn_in"), scale=R ** -0.5),
        "b_i": ParamDef((R,), ("lru",), init="zeros"),
        "lam": ParamDef((R,), ("lru",), init="const", const=1.0),
        "w_out": ParamDef((R, D), ("lru", "ffn_in")),
    }


def rglru_scan(x: jax.Array, r: jax.Array, i: jax.Array, lam: jax.Array,
               h0: Optional[jax.Array] = None, block: int = 256
               ) -> Tuple[jax.Array, jax.Array]:
    """x, r, i: (B, S, R); lam: (R,). Returns (y (B,S,R), h_final (B,R)).

    Blocked: an outer lax.scan over S/block time blocks carries the hidden
    state; within each (checkpointed) block the linear recurrence
    h_t = a_t h_{t-1} + b_t is computed by an associative scan (log-depth,
    TPU-friendly). A flat per-step scan at S=4k stores per-step residuals
    for backward (measured 87 GiB/dev on recurrentgemma train_4k) and
    compiles ~6x slower.
    """
    B, S, R = x.shape
    log_a = -_C * jax.nn.softplus(lam.astype(jnp.float32)) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed stably via expm1
    beta = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    gated = beta * i.astype(jnp.float32) * x.astype(jnp.float32)
    h_init = (h0.astype(jnp.float32) if h0 is not None
              else jnp.zeros((B, R), jnp.float32))

    def assoc(e1, e2):  # compose two recurrence elements (time order)
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    if S % block or S <= block:
        with jax.named_scope("rglru_scan"):
            aa, hh = jax.lax.associative_scan((assoc), (a, gated), axis=1)
            hh = hh + aa * h_init[:, None, :]
        return hh.astype(x.dtype), hh[:, -1]

    nb = S // block
    ab = a.reshape(B, nb, block, R).transpose(1, 0, 2, 3)
    gb = gated.reshape(B, nb, block, R).transpose(1, 0, 2, 3)

    @jax.checkpoint
    def body(h, inp):
        a_blk, g_blk = inp                       # (B, Q, R)
        aa, hh = jax.lax.associative_scan(assoc, (a_blk, g_blk), axis=1)
        hh = hh + aa * h[:, None, :]             # fold carried state
        return hh[:, -1], hh

    with jax.named_scope("rglru_scan"):
        h_final, ys = jax.lax.scan(body, h_init, (ab, gb))
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, R)
    return y.astype(x.dtype), h_final


def rglru_block(
    cfg: ModelConfig,
    p: Params,
    u: jax.Array,                                # (B, S, D)
    *,
    state: Optional[Dict[str, jax.Array]] = None,  # {"h": (B,R), "conv": (B,W-1,R)}
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    B, S, D = u.shape
    R = cfg.lru_width
    W = p["conv_w"].shape[0]

    gate = jax.nn.gelu(u @ p["w_in_gate"])       # (B,S,R)
    x = u @ p["w_in_x"]                          # (B,S,R)

    # depthwise causal conv
    if state is not None and S == 1:
        window = jnp.concatenate([state["conv"], x], axis=1)   # (B,W,R)
        xc = jnp.einsum("bwr,wr->br", window, p["conv_w"]) + p["conv_b"]
        xc = xc[:, None]
        conv_tail = window[:, 1:]
    else:
        padx = jnp.concatenate(
            [state["conv"] if state is not None
             else jnp.zeros((B, W - 1, R), x.dtype), x], axis=1)
        # shifted-slice sum (avoids the (B,S,W,R) window gather)
        xc = sum(padx[:, w:w + S] * p["conv_w"][w] for w in range(W))
        xc = xc + p["conv_b"]
        conv_tail = padx[:, -(W - 1):]

    r = jax.nn.sigmoid(xc @ p["w_a"] + p["b_a"])
    i = jax.nn.sigmoid(xc @ p["w_i"] + p["b_i"])
    h0 = state["h"] if state is not None else None
    y, h_final = rglru_scan(xc, r, i, p["lam"], h0)

    out = (y * gate) @ p["w_out"]
    new_state = None
    if state is not None or S > 1:
        new_state = {"h": h_final, "conv": conv_tail}
    return out, new_state


def rglru_state_defs(cfg: ModelConfig, batch: int, n_rec: int) -> ParamDefs:
    R, W = cfg.lru_width, 4
    return {
        "h": ParamDef((n_rec, batch, R), ("stack", "batch", "lru"),
                      init="zeros", dtype="float32"),
        "conv": ParamDef((n_rec, batch, W - 1, R),
                         ("stack", "batch", "conv_w", "lru"), init="zeros"),
    }
