"""Parameter system + shared neural-net primitives.

Parameters live in a FLAT dict keyed by '/'-separated path; a parallel dict
maps each path to its logical-axes tuple (consumed by ``repro.sharding``).
Layer stacks that are scanned carry a leading "layers" dim.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, jax.Array]
Axes = Dict[str, Tuple[Optional[str], ...]]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"      # normal | zeros | ones | const
    scale: Optional[float] = None  # None -> 1/sqrt(fan_in) for normal
    const: float = 0.0
    dtype: Optional[str] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


ParamDefs = Dict[str, ParamDef]


def _fan_in(shape: Tuple[int, ...]) -> int:
    # heuristically treat all but the last dim as fan-in for >=2D weights
    if len(shape) <= 1:
        return shape[0] if shape else 1
    return int(np.prod(shape[:-1]))


def materialize(defs: ParamDefs, key: jax.Array, dtype: str) -> Params:
    params: Params = {}
    keys = jax.random.split(key, max(len(defs), 1))
    for (name, d), k in zip(sorted(defs.items()), keys):
        dt = jnp.dtype(d.dtype or dtype)
        if d.init == "zeros":
            params[name] = jnp.zeros(d.shape, dt)
        elif d.init == "ones":
            params[name] = jnp.ones(d.shape, dt)
        elif d.init == "const":
            params[name] = jnp.full(d.shape, d.const, dt)
        else:
            scale = d.scale if d.scale is not None else _fan_in(d.shape) ** -0.5
            params[name] = (jax.random.normal(k, d.shape, jnp.float32)
                            * scale).astype(dt)
    return params


def abstract(defs: ParamDefs, dtype: str) -> Params:
    return {
        name: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype or dtype))
        for name, d in defs.items()
    }


def axes_of(defs: ParamDefs) -> Axes:
    return {name: d.axes for name, d in defs.items()}


def stacked(defs: ParamDefs, n: int, prefix: str) -> ParamDefs:
    """Stack per-layer defs with a leading scanned "layers" dim."""
    return {
        f"{prefix}/{k}": dataclasses.replace(
            d, shape=(n,) + d.shape, axes=("layers",) + d.axes)
        for k, d in defs.items()
    }


def subtree(params: Params, prefix: str) -> Params:
    pre = prefix + "/"
    return {k[len(pre):]: v for k, v in params.items() if k.startswith(pre)}


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1).astype(dt)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


def sinusoidal_positions(length: int, dim: int) -> jax.Array:
    pos = np.arange(length)[:, None]
    div = np.exp(-np.log(10000.0) * np.arange(0, dim, 2) / dim)
    out = np.zeros((length, dim), np.float32)
    out[:, 0::2] = np.sin(pos * div)
    out[:, 1::2] = np.cos(pos * div)
    return jnp.asarray(out)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  final_cap: float = 0.0) -> jax.Array:
    logits = softcap(logits.astype(jnp.float32), final_cap)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)
