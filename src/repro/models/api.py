"""Public model API: build_model(cfg) -> Model with init/loss/prefill/decode,
plus ``input_specs(cfg, shape)`` producing ShapeDtypeStruct stand-ins for
every (architecture x input-shape) dry-run cell.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tf
from repro.models.common import (Axes, ParamDefs, Params, abstract, axes_of,
                                 cross_entropy, materialize)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    max_seq: int
    param_defs: ParamDefs

    # ---- params ---------------------------------------------------------
    def init(self, key: jax.Array) -> Params:
        return materialize(self.param_defs, key, self.cfg.dtype)

    def abstract_params(self) -> Params:
        return abstract(self.param_defs, self.cfg.dtype)

    def param_axes(self) -> Axes:
        return axes_of(self.param_defs)

    # ---- cache ----------------------------------------------------------
    def cache_defs(self, batch: int, max_len: int) -> ParamDefs:
        return tf.cache_param_defs(self.cfg, batch, max_len)

    def init_cache(self, batch: int, max_len: int) -> Params:
        return materialize(self.cache_defs(batch, max_len),
                           jax.random.PRNGKey(0), self.cfg.dtype)

    def abstract_cache(self, batch: int, max_len: int) -> Params:
        return abstract(self.cache_defs(batch, max_len), self.cfg.dtype)

    def cache_axes(self, batch: int, max_len: int) -> Axes:
        return axes_of(self.cache_defs(batch, max_len))

    # ---- paged cache (serve; DESIGN.md §15) -----------------------------
    def paged_cache_defs(self, num_pages: int, page_size: int) -> ParamDefs:
        """Per-layer physical KV page pools; ``num_pages`` includes the
        reserved trailing TRASH page."""
        return tf.paged_cache_param_defs(self.cfg, num_pages, page_size)

    def init_paged_cache(self, num_pages: int, page_size: int) -> Params:
        return materialize(self.paged_cache_defs(num_pages, page_size),
                           jax.random.PRNGKey(0), self.cfg.dtype)

    def paged_cache_axes(self, num_pages: int, page_size: int) -> Axes:
        return axes_of(self.paged_cache_defs(num_pages, page_size))

    # ---- forward --------------------------------------------------------
    def forward(self, params: Params, batch: Dict[str, jax.Array], *,
                mode: str = "train", cache: Optional[Params] = None,
                cache_pos=None, attn_impl: str = "chunked",
                page_table=None, kv_write_mask=None):
        cfg = self.cfg
        if cfg.family == "encdec":
            if page_table is not None:
                raise ValueError("paged KV serving requires a dense/moe/vlm "
                                 "decoder (encdec has ring-buffer caches)")
            return tf.encdec_forward(
                cfg, params, batch["tokens"], frames=batch.get("frames"),
                enc_out=batch.get("enc_out"), mode=mode, cache=cache,
                cache_pos=cache_pos, attn_impl=attn_impl)
        if cfg.family == "hybrid":
            if page_table is not None:
                raise ValueError("paged KV serving requires a dense/moe/vlm "
                                 "decoder (hybrid has recurrent state)")
            return tf.hybrid_forward(
                cfg, params, batch["tokens"], mode=mode, cache=cache,
                cache_pos=cache_pos, attn_impl=attn_impl)
        return tf.decoder_forward(
            cfg, params, batch["tokens"], mode=mode, cache=cache,
            cache_pos=cache_pos, vision_embeds=batch.get("vision_embeds"),
            attn_impl=attn_impl, page_table=page_table,
            kv_write_mask=kv_write_mask)

    def loss(self, params: Params, batch: Dict[str, jax.Array], *,
             attn_impl: str = "chunked") -> jax.Array:
        logits, _, aux = self.forward(params, batch, mode="train",
                                      attn_impl=attn_impl)
        return cross_entropy(logits, batch["labels"],
                             self.cfg.final_softcap) + aux

    def prefill(self, params: Params, batch: Dict[str, jax.Array], *,
                attn_impl: str = "chunked"):
        logits, cache, _ = self.forward(params, batch, mode="prefill",
                                        attn_impl=attn_impl)
        return logits, cache

    def decode_step(self, params: Params, cache: Params, batch:
                    Dict[str, jax.Array], pos, *, attn_impl: str = "chunked",
                    page_table=None, kv_write_mask=None):
        """One decode step. ``pos`` is a scalar write position for the whole
        batch, or — for ``supports_batched_serve`` families — a (B,) int32
        vector of per-row positions (continuous batching: every serve slot
        decodes at its own depth in one fused step).

        With ``page_table`` (B, nb) the cache is the paged pool and
        ``pos`` each row's first write position; tokens (B, S) with
        S > 1 is the paged suffix prefill (writes masked by
        ``kv_write_mask``; see DESIGN.md §15)."""
        logits, new_cache, _ = self.forward(
            params, batch, mode="decode", cache=cache, cache_pos=pos,
            attn_impl=attn_impl, page_table=page_table,
            kv_write_mask=kv_write_mask)
        return logits, new_cache

    @property
    def supports_batched_serve(self) -> bool:
        """Families with the standard stacked-KV cache layout
        (layers, batch, max_len, kv_heads, head_dim): their decode path
        accepts per-row position vectors and their prefill caches scatter
        directly into serve-engine slots. ssm keeps positionless recurrent
        state, so batched slots cannot be isolated (a step advances every
        row's state); hybrid/encdec need per-row ring slots /
        learned-position slices they don't have yet."""
        return self.cfg.family in ("dense", "moe", "vlm")


def build_model(cfg: ModelConfig, max_seq: int = 4096) -> Model:
    return Model(cfg=cfg, max_seq=max_seq,
                 param_defs=tf.model_param_defs(cfg, max_seq))


# ---------------------------------------------------------------------------
# input specs (dry-run stand-ins; no device allocation)
# ---------------------------------------------------------------------------

# encoder frame count used for decode-mode whisper cells (encoder runs once
# at prefill; decode attends to its output)
WHISPER_DECODE_ENC_LEN = 1536


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for one (arch x shape) cell.

    train   -> {tokens, labels [, frames | vision_embeds]}
    prefill -> {tokens [, frames | vision_embeds]}
    decode  -> {tokens (B,1) [, enc_out]}  (the KV cache spec comes from
               Model.abstract_cache(batch, seq_len))
    """
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        specs = {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}
        if cfg.family == "encdec":
            specs["frames"] = sds((B, S, cfg.d_model), dt)
        if cfg.family == "vlm":
            specs["vision_embeds"] = sds((B, cfg.vision_tokens,
                                          cfg.d_model), dt)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": sds((B, S), i32)}
        if cfg.family == "encdec":
            specs["frames"] = sds((B, S, cfg.d_model), dt)
        if cfg.family == "vlm":
            specs["vision_embeds"] = sds((B, cfg.vision_tokens,
                                          cfg.d_model), dt)
        return specs
    # decode: one new token against a cache of length S
    specs = {"tokens": sds((B, 1), i32)}
    if cfg.family == "encdec":
        specs["enc_out"] = sds((B, WHISPER_DECODE_ENC_LEN, cfg.d_model), dt)
    return specs


def make_inputs(cfg: ModelConfig, shape: ShapeConfig,
                key: Optional[jax.Array] = None) -> Dict[str, jax.Array]:
    """Concrete random inputs matching input_specs (smoke tests/examples)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    out = {}
    for name, spec in input_specs(cfg, shape).items():
        key, sub = jax.random.split(key)
        if spec.dtype == jnp.int32:
            out[name] = jax.random.randint(sub, spec.shape, 0,
                                           cfg.vocab_size, jnp.int32)
        else:
            out[name] = jax.random.normal(sub, spec.shape, jnp.float32
                                          ).astype(spec.dtype)
    return out
