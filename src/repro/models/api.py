"""Public model API: build_model(cfg) -> Model with init/loss/prefill/decode,
plus ``input_specs(cfg, shape)`` producing ShapeDtypeStruct stand-ins for
every (architecture x input-shape) dry-run cell.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tf
from repro.models.common import (Axes, ParamDefs, Params, abstract, axes_of,
                                 cross_entropy, materialize)


@dataclasses.dataclass(frozen=True)
class StateBank:
    """One named per-slot state bank — the serve stack's cache contract.

    The serve engines treat a model's decode cache as a *pytree of
    banks*: the flat dict returned by ``Model.cache_defs`` / carried
    through ``decode_step``, with one ``StateBank`` describing each
    array.  The canonical bank contract is:

      * Every bank has a slot axis at ``batch_axis``; row ``b`` belongs
        exclusively to serve slot ``b``.  A decode step only reads and
        writes its own row — rows are computationally independent, so a
        row-masked merge/reset leaves every other slot's state bitwise
        unchanged (the invariant behind continuous batching, preemption,
        quarantine, and the hypothesis isolation tests).
      * ``kind`` fixes the lifecycle the engine applies to the bank:

        - ``"kv"``: positioned KV rows with a sequence axis at
          ``seq_axis``.  Prefill scatters positions ``[0, len)`` along
          that axis; decode writes at the row's own position and reads
          are position-guarded (``decode_attention``), so stale entries
          from a freed slot are unreadable and no reset is needed.
        - ``"recurrent"``: positionless recurrent state (SSD conv/state,
          RG-LRU hidden state).  Every decode step rewrites the whole
          row, so the engine must merge decode results under the active
          mask (frozen rows stay bitwise frozen), prefill is a masked
          per-token scan, and slot admit/free re-initializes the row.
        - ``"ring"``: ring-buffer KV whose slot-position entries (or the
          ``pos`` bank guarding them) wrap modulo the window.  Treated
          like ``"recurrent"`` — a new occupant could otherwise read a
          stale in-window entry — plus reads honor the ``pos >= 0``
          empty-slot guard.
        - ``"enc"``: encoder output written once per row at admission
          and passed through decode unchanged (whisper cross-attention
          source).  Reset by full-row overwrite at the next admit.

      * All banks with a ``seq_axis`` satisfy ``batch_axis < seq_axis``
        (the engines' generic masked scatter relies on it).
    """

    name: str
    kind: str            # "kv" | "recurrent" | "ring" | "enc"
    batch_axis: int
    seq_axis: Optional[int] = None

    def __post_init__(self):
        if self.kind not in ("kv", "recurrent", "ring", "enc"):
            raise ValueError(f"unknown bank kind {self.kind!r}")
        if self.seq_axis is not None and self.batch_axis >= self.seq_axis:
            raise ValueError(
                f"bank {self.name!r}: batch_axis {self.batch_axis} must "
                f"precede seq_axis {self.seq_axis}")


# Which serve engines can host each family (satellite of DESIGN.md §17):
# "dense" = Engine/EngineReference slot caches, "paged" = PagedEngine page
# pools.  Paged stays KV-decoder-only by design — pages hold positioned KV
# rows, which recurrent/ring/encoder banks do not have.
_FAMILY_SERVE_MODES: Dict[str, frozenset] = {
    "dense": frozenset({"dense", "paged"}),
    "moe": frozenset({"dense", "paged"}),
    "vlm": frozenset({"dense", "paged"}),
    "ssm": frozenset({"dense"}),
    "hybrid": frozenset({"dense"}),
    "encdec": frozenset({"dense"}),
}


def serve_families(mode: str) -> Tuple[str, ...]:
    """Families servable under engine ``mode`` ("dense" | "paged")."""
    return tuple(sorted(f for f, m in _FAMILY_SERVE_MODES.items()
                        if mode in m))


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    max_seq: int
    param_defs: ParamDefs

    # ---- params ---------------------------------------------------------
    def init(self, key: jax.Array) -> Params:
        return materialize(self.param_defs, key, self.cfg.dtype)

    def abstract_params(self) -> Params:
        return abstract(self.param_defs, self.cfg.dtype)

    def param_axes(self) -> Axes:
        return axes_of(self.param_defs)

    # ---- cache ----------------------------------------------------------
    def cache_defs(self, batch: int, max_len: int) -> ParamDefs:
        return tf.cache_param_defs(self.cfg, batch, max_len)

    def init_cache(self, batch: int, max_len: int) -> Params:
        return materialize(self.cache_defs(batch, max_len),
                           jax.random.PRNGKey(0), self.cfg.dtype)

    def abstract_cache(self, batch: int, max_len: int) -> Params:
        return abstract(self.cache_defs(batch, max_len), self.cfg.dtype)

    def cache_axes(self, batch: int, max_len: int) -> Axes:
        return axes_of(self.cache_defs(batch, max_len))

    # ---- paged cache (serve; DESIGN.md §15) -----------------------------
    def paged_cache_defs(self, num_pages: int, page_size: int) -> ParamDefs:
        """Per-layer physical KV page pools; ``num_pages`` includes the
        reserved trailing TRASH page."""
        return tf.paged_cache_param_defs(self.cfg, num_pages, page_size)

    def init_paged_cache(self, num_pages: int, page_size: int) -> Params:
        return materialize(self.paged_cache_defs(num_pages, page_size),
                           jax.random.PRNGKey(0), self.cfg.dtype)

    def paged_cache_axes(self, num_pages: int, page_size: int) -> Axes:
        return axes_of(self.paged_cache_defs(num_pages, page_size))

    # ---- forward --------------------------------------------------------
    def forward(self, params: Params, batch: Dict[str, jax.Array], *,
                mode: str = "train", cache: Optional[Params] = None,
                cache_pos=None, attn_impl: str = "chunked",
                page_table=None, kv_write_mask=None):
        cfg = self.cfg
        if cfg.family == "encdec":
            if page_table is not None:
                raise ValueError("paged KV serving requires a dense/moe/vlm "
                                 "decoder (encdec has ring-buffer caches)")
            return tf.encdec_forward(
                cfg, params, batch["tokens"], frames=batch.get("frames"),
                enc_out=batch.get("enc_out"), mode=mode, cache=cache,
                cache_pos=cache_pos, attn_impl=attn_impl)
        if cfg.family == "hybrid":
            if page_table is not None:
                raise ValueError("paged KV serving requires a dense/moe/vlm "
                                 "decoder (hybrid has recurrent state)")
            return tf.hybrid_forward(
                cfg, params, batch["tokens"], mode=mode, cache=cache,
                cache_pos=cache_pos, attn_impl=attn_impl)
        return tf.decoder_forward(
            cfg, params, batch["tokens"], mode=mode, cache=cache,
            cache_pos=cache_pos, vision_embeds=batch.get("vision_embeds"),
            attn_impl=attn_impl, page_table=page_table,
            kv_write_mask=kv_write_mask)

    def loss(self, params: Params, batch: Dict[str, jax.Array], *,
             attn_impl: str = "chunked") -> jax.Array:
        logits, _, aux = self.forward(params, batch, mode="train",
                                      attn_impl=attn_impl)
        return cross_entropy(logits, batch["labels"],
                             self.cfg.final_softcap) + aux

    def prefill(self, params: Params, batch: Dict[str, jax.Array], *,
                attn_impl: str = "chunked"):
        logits, cache, _ = self.forward(params, batch, mode="prefill",
                                        attn_impl=attn_impl)
        return logits, cache

    def decode_step(self, params: Params, cache: Params, batch:
                    Dict[str, jax.Array], pos, *, attn_impl: str = "chunked",
                    page_table=None, kv_write_mask=None):
        """One decode step. ``pos`` is a scalar write position for the whole
        batch, or a (B,) int32 vector of per-row positions (continuous
        batching: every serve slot decodes at its own depth — or, for
        recurrent banks, its own step count — in one fused step).  All
        families accept the vector form; see ``StateBank``.

        With ``page_table`` (B, nb) the cache is the paged pool and
        ``pos`` each row's first write position; tokens (B, S) with
        S > 1 is the paged suffix prefill (writes masked by
        ``kv_write_mask``; see DESIGN.md §15)."""
        logits, new_cache, _ = self.forward(
            params, batch, mode="decode", cache=cache, cache_pos=pos,
            attn_impl=attn_impl, page_table=page_table,
            kv_write_mask=kv_write_mask)
        return logits, new_cache

    # ---- serve capability metadata (DESIGN.md §17) ----------------------
    @property
    def serve_modes(self) -> frozenset:
        """Per-engine serve capability: ``"dense"`` = the slot-cache
        engines (Engine / EngineReference), ``"paged"`` = PagedEngine.
        Every family serves batched through its state banks; only the
        stacked-KV decoder families additionally page."""
        return _FAMILY_SERVE_MODES[self.cfg.family]

    @property
    def supports_batched_serve(self) -> bool:
        """True when the slot-cache serve engines accept this model
        (derived from ``serve_modes``; kept for callers of the old
        single-bool API)."""
        return "dense" in self.serve_modes

    def state_banks(self) -> Dict[str, "StateBank"]:
        """The model's slot-state banks, keyed exactly like
        ``cache_defs``/``decode_step`` caches (contract: StateBank)."""
        cfg = self.cfg
        if cfg.family == "ssm":
            return {n: StateBank(n, "recurrent", batch_axis=1)
                    for n in ("conv", "ssm")}
        if cfg.family == "hybrid":
            banks = {n: StateBank(n, "recurrent", batch_axis=1)
                     for n in ("rec/h", "rec/conv")}
            for n in ("attn/k", "attn/v", "attn/pos"):
                banks[n] = StateBank(n, "ring", batch_axis=1, seq_axis=2)
            return banks
        if cfg.family == "encdec":
            banks = {}
            for i in range(cfg.dec_layers):
                for n in (f"dec_{i}/k", f"dec_{i}/v"):
                    banks[n] = StateBank(n, "kv", batch_axis=0, seq_axis=1)
            banks["enc/out"] = StateBank("enc/out", "enc", batch_axis=0)
            return banks
        return {n: StateBank(n, "kv", batch_axis=1, seq_axis=2)
                for n in ("k", "v")}

    def encode_prompt(self, params: Params, tokens: jax.Array,
                      lens: jax.Array) -> jax.Array:
        """Encoder forward over stub frames built from prompt tokens
        (whisper's conv frontend is a stub, so frames = token embeddings
        masked by ``arange(Se) < lens``).

        tokens (B, Se) int32 right-padded prompts, lens (B,) int32 valid
        lengths.  Returns (B, Se, d_model) encoder output for the
        ``enc/out`` bank.  The encoder is bidirectional with NO padding
        mask, so the output depends on the padded length Se: serve
        callers MUST pad to one fixed Se (the engines use max_len) so
        every engine compiles the identical program and per-row encoder
        outputs stay bitwise comparable across them.
        """
        if self.cfg.family != "encdec":
            raise ValueError(
                f"encode_prompt is encdec-only (family {self.cfg.family!r})")
        emb = params["emb/tok"][tokens].astype(jnp.dtype(self.cfg.dtype))
        m = jnp.arange(tokens.shape[1])[None, :] < lens[:, None]
        frames = emb * m[:, :, None].astype(emb.dtype)
        return tf.encoder_forward(self.cfg, params, frames)


def build_model(cfg: ModelConfig, max_seq: int = 4096) -> Model:
    return Model(cfg=cfg, max_seq=max_seq,
                 param_defs=tf.model_param_defs(cfg, max_seq))


# ---------------------------------------------------------------------------
# input specs (dry-run stand-ins; no device allocation)
# ---------------------------------------------------------------------------

# encoder frame count used for decode-mode whisper cells (encoder runs once
# at prefill; decode attends to its output)
WHISPER_DECODE_ENC_LEN = 1536


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for one (arch x shape) cell.

    train   -> {tokens, labels [, frames | vision_embeds]}
    prefill -> {tokens [, frames | vision_embeds]}
    decode  -> {tokens (B,1) [, enc_out]}  (the KV cache spec comes from
               Model.abstract_cache(batch, seq_len))
    """
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        specs = {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}
        if cfg.family == "encdec":
            specs["frames"] = sds((B, S, cfg.d_model), dt)
        if cfg.family == "vlm":
            specs["vision_embeds"] = sds((B, cfg.vision_tokens,
                                          cfg.d_model), dt)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": sds((B, S), i32)}
        if cfg.family == "encdec":
            specs["frames"] = sds((B, S, cfg.d_model), dt)
        if cfg.family == "vlm":
            specs["vision_embeds"] = sds((B, cfg.vision_tokens,
                                          cfg.d_model), dt)
        return specs
    # decode: one new token against a cache of length S
    specs = {"tokens": sds((B, 1), i32)}
    if cfg.family == "encdec":
        specs["enc_out"] = sds((B, WHISPER_DECODE_ENC_LEN, cfg.d_model), dt)
    return specs


def make_inputs(cfg: ModelConfig, shape: ShapeConfig,
                key: Optional[jax.Array] = None) -> Dict[str, jax.Array]:
    """Concrete random inputs matching input_specs (smoke tests/examples)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    out = {}
    for name, spec in input_specs(cfg, shape).items():
        key, sub = jax.random.split(key)
        if spec.dtype == jnp.int32:
            out[name] = jax.random.randint(sub, spec.shape, 0,
                                           cfg.vocab_size, jnp.int32)
        else:
            out[name] = jax.random.normal(sub, spec.shape, jnp.float32
                                          ).astype(spec.dtype)
    return out
