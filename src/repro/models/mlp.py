"""Dense (optionally gated) MLP blocks: SwiGLU / GeGLU / plain."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamDef, ParamDefs, Params, activation


def mlp_param_defs(cfg: ModelConfig, d_ff: int = 0) -> ParamDefs:
    D, F = cfg.d_model, d_ff or cfg.d_ff
    defs: ParamDefs = {
        "w_up": ParamDef((D, F), ("ffn_in", "ffn")),
        "w_down": ParamDef((F, D), ("ffn", "ffn_in")),
    }
    if cfg.gated_mlp:
        defs["w_gate"] = ParamDef((D, F), ("ffn_in", "ffn"))
    return defs


def mlp_block(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    act = activation(cfg.mlp_act)
    up = x @ p["w_up"]
    h = act(x @ p["w_gate"]) * up if cfg.gated_mlp else act(up)
    return h @ p["w_down"]
