"""Model assembly: decoder LMs (dense / MoE / VLM / SSM / hybrid) and the
Whisper-style encoder-decoder. Layer stacks are scanned (homogeneous archs)
or unrolled (whisper, recurrentgemma) per ``cfg.scan_layers``.

Modes:
  train   — full-sequence forward, logits for CE loss
  prefill — full-sequence forward, returns per-layer KV/state cache
  decode  — one token against an existing cache (``serve_step``)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (ParamDef, ParamDefs, Params, layer_norm,
                                 rms_norm, sinusoidal_positions, softcap,
                                 stacked, subtree)
from repro.sharding import constrain

_ACT = ("batch", "seq", "embed_act")  # canonical activation sharding


def _prefix(pre: str, defs: ParamDefs) -> ParamDefs:
    return {f"{pre}/{k}": v for k, v in defs.items()}


# ---------------------------------------------------------------------------
# per-layer param defs
# ---------------------------------------------------------------------------


def _decoder_layer_defs(cfg: ModelConfig) -> ParamDefs:
    D = cfg.d_model
    defs: ParamDefs = {"ln1/g": ParamDef((D,), (None,), init="zeros")}
    if cfg.family == "ssm":
        defs.update(_prefix("ssm", ssm_mod.ssm_param_defs(cfg)))
        return defs
    defs.update(_prefix("attn", attn_mod.attn_param_defs(cfg)))
    defs["ln2/g"] = ParamDef((D,), (None,), init="zeros")
    if cfg.is_moe:
        defs.update(_prefix("moe", moe_mod.moe_param_defs(cfg)))
    else:
        defs.update(_prefix("mlp", mlp_mod.mlp_param_defs(cfg)))
    return defs


def _hybrid_layer_defs(cfg: ModelConfig, kind: str) -> ParamDefs:
    D = cfg.d_model
    defs: ParamDefs = {"ln1/g": ParamDef((D,), (None,), init="zeros"),
                       "ln2/g": ParamDef((D,), (None,), init="zeros")}
    if kind == "R":
        defs.update(_prefix("rec", rglru_mod.rglru_param_defs(cfg)))
    else:
        defs.update(_prefix("attn", attn_mod.attn_param_defs(cfg)))
    defs.update(_prefix("mlp", mlp_mod.mlp_param_defs(cfg)))
    return defs


def hybrid_pattern(cfg: ModelConfig):
    pat = cfg.block_pattern or "A"
    return [pat[i % len(pat)] for i in range(cfg.num_layers)]


def _encdec_layer_defs(cfg: ModelConfig, cross: bool) -> ParamDefs:
    D = cfg.d_model
    defs: ParamDefs = {
        "ln1/g": ParamDef((D,), (None,), init="ones"),
        "ln1/b": ParamDef((D,), (None,), init="zeros"),
        "ln2/g": ParamDef((D,), (None,), init="ones"),
        "ln2/b": ParamDef((D,), (None,), init="zeros"),
    }
    defs.update(_prefix("attn", attn_mod.attn_param_defs(cfg)))
    defs.update(_prefix("mlp", mlp_mod.mlp_param_defs(cfg)))
    if cross:
        defs["lnx/g"] = ParamDef((D,), (None,), init="ones")
        defs["lnx/b"] = ParamDef((D,), (None,), init="zeros")
        defs.update(_prefix("xattn", attn_mod.attn_param_defs(cfg, cross=True)))
    return defs


def model_param_defs(cfg: ModelConfig, max_seq: int) -> ParamDefs:
    D, V = cfg.d_model, cfg.vocab_size
    defs: ParamDefs = {
        "emb/tok": ParamDef((V, D), ("vocab", "embed"), scale=0.02),
        "final_ln/g": ParamDef((D,), (None,), init="zeros"),
    }
    if not cfg.tie_embeddings:
        defs["emb/out"] = ParamDef((D, V), ("embed", "vocab"),
                                   scale=D ** -0.5)
    if cfg.family == "encdec":
        # whisper uses LayerNorm: gamma is multiplicative (init ones)
        defs["final_ln/g"] = ParamDef((D,), (None,), init="ones")
        defs["final_ln/b"] = ParamDef((D,), (None,), init="zeros")
        defs["enc_ln/g"] = ParamDef((D,), (None,), init="ones")
        defs["enc_ln/b"] = ParamDef((D,), (None,), init="zeros")
        defs["pos/dec"] = ParamDef((max_seq, D), ("seq", "embed"), scale=0.02)
        enc = _encdec_layer_defs(cfg, cross=False)
        dec = _encdec_layer_defs(cfg, cross=True)
        for i in range(cfg.enc_layers):
            defs.update(_prefix(f"enc_{i}", enc))
        for i in range(cfg.dec_layers):
            defs.update(_prefix(f"dec_{i}", dec))
        return defs
    if cfg.family == "hybrid":
        for i, kind in enumerate(hybrid_pattern(cfg)):
            defs.update(_prefix(f"layer_{i}", _hybrid_layer_defs(cfg, kind)))
        return defs
    layer = _decoder_layer_defs(cfg)
    if cfg.scan_layers:
        defs.update(stacked(layer, cfg.num_layers, "blocks"))
    else:
        for i in range(cfg.num_layers):
            defs.update(_prefix(f"layer_{i}", layer))
    return defs


def layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    """Per-layer local-attention window (0 = global), shape (L,) int32."""
    if cfg.alt_local_global:
        w = [cfg.local_window if i % 2 == 0 else 0
             for i in range(cfg.num_layers)]
    else:
        w = [cfg.local_window] * cfg.num_layers
    return jnp.asarray(w, jnp.int32)


# ---------------------------------------------------------------------------
# cache defs
# ---------------------------------------------------------------------------


def cache_param_defs(cfg: ModelConfig, batch: int, max_len: int) -> ParamDefs:
    if cfg.family == "ssm":
        return ssm_mod.ssm_state_defs(cfg, batch, cfg.num_layers)
    if cfg.family == "hybrid":
        pat = hybrid_pattern(cfg)
        n_rec = sum(1 for k in pat if k == "R")
        n_attn = len(pat) - n_rec
        W = min(cfg.local_window or max_len, max_len)
        defs = {f"rec/{k}": v for k, v in
                rglru_mod.rglru_state_defs(cfg, batch, n_rec).items()}
        K, hd = cfg.num_kv_heads, cfg.head_dim
        defs["attn/k"] = ParamDef((n_attn, batch, W, K, hd),
                                  ("stack", "batch", "kv_seq", "kv_heads",
                                   "head_dim"), init="zeros")
        defs["attn/v"] = ParamDef((n_attn, batch, W, K, hd),
                                  ("stack", "batch", "kv_seq", "kv_heads",
                                   "head_dim"), init="zeros")
        defs["attn/pos"] = ParamDef((n_attn, batch, W),
                                    ("stack", "batch", "kv_seq"),
                                    init="const", const=-1, dtype="int32")
        return defs
    if cfg.family == "encdec":
        K, hd = cfg.num_kv_heads, cfg.head_dim
        defs: ParamDefs = {}
        for i in range(cfg.dec_layers):
            defs[f"dec_{i}/k"] = ParamDef(
                (batch, max_len, K, hd),
                ("batch", "kv_seq", "kv_heads", "head_dim"), init="zeros")
            defs[f"dec_{i}/v"] = ParamDef(
                (batch, max_len, K, hd),
                ("batch", "kv_seq", "kv_heads", "head_dim"), init="zeros")
        # per-row encoder-output bank (StateBank kind "enc"): row b holds
        # slot b's encoder output, written at admission and read by every
        # decode tick's cross-attention — whisper decodes slot-isolated
        defs["enc/out"] = ParamDef(
            (batch, max_len, cfg.d_model), ("batch", "kv_seq", "embed"),
            init="zeros")
        return defs
    return attn_mod.cache_defs(cfg, batch, max_len, cfg.num_layers)


def paged_cache_param_defs(cfg: ModelConfig, num_pages: int,
                           page_size: int) -> ParamDefs:
    """Paged-pool KV cache defs (dense/moe/vlm serve; DESIGN.md §15)."""
    if cfg.family in ("ssm", "hybrid", "encdec"):
        raise ValueError(
            f"paged KV serving not supported for family '{cfg.family}' "
            "(recurrent state / ring buffers are not paged)")
    return attn_mod.paged_cache_defs(cfg, num_pages, page_size,
                                     cfg.num_layers)


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------


def _maybe_remat(fn, cfg: ModelConfig, mode: str):
    if mode != "train" or cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def _decoder_layer(cfg: ModelConfig, p: Params, x, *, positions, window,
                   cache=None, cache_pos=None, return_kv=False, impl,
                   page_table=None, kv_write_mask=None):
    """Dense/MoE/VLM/SSM layer body. Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    x = constrain(x, _ACT)
    if cfg.family == "ssm":
        h, new_state = ssm_mod.ssm_block(
            cfg, subtree(p, "ssm"), rms_norm(x, p["ln1/g"]),
            state=cache)
        return constrain(x + h, _ACT), new_state, aux
    h, new_cache = attn_mod.attention_block(
        cfg, subtree(p, "attn"), rms_norm(x, p["ln1/g"]),
        positions=positions, window=window, cache=cache,
        cache_pos=cache_pos, return_kv=return_kv, impl=impl,
        page_table=page_table, kv_write_mask=kv_write_mask)
    x = constrain(x + h, _ACT)
    z = rms_norm(x, p["ln2/g"])
    if cfg.is_moe:
        m, aux = moe_mod.moe_block(cfg, subtree(p, "moe"), z)
    else:
        m = mlp_mod.mlp_block(cfg, subtree(p, "mlp"), z)
    return constrain(x + m, _ACT), new_cache, aux


def _embed(cfg: ModelConfig, params: Params, tokens, vision_embeds=None):
    x = params["emb/tok"][tokens].astype(jnp.dtype(cfg.dtype))
    if cfg.family == "vlm" and vision_embeds is not None:
        x = jax.lax.dynamic_update_slice(
            x, vision_embeds.astype(x.dtype), (0, 0, 0))
    return constrain(x, _ACT)


def _unembed(cfg: ModelConfig, params: Params, x):
    x = constrain(rms_norm(x, params["final_ln/g"]), _ACT)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["emb/tok"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["emb/out"])
    logits = constrain(logits, ("batch", "seq", "vocab"))
    return softcap(logits.astype(jnp.float32), cfg.final_softcap)


def decoder_forward(
    cfg: ModelConfig,
    params: Params,
    tokens: jax.Array,                  # (B, S) int32
    *,
    mode: str = "train",                # train | prefill | decode
    cache: Optional[Params] = None,     # flat cache dict (stacked over layers)
    cache_pos=None,                     # decode: scalar position
    vision_embeds: Optional[jax.Array] = None,
    attn_impl: str = "chunked",
    page_table=None,                    # paged serve: (B, nb) int32
    kv_write_mask=None,                 # paged suffix prefill: (B, S) bool
) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    """Returns (logits, new_cache, aux_loss).

    ``cache_pos`` in decode mode is either a scalar (whole batch at one
    position — the dry-run/training-eval convention) or a (B,) int32 vector
    of PER-ROW positions (the serve engine's continuous-batching tick, where
    every slot sits at a different depth; see attention.decode_attention).
    With vector positions ``attn_impl="pallas_decode"`` selects the Pallas
    blocked decode kernel with the fused in-launch KV scatter
    (kernels.decode_attention; per-layer windows ride through the layer
    scan as traced scalars); the default jnp path is its parity oracle.

    With ``page_table`` set, ``cache`` is the per-layer physical page
    pool and vector ``cache_pos`` holds each row's FIRST write position;
    ``S > 1`` is the paged *suffix prefill* (positions ``cache_pos[b] +
    s``, writes masked by ``kv_write_mask``), ``S == 1`` the paged
    decode tick, ``attn_impl="pallas_paged"`` its Pallas kernel
    (DESIGN.md §15).
    """
    B, S = tokens.shape
    x = _embed(cfg, params, tokens, vision_embeds)
    if mode == "decode":
        if cache_pos is not None and jnp.ndim(cache_pos) >= 1:
            positions = (jnp.asarray(cache_pos, jnp.int32)[:, None]
                         + jnp.arange(S, dtype=jnp.int32)[None, :])  # (B, S)
        else:
            positions = jnp.full((1,), cache_pos, jnp.int32)
    else:
        positions = jnp.arange(S, dtype=jnp.int32)
    windows = layer_windows(cfg)
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.family == "ssm":
        return _ssm_forward(cfg, params, x, mode=mode, cache=cache)

    if cfg.scan_layers:
        blocks = subtree(params, "blocks")

        if mode == "train":
            def body(xc, xs):
                lp, w = xs
                y, _, aux = _decoder_layer(cfg, lp, xc, positions=positions,
                                           window=w, impl=attn_impl)
                return y, aux
            body = _maybe_remat(body, cfg, mode)
            x, auxs = jax.lax.scan(body, x, (blocks, windows))
            return _unembed(cfg, params, x), None, auxs.sum()

        if mode == "prefill":
            def body(xc, xs):
                lp, w = xs
                y, kv, aux = _decoder_layer(cfg, lp, xc, positions=positions,
                                            window=w, return_kv=True,
                                            impl=attn_impl)
                return y, (kv["k"], kv["v"], aux)
            x, (ck, cv, auxs) = jax.lax.scan(body, x, (blocks, windows))
            return (_unembed(cfg, params, x), {"k": ck, "v": cv}, auxs.sum())

        # decode (page_table/kv_write_mask are layer-invariant: closed
        # over, not scanned — the per-layer pool slices are)
        def body(xc, xs):
            lp, w, k_l, v_l = xs
            y, kv, _ = _decoder_layer(cfg, lp, xc, positions=positions,
                                      window=w, cache={"k": k_l, "v": v_l},
                                      cache_pos=cache_pos, impl=attn_impl,
                                      page_table=page_table,
                                      kv_write_mask=kv_write_mask)
            return y, (kv["k"], kv["v"])
        x, (ck, cv) = jax.lax.scan(body, x, (blocks, windows, cache["k"],
                                             cache["v"]))
        return _unembed(cfg, params, x), {"k": ck, "v": cv}, aux_total

    # unrolled homogeneous stack
    new_cache: Dict[str, jax.Array] = {}
    for i in range(cfg.num_layers):
        lp = subtree(params, f"layer_{i}")
        c_i = None
        if cache is not None:
            c_i = {"k": cache["k"][i], "v": cache["v"][i]}

        def layer_fn(lp_, x_, w=int(windows[i]), c=c_i):
            return _decoder_layer(
                cfg, lp_, x_, positions=positions, window=w, cache=c,
                cache_pos=cache_pos, return_kv=(mode == "prefill"),
                impl=attn_impl, page_table=page_table,
                kv_write_mask=kv_write_mask)

        x, kv, aux = _maybe_remat(layer_fn, cfg, mode)(lp, x)
        aux_total += aux
        if kv is not None:
            new_cache.setdefault("k", []).append(kv["k"])
            new_cache.setdefault("v", []).append(kv["v"])
    out_cache = None
    if new_cache:
        out_cache = {k: jnp.stack(v) for k, v in new_cache.items()}
    return _unembed(cfg, params, x), out_cache, aux_total


def _ssm_forward(cfg, params, x, *, mode, cache):
    blocks = subtree(params, "blocks")

    if mode == "train":
        def body(xc, lp):
            y, _, aux = _decoder_layer(cfg, lp, xc, positions=None,
                                       window=0, impl="chunked")
            return y, aux
        body = _maybe_remat(body, cfg, mode)
        x, auxs = jax.lax.scan(body, x, blocks)
        return _unembed(cfg, params, x), None, auxs.sum()

    if mode == "prefill":
        def body2(xc, lp):
            h, st = ssm_mod.ssm_block(cfg, subtree(lp, "ssm"),
                                      rms_norm(xc, lp["ln1/g"]), state=None)
            return constrain(xc + h, _ACT), (st["conv"], st["ssm"])
        x, (conv, ssm) = jax.lax.scan(body2, x, blocks)
        return _unembed(cfg, params, x), {"conv": conv, "ssm": ssm}, jnp.zeros((), jnp.float32)

    # decode
    def body(xc, xs):
        lp, conv_l, ssm_l = xs
        h, st = ssm_mod.ssm_block(cfg, subtree(lp, "ssm"),
                                  rms_norm(xc, lp["ln1/g"]),
                                  state={"conv": conv_l, "ssm": ssm_l})
        return constrain(xc + h, _ACT), (st["conv"], st["ssm"])
    x, (conv, ssm) = jax.lax.scan(body, x, (blocks, cache["conv"],
                                            cache["ssm"]))
    return (_unembed(cfg, params, x), {"conv": conv, "ssm": ssm},
            jnp.zeros((), jnp.float32))


# ---------------------------------------------------------------------------
# hybrid (recurrentgemma) forward — unrolled heterogeneous stack
# ---------------------------------------------------------------------------


def hybrid_forward(cfg: ModelConfig, params: Params, tokens, *, mode="train",
                   cache=None, cache_pos=None, attn_impl="chunked"):
    """``cache_pos`` in decode mode is a scalar (whole batch at one
    position — the dry-run convention) or a (B,) int32 vector of PER-ROW
    positions (batched serve): each row then writes its k/v into its OWN
    ring slot ``cache_pos[b] % W`` and attends through
    ``attention.ring_decode_attention``'s per-row position mask, so serve
    slots at different depths stay isolated (DESIGN.md §17)."""
    vec = cache_pos is not None and jnp.ndim(cache_pos) >= 1
    cp_vec = jnp.asarray(cache_pos, jnp.int32) if vec else None
    B, S = tokens.shape
    x = _embed(cfg, params, tokens)
    pat = hybrid_pattern(cfg)
    if mode == "decode":
        positions = (cp_vec[:, None] if vec
                     else jnp.full((1,), cache_pos, jnp.int32))
    else:
        positions = jnp.arange(S, dtype=jnp.int32)
    W = cfg.local_window
    r_i = a_i = 0
    new_rec_h, new_rec_conv = [], []
    new_k, new_v, new_pos = [], [], []

    def attn_ring_decode(lp, z, idx):
        """Local attention against a ring-buffer cache of size W."""
        k_l, v_l, pos_l = (cache["attn/k"][idx], cache["attn/v"][idx],
                           cache["attn/pos"][idx])
        p = subtree(lp, "attn")
        q = jnp.einsum("bsd,dhk->bshk", z, p["wq"])
        k = jnp.einsum("bsd,dhk->bshk", z, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", z, p["wv"])
        if cfg.rope_theta:
            pos2d = positions if positions.ndim > 1 else positions[None, :]
            q = attn_mod.rope(q, pos2d, cfg.rope_theta)
            k = attn_mod.rope(k, pos2d, cfg.rope_theta)
        Wr = k_l.shape[1]
        if vec:
            rows = jnp.arange(B)
            slot = jnp.mod(cp_vec, Wr)
            k_l = k_l.at[rows, slot].set(k[:, 0].astype(k_l.dtype))
            v_l = v_l.at[rows, slot].set(v[:, 0].astype(v_l.dtype))
            pos_l = pos_l.at[rows, slot].set(cp_vec)
            out = attn_mod.ring_decode_attention(
                q, k_l, v_l, q_pos=cp_vec, k_positions=pos_l, window=W,
                logit_cap=cfg.attn_softcap)
            y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
            return y, (k_l, v_l, pos_l)
        slot = jnp.mod(cache_pos, Wr)
        k_l = jax.lax.dynamic_update_slice_in_dim(
            k_l, k.astype(k_l.dtype), slot, axis=1)
        v_l = jax.lax.dynamic_update_slice_in_dim(
            v_l, v.astype(v_l.dtype), slot, axis=1)
        pos_l = jax.lax.dynamic_update_slice_in_dim(
            pos_l, jnp.full((B, 1), cache_pos, jnp.int32), slot, axis=1)
        out = attn_mod.naive_attention(
            q, k_l, v_l, causal=True, window=W, logit_cap=cfg.attn_softcap,
            q_offset=cache_pos, k_positions=pos_l[0])
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
        return y, (k_l, v_l, pos_l)

    def _train_layer(lp_, x_, kind):
        """One hybrid layer, train mode (no cache) — rematerializable."""
        z_ = rms_norm(x_, lp_["ln1/g"])
        if kind == "R":
            h_, _ = rglru_mod.rglru_block(cfg, subtree(lp_, "rec"), z_)
        else:
            h_, _ = attn_mod.attention_block(
                cfg, subtree(lp_, "attn"), z_, positions=positions,
                window=W, impl=attn_impl)
        x_ = constrain(x_ + h_, _ACT)
        return constrain(
            x_ + mlp_mod.mlp_block(cfg, subtree(lp_, "mlp"),
                                   rms_norm(x_, lp_["ln2/g"])), _ACT)

    if mode == "train":
        for i, kind in enumerate(pat):
            lp = subtree(params, f"layer_{i}")
            fn = _maybe_remat(lambda lp_, x_, k=kind: _train_layer(lp_, x_, k),
                              cfg, mode)
            x = fn(lp, x)
        return _unembed(cfg, params, x), None, jnp.zeros((), jnp.float32)

    for i, kind in enumerate(pat):
        lp = subtree(params, f"layer_{i}")
        z = rms_norm(x, lp["ln1/g"])
        if kind == "R":
            st = None
            if cache is not None:
                st = {"h": cache["rec/h"][r_i], "conv": cache["rec/conv"][r_i]}
            h, st_new = rglru_mod.rglru_block(cfg, subtree(lp, "rec"), z,
                                              state=st)
            if st_new is not None:
                new_rec_h.append(st_new["h"])
                new_rec_conv.append(st_new["conv"])
            r_i += 1
        else:
            if mode == "decode":
                h, (k_l, v_l, pos_l) = attn_ring_decode(lp, z, a_i)
                new_k.append(k_l)
                new_v.append(v_l)
                new_pos.append(pos_l)
            else:
                h, kv = attn_mod.attention_block(
                    cfg, subtree(lp, "attn"), z, positions=positions,
                    window=W, return_kv=(mode == "prefill"), impl=attn_impl)
                if kv is not None:
                    # fold the last-W keys into the ring layout
                    ks, vs = kv["k"][:, -W:], kv["v"][:, -W:]
                    kpos = jnp.maximum(jnp.arange(S - min(W, S), S), -1)
                    pad = W - min(W, S)
                    if pad:
                        ks = jnp.pad(ks, ((0, 0), (0, pad), (0, 0), (0, 0)))
                        vs = jnp.pad(vs, ((0, 0), (0, pad), (0, 0), (0, 0)))
                        kpos = jnp.pad(kpos, (0, pad), constant_values=-1)
                    # ring layout: slot = pos % W; empty (pos=-1) entries go
                    # to the unused tail slots so they never clobber real kv
                    slots = jnp.where(kpos >= 0, jnp.mod(kpos, W),
                                      jnp.arange(W))
                    k_r = jnp.zeros_like(ks).at[:, slots].set(ks)
                    v_r = jnp.zeros_like(vs).at[:, slots].set(vs)
                    p_r = jnp.full((B, W), -1, jnp.int32).at[:, slots].set(
                        jnp.where(kpos >= 0, kpos, -1)[None, :])
                    new_k.append(k_r)
                    new_v.append(v_r)
                    new_pos.append(p_r)
            a_i += 1
        x = constrain(x + h, _ACT)
        x = constrain(x + mlp_mod.mlp_block(cfg, subtree(lp, "mlp"),
                                            rms_norm(x, lp["ln2/g"])), _ACT)

    new_cache = None
    if new_rec_h or new_k:
        new_cache = {}
        if new_rec_h:
            new_cache["rec/h"] = jnp.stack(new_rec_h)
            new_cache["rec/conv"] = jnp.stack(new_rec_conv)
        if new_k:
            new_cache["attn/k"] = jnp.stack(new_k)
            new_cache["attn/v"] = jnp.stack(new_v)
            new_cache["attn/pos"] = jnp.stack(new_pos)
    return _unembed(cfg, params, x), new_cache, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# encoder-decoder (whisper) forward
# ---------------------------------------------------------------------------


def _encdec_layer(cfg, p, x, *, positions, causal, enc_out=None, cache=None,
                  cache_pos=None, return_kv=False, impl):
    h, kv = attn_mod.attention_block(
        cfg, subtree(p, "attn"), layer_norm(x, p["ln1/g"], p["ln1/b"]),
        positions=positions, causal=causal, window=0, cache=cache,
        cache_pos=cache_pos, return_kv=return_kv, impl=impl)
    x = constrain(x + h, _ACT)
    if enc_out is not None:
        h, _ = attn_mod.attention_block(
            cfg, subtree(p, "xattn"), layer_norm(x, p["lnx/g"], p["lnx/b"]),
            positions=positions, kv_source=enc_out, impl=impl)
        x = constrain(x + h, _ACT)
    x = constrain(x + mlp_mod.mlp_block(cfg, subtree(p, "mlp"),
                                        layer_norm(x, p["ln2/g"], p["ln2/b"])),
                  _ACT)
    return x, kv


def encoder_forward(cfg: ModelConfig, params: Params, frames: jax.Array,
                    attn_impl="chunked", train: bool = False) -> jax.Array:
    """frames: (B, Se, D) stub embeddings (conv frontend is a stub)."""
    B, Se, D = frames.shape
    x = frames.astype(jnp.dtype(cfg.dtype)) + sinusoidal_positions(
        Se, D).astype(jnp.dtype(cfg.dtype))[None]
    x = constrain(x, _ACT)
    positions = jnp.arange(Se, dtype=jnp.int32)
    for i in range(cfg.enc_layers):
        def layer_fn(lp_, x_):
            return _encdec_layer(cfg, lp_, x_, positions=positions,
                                 causal=False, impl=attn_impl)
        x, _ = _maybe_remat(layer_fn, cfg, "train" if train else "eval")(
            subtree(params, f"enc_{i}"), x)
    return layer_norm(x, params["enc_ln/g"], params["enc_ln/b"])


def encdec_forward(cfg: ModelConfig, params: Params, tokens, *, frames=None,
                   enc_out=None, mode="train", cache=None, cache_pos=None,
                   attn_impl="chunked"):
    """Decoder (+ optional encoder) forward. Returns (logits, cache, aux).

    ``cache_pos`` in decode mode is a scalar (dry-run convention) or a
    (B,) int32 vector of PER-ROW positions (batched serve): each row then
    takes its own learned-position slice ``pos/dec[cache_pos[b]]``, its
    self-attention KV writes land at its own row position (the per-layer
    ``dec_i/*`` banks have batch axis 0), and — when ``enc_out`` is not
    given — cross-attention reads the per-row ``enc/out`` bank from the
    cache, so each slot decodes against ITS OWN encoder output
    (DESIGN.md §17)."""
    if enc_out is None and frames is not None:
        enc_out = encoder_forward(cfg, params, frames, attn_impl,
                                  train=(mode == "train"))
    if enc_out is None and cache is not None and "enc/out" in cache:
        enc_out = cache["enc/out"]
    B, S = tokens.shape
    vec = cache_pos is not None and jnp.ndim(cache_pos) >= 1
    if mode == "decode":
        if vec:
            cp = jnp.asarray(cache_pos, jnp.int32)
            positions = cp[:, None]                       # (B, 1)
            pos_emb = params["pos/dec"][cp][:, None]      # (B, 1, D)
        else:
            positions = jnp.full((1,), cache_pos, jnp.int32)
            pos_emb = jax.lax.dynamic_slice_in_dim(
                params["pos/dec"], cache_pos, 1, axis=0)[None]
    else:
        positions = jnp.arange(S, dtype=jnp.int32)
        pos_emb = params["pos/dec"][:S][None]
    x = constrain(params["emb/tok"][tokens].astype(jnp.dtype(cfg.dtype))
                  + pos_emb, _ACT)
    new_cache: Dict[str, jax.Array] = {}
    for i in range(cfg.dec_layers):
        c_i = None
        if cache is not None:
            c_i = {"k": cache[f"dec_{i}/k"], "v": cache[f"dec_{i}/v"]}

        def layer_fn(lp_, x_, enc_, c=c_i):
            return _encdec_layer(
                cfg, lp_, x_, positions=positions, causal=True, enc_out=enc_,
                cache=c, cache_pos=cache_pos,
                return_kv=(mode == "prefill"), impl=attn_impl)

        x, kv = _maybe_remat(layer_fn, cfg, mode)(
            subtree(params, f"dec_{i}"), x, enc_out)
        if kv is not None:
            new_cache[f"dec_{i}/k"] = kv["k"]
            new_cache[f"dec_{i}/v"] = kv["v"]
    if new_cache and cache is not None and "enc/out" in cache:
        # pass the enc bank through unchanged so the decode cache pytree
        # keeps a stable structure (the serve window donates it as a carry)
        new_cache["enc/out"] = cache["enc/out"]
    x = layer_norm(x, params["final_ln/g"], params["final_ln/b"])
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["emb/tok"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["emb/out"])
    return (logits.astype(jnp.float32), new_cache or None,
            jnp.zeros((), jnp.float32))
