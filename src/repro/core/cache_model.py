"""Microarchitecture-level cache PPA model ("NVSim-lite", paper §3.2).

NVSim itself is a closed C++ tool with a proprietary 16nm tech file, so we
implement a parametric analytical cache array model with the same structure
(subarray bitline/wordline RC, decoders, sense amps, H-tree routing with
repeaters, bank organization) and calibrate its constants so that the
EDAP-optimal configurations reproduce the paper's Table 2 anchors at
{SRAM 3MB, STT 3/7MB, SOT 3/10MB} and the Fig-10 scaling crossovers.

Conventions (documented deviations -> DESIGN.md):
  * reads fill a full 128 B line; writes update one 32 B sector (GPU L2 is
    32 B-sectored) with ~50% bit-flip rate (differential write).
  * "access type" {Normal, Fast, Sequential} is abstracted as a PPA
    trade-off multiplier triple (NVSim's internal modes are unavailable).

The design space swept per (memory, capacity) is banks x subarray-rows x
access type. ``evaluate_batch`` is the array-native core: one elementwise
JAX computation over a stacked (memory x capacity x banks x rows x access)
tensor, differentiable in the calibration constants. Everything else in
this module (``design_grid``, ``evaluate_config``) is a thin per-point view
over it for compatibility; ``repro.core.sweep`` builds the batched
design-space engine (Algorithm 1, iso-area search, calibration loss) on
top, and ``repro.core.tuner`` keeps the paper-shaped public API.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitcell import TABLE1, Bitcell
from repro.core.constants import LINE_BYTES, MB

# --- calibrated technology constants (16nm-class) --------------------------
# Derived analytically from Table 1/2 anchors, then polished by the
# calibration sweep in tools/calibrate_cache.py. See DESIGN.md §3.
CAL = {
    # frozen output of tools/calibrate_cache.py (mean |log err| 0.088 over
    # the 30 Table-2 anchor numbers; see that script for the fit loop)
    "sram_cell_um2": 0.107589,   # foundry 6T bitcell (incl. well/strap)
    "layout_overhead": 0.789732,  # array wiring/well overhead multiplier - 1
    "sa_area_um2": 23.0016,      # sense amp + write driver per column
    "bank_area_mm2": 0.0321116,  # per-bank control/decode block
    "dec_ns": 0.17578,           # decoder base delay
    "dec_log_ns": 0.00964161,    # + per log2(rows*banks)
    "bl_ns_per_row": 7.50848e-4,  # bitline RC per row
    "rt_ns_per_mm": 0.748434,    # H-tree (repeatered) delay per mm
    "rt_ns_per_mm2": 0.089795,   # superlinear term (mux/levels)
    "wr_drv_ns": 0.176685,       # write driver setup
    "e_dec_nj": 0.0789971,       # decoder + control energy per access
    "e_wire_nj_mm": 0.193917,    # data movement energy per mm of H-tree
    "e_sense_mult": 10.1942,     # SA + reference path vs raw cell sense
    "wr_flip_rate": 0.213389,    # differential-write bit-flip rate
    "wr_sector_bits": 256,       # 32 B sectored writes (GPU L2)
    "p_cell_nw": 196.726,        # SRAM array leakage per bit (HP 16nm)
    "p_periph_mw_mm2": 942.079,  # periphery leakage per mm^2
}

ACCESS_TYPES = ("Normal", "Fast", "Sequential")
# (latency, energy, area) multipliers
_ACC_MULT = {
    "Normal": (1.00, 1.00, 1.00),
    "Fast": (0.75, 1.25, 1.10),
    "Sequential": (1.10, 0.80, 0.98),
}

BANKS = (1, 2, 4, 8, 16, 32, 64)
ROWS = (128, 256, 512, 1024, 2048)


@dataclasses.dataclass(frozen=True)
class CachePPA:
    """Per-access PPA of one cache configuration."""
    mem: str
    capacity_mb: float
    banks: int
    rows: int
    access_type: str
    read_latency_ns: float
    write_latency_ns: float
    read_energy_nj: float
    write_energy_nj: float
    leakage_mw: float
    area_mm2: float

    @property
    def edap(self) -> float:
        e = 0.5 * (self.read_energy_nj + self.write_energy_nj)
        d = 0.5 * (self.read_latency_ns + self.write_latency_ns)
        return e * d * self.area_mm2

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


PPA_METRICS = ("read_latency_ns", "write_latency_ns", "read_energy_nj",
               "write_energy_nj", "leakage_mw", "area_mm2")

# bitcell fields entering the array model, stacked over the memory axis
_CELL_FIELDS = ("area_rel_sram", "sense_latency_ps", "sense_energy_pj",
                "write_latency_ps", "write_energy_pj", "leak_rel_sram")


def cell_arrays(cells: Sequence[Bitcell]) -> Dict[str, jnp.ndarray]:
    """Stack bitcell parameters into (M,) arrays for ``evaluate_batch``."""
    return {f: jnp.asarray([getattr(c, f) for c in cells], jnp.float32)
            for f in _CELL_FIELDS}


def evaluate_batch(cells: Dict[str, jnp.ndarray], caps_mb: jnp.ndarray,
                   c: Dict = CAL) -> Dict[str, jnp.ndarray]:
    """Array-native PPA model: one elementwise computation over the full
    (memory x capacity x banks x rows x access-type) design tensor.

    ``cells`` is a ``cell_arrays`` dict of (M,) arrays, ``caps_mb`` a (C,)
    array of capacities in MB, ``c`` the calibration constants (a pytree —
    traceable, so the whole tensor is differentiable in the constants).
    Returns {metric: (M, C, len(BANKS), len(ROWS), len(ACCESS_TYPES))}.
    """
    cap = jnp.asarray(caps_mb, jnp.float32)[None, :, None, None, None]
    cf = {k: v[:, None, None, None, None] for k, v in cells.items()}
    banks = jnp.asarray(BANKS, jnp.float32)[None, None, :, None, None]
    rows = jnp.asarray(ROWS, jnp.float32)[None, None, None, :, None]
    lat_m = jnp.asarray([_ACC_MULT[a][0] for a in ACCESS_TYPES])[None, None,
                                                                 None, None, :]
    en_m = jnp.asarray([_ACC_MULT[a][1] for a in ACCESS_TYPES])[None, None,
                                                                None, None, :]
    ar_m = jnp.asarray([_ACC_MULT[a][2] for a in ACCESS_TYPES])[None, None,
                                                                None, None, :]

    nbits = cap * (MB * 8.0)
    cell_um2 = c["sram_cell_um2"] * cf["area_rel_sram"]
    a_cells = nbits * cell_um2 * 1e-6 * (1.0 + c["layout_overhead"])  # mm^2
    n_cols = nbits / rows
    a_periph = n_cols * c["sa_area_um2"] * 1e-6 / jnp.sqrt(banks) \
        + banks * c["bank_area_mm2"]
    area = (a_cells + a_periph) * ar_m

    line_bits = LINE_BYTES * 8.0
    dist_mm = jnp.sqrt(area / banks) + 0.5 * jnp.sqrt(area)
    t_dec = c["dec_ns"] + c["dec_log_ns"] * jnp.log2(rows * banks)
    t_bl = c["bl_ns_per_row"] * rows
    t_rt = c["rt_ns_per_mm"] * dist_mm + c["rt_ns_per_mm2"] * area
    t_read = (t_dec + t_bl + cf["sense_latency_ps"] * 1e-3 + t_rt) * lat_m
    t_write = (t_dec + 0.5 * t_rt + c["wr_drv_ns"]
               + cf["write_latency_ps"] * 1e-3) * lat_m

    e_wire = c["e_wire_nj_mm"] * dist_mm
    e_read = (c["e_dec_nj"] + e_wire
              + line_bits * cf["sense_energy_pj"] * 1e-3
              * c["e_sense_mult"]) * en_m
    e_write = (c["e_dec_nj"] + e_wire
               + c["wr_sector_bits"] * c["wr_flip_rate"]
               * cf["write_energy_pj"] * 1e-3) * en_m

    leak = (c["p_cell_nw"] * 1e-6 * nbits * cf["leak_rel_sram"]
            + c["p_periph_mw_mm2"] * (area - a_cells * ar_m
                                      + 0.08 * a_cells * ar_m))
    shape = jnp.broadcast_shapes(area.shape, lat_m.shape, en_m.shape)
    return {
        "read_latency_ns": jnp.broadcast_to(t_read, shape),
        "write_latency_ns": jnp.broadcast_to(t_write, shape),
        "read_energy_nj": jnp.broadcast_to(e_read, shape),
        "write_energy_nj": jnp.broadcast_to(e_write, shape),
        "leakage_mw": jnp.broadcast_to(leak, shape),
        "area_mm2": jnp.broadcast_to(area, shape),
    }


_evaluate_batch_jit = jax.jit(evaluate_batch)


def _evaluate_grid(cell: Bitcell, capacity_mb: float, c: Dict = CAL):
    """Per-point view over ``evaluate_batch``: PPA dict of jnp arrays
    shaped (len(BANKS), len(ROWS), len(ACCESS_TYPES))."""
    g = _evaluate_batch_jit(cell_arrays([cell]),
                            jnp.asarray([capacity_mb], jnp.float32),
                            {k: float(v) for k, v in c.items()})
    return {k: v[0, 0] for k, v in g.items()}


def evaluate_config(mem: str, capacity_mb: float, banks: int, rows: int,
                    access_type: str, cal: Dict = CAL) -> CachePPA:
    cell = TABLE1[mem]
    g = _evaluate_grid(cell, capacity_mb, cal)
    bi, ri = BANKS.index(banks), ROWS.index(rows)
    ai = ACCESS_TYPES.index(access_type)
    vals = {k: float(v[bi, ri, ai]) for k, v in g.items()}
    return CachePPA(mem=mem, capacity_mb=capacity_mb, banks=banks, rows=rows,
                    access_type=access_type, **vals)


def design_grid(mem: str, capacity_mb: float, cal: Dict = CAL):
    """All CachePPA points of the design space for (mem, capacity)."""
    cell = TABLE1[mem]
    g = _evaluate_grid(cell, capacity_mb, cal)
    full = {k: np.asarray(v) for k, v in g.items()}
    out = []
    for bi, b in enumerate(BANKS):
        for ri, r in enumerate(ROWS):
            for ai, a in enumerate(ACCESS_TYPES):
                out.append(CachePPA(
                    mem=mem, capacity_mb=capacity_mb, banks=b, rows=r,
                    access_type=a,
                    **{k: float(v[bi, ri, ai]) for k, v in full.items()}))
    return out
