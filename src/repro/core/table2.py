"""Paper Table 2: EDAP-tuned cache PPA anchors.

The single source for the 30 anchor numbers {SRAM 3MB, STT 3/7MB,
SOT 3/10MB} x {read/write latency, read/write energy, leakage, area} —
the calibration targets of ``tools/calibrate_cache.py`` and the regression
contract checked by the tests and ``benchmarks/table2_cache.py``.
"""
from __future__ import annotations

from typing import Dict, Tuple

TABLE2_ANCHORS: Dict[Tuple[str, int], Dict[str, float]] = {
    ("SRAM", 3): dict(read_latency_ns=2.91, write_latency_ns=1.53,
                      read_energy_nj=0.35, write_energy_nj=0.32,
                      leakage_mw=6442, area_mm2=5.53),
    ("STT", 3): dict(read_latency_ns=2.98, write_latency_ns=9.31,
                     read_energy_nj=0.81, write_energy_nj=0.31,
                     leakage_mw=748, area_mm2=2.34),
    ("STT", 7): dict(read_latency_ns=4.58, write_latency_ns=10.06,
                     read_energy_nj=0.93, write_energy_nj=0.43,
                     leakage_mw=1706, area_mm2=5.12),
    ("SOT", 3): dict(read_latency_ns=3.71, write_latency_ns=1.38,
                     read_energy_nj=0.49, write_energy_nj=0.22,
                     leakage_mw=527, area_mm2=1.95),
    ("SOT", 10): dict(read_latency_ns=6.69, write_latency_ns=2.47,
                      read_energy_nj=0.51, write_energy_nj=0.40,
                      leakage_mw=1434, area_mm2=5.64),
}
