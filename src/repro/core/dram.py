"""DRAM traffic model (paper §3.4 / Fig 7).

GPGPU-Sim is replaced by two cross-validating components:

1. A power-law reuse/miss model: DRAM transactions at cache capacity C
   scale as (C / C0)^(-MISS_ALPHA) from the measured 3MB baseline. With
   MISS_ALPHA = 0.186 this reproduces the paper's Fig-7 AlexNet results
   (14.6% reduction at 7MB, 19.8% at 10MB) to within 0.5 points — the
   exponent is solved from those two published points and then *predicts*
   the rest of the 3..24MB curve.

2. The trace-driven set-associative LRU cache simulator
   (repro.core.cachesim + Pallas kernel repro.kernels.cache_sim), run on
   synthetic power-law-reuse traces, which produces the same curve shape
   from first principles (tests cross-check).
"""
from __future__ import annotations

from typing import Iterable, List

from repro.core.constants import GPU_L2_MB, MISS_ALPHA


def dram_scale(capacity_mb: float, base_mb: float = GPU_L2_MB,
               alpha: float = MISS_ALPHA) -> float:
    """DRAM-transaction multiplier vs the base capacity (<= 1 for bigger)."""
    return (capacity_mb / base_mb) ** (-alpha)


def reduction_pct_from_misses(misses: float, base_misses: float) -> float:
    """% DRAM-access reduction given simulated miss counts — the same
    formula the analytic curve uses, so the trace-driven validation
    (core/cachesim.py) and this model are directly comparable."""
    return 100.0 * (1.0 - misses / base_misses)


def dram_reduction_pct(capacity_mb: float, base_mb: float = GPU_L2_MB,
                       alpha: float = MISS_ALPHA) -> float:
    """Fig 7: percentage reduction in total DRAM accesses."""
    return 100.0 * (1.0 - dram_scale(capacity_mb, base_mb, alpha))


def fig7_curve(capacities: Iterable[float] = (3, 6, 12, 24)) -> List[float]:
    return [dram_reduction_pct(c) for c in capacities]
