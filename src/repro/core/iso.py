"""Iso-capacity and iso-area analyses (paper §4.1 / §4.2, Figs 4-9)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core import energy as en
from repro.core.cache_model import CachePPA
from repro.core.constants import GPU_L2_MB
from repro.core.dram import dram_scale
from repro.core.profiles import MemoryProfile, paper_profiles, profile
from repro.core.sweep import iso_area_search
from repro.core.tuner import iso_capacity_configs, tune


@dataclasses.dataclass
class IsoResult:
    """Per-workload normalized-to-SRAM metrics for STT and SOT."""
    workload: str
    metrics: Dict[str, Dict[str, float]]   # mem -> relative metrics


def _configs_iso_capacity(capacity_mb: float = GPU_L2_MB
                          ) -> Dict[str, CachePPA]:
    # one batched sweep over all three memories at this capacity
    return iso_capacity_configs(capacity_mb)


def _configs_iso_area(capacity_mb: float = GPU_L2_MB) -> Dict[str, CachePPA]:
    sram = tune("SRAM", capacity_mb)
    # one batched ladder sweep covering both NVMs; raises ValueError when
    # nothing fits the budget (legacy returned None and crashed downstream)
    nvm = iso_area_search(("STT", "SOT"), sram.area_mm2)
    return {"SRAM": sram, **nvm}


def iso_capacity(profiles: Optional[List[MemoryProfile]] = None,
                 capacity_mb: float = GPU_L2_MB) -> List[IsoResult]:
    """Figs 4-5: same capacity, NVM vs SRAM, DRAM identical across mems."""
    profiles = profiles or paper_profiles()
    cfgs = _configs_iso_capacity(capacity_mb)
    out = []
    for p in profiles:
        base = en.evaluate(p, cfgs["SRAM"])
        metrics = {m: en.relative(base, en.evaluate(p, cfgs[m]))
                   for m in ("STT", "SOT")}
        out.append(IsoResult(p.label, metrics))
    return out


def iso_area(profiles: Optional[List[MemoryProfile]] = None,
             capacity_mb: float = GPU_L2_MB,
             dram_model: str = "analytic",
             trace_kwargs: Optional[Dict] = None) -> List[IsoResult]:
    """Figs 8-9: same area -> larger NVM caches -> fewer DRAM accesses.

    ``dram_model`` picks how the DRAM-transaction multiplier at the
    iso-area capacities is obtained: ``"analytic"`` uses the power-law
    miss model (core/dram.py); ``"trace"`` runs the batched LRU ladder
    simulator (core/cachesim.py, one launch covering the base capacity
    and both NVM capacities), with ``trace_kwargs`` forwarded to
    ``trace_dram_scale``.
    """
    if dram_model not in ("analytic", "trace"):
        raise ValueError(f"dram_model must be 'analytic' or 'trace', "
                         f"got {dram_model!r}")
    profiles = profiles or paper_profiles()
    cfgs = _configs_iso_area(capacity_mb)
    if dram_model == "trace":
        from repro.core.cachesim import trace_dram_scale
        scales = trace_dram_scale(
            [cfgs[m].capacity_mb for m in ("STT", "SOT")],
            base_mb=capacity_mb, **(trace_kwargs or {}))
    else:
        scales = {cfgs[m].capacity_mb: dram_scale(cfgs[m].capacity_mb,
                                                  capacity_mb)
                  for m in ("STT", "SOT")}
    out = []
    for p in profiles:
        base = en.evaluate(p, cfgs["SRAM"])
        metrics = {}
        for m in ("STT", "SOT"):
            scale = scales[cfgs[m].capacity_mb]
            rep = en.evaluate(p, cfgs[m], dram_transactions=p.dram * scale)
            metrics[m] = en.relative(base, rep)
        out.append(IsoResult(p.label, metrics))
    return out


def iso_area_capacities(capacity_mb: float = GPU_L2_MB) -> Dict[str, float]:
    cfgs = _configs_iso_area(capacity_mb)
    return {m: cfgs[m].capacity_mb for m in ("STT", "SOT")}


def summarize(results: List[IsoResult], metric: str) -> Dict[str, Dict[str, float]]:
    """avg / best (max reduction = min ratio) per memory for one metric."""
    out = {}
    for m in ("STT", "SOT"):
        vals = [r.metrics[m][metric] for r in results]
        out[m] = {
            "mean": sum(vals) / len(vals),
            "min": min(vals),                 # best case (max reduction)
            "max": max(vals),
            "mean_reduction_x": len(vals) / sum(vals),  # harmonic-style
            "best_reduction_x": 1.0 / min(vals),
        }
    return out


def batch_sweep(net: str = "AlexNet", mode: str = "training",
                batches=(4, 8, 16, 32, 64, 128)) -> Dict[int, IsoResult]:
    """Fig 6: EDP (with DRAM) vs batch size, iso-capacity."""
    cfgs = _configs_iso_capacity()
    out = {}
    for b in batches:
        p = profile(net, mode, b)
        base = en.evaluate(p, cfgs["SRAM"])
        out[b] = IsoResult(p.label, {
            m: en.relative(base, en.evaluate(p, cfgs[m]))
            for m in ("STT", "SOT")})
    return out
