"""Iso-capacity and iso-area analyses (paper §4.1 / §4.2, Figs 4-9).

Since the traffic-engine refactor every analysis here consumes whole
traffic tensors: the profile set is stacked into (P,) read/write/DRAM
arrays and evaluated against each memory's tuned PPA in one array-native
energy computation (``energy.evaluate_arrays`` — jittable end-to-end with
the engine, DESIGN.md §10) instead of looping ``energy.evaluate`` per
(profile, memory) pair.  ``batch_sweep`` computes its whole batch grid
from a single engine evaluation.  Public APIs are unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import energy as en
from repro.core import traffic as tr
from repro.core.cache_model import CachePPA
from repro.core.constants import GPU_L2_MB
from repro.core.dram import dram_scale
from repro.core.profiles import MemoryProfile, paper_profiles
from repro.core.sweep import iso_area_search
from repro.core.tuner import iso_capacity_configs, tune

NVM_MEMS = ("STT", "SOT")


@dataclasses.dataclass
class IsoResult:
    """Per-workload normalized-to-SRAM metrics for STT and SOT."""
    workload: str
    metrics: Dict[str, Dict[str, float]]   # mem -> relative metrics


def _configs_iso_capacity(capacity_mb: float = GPU_L2_MB
                          ) -> Dict[str, CachePPA]:
    # one batched sweep over all three memories at this capacity
    return iso_capacity_configs(capacity_mb)


def _configs_iso_area(capacity_mb: float = GPU_L2_MB) -> Dict[str, CachePPA]:
    sram = tune("SRAM", capacity_mb)
    # one batched ladder sweep covering both NVMs; raises ValueError when
    # nothing fits the budget (legacy returned None and crashed downstream)
    nvm = iso_area_search(NVM_MEMS, sram.area_mm2)
    return {"SRAM": sram, **nvm}


def _profile_arrays(profiles: Sequence[MemoryProfile]):
    return (jnp.asarray([p.l2_reads for p in profiles], jnp.float32),
            jnp.asarray([p.l2_writes for p in profiles], jnp.float32),
            jnp.asarray([p.dram for p in profiles], jnp.float32))


def _relative_results(profiles: Sequence[MemoryProfile],
                      cfgs: Dict[str, CachePPA],
                      dram_scales: Optional[Dict[str, float]] = None
                      ) -> List[IsoResult]:
    """Whole-tensor evaluation: one array-energy pass per memory over the
    stacked profile set, unpacked into the legacy per-workload results."""
    reads, writes, dram = _profile_arrays(profiles)
    base = en.evaluate_arrays(reads, writes, dram,
                              en.ppa_scalars(cfgs["SRAM"]))
    rel = {}
    for m in NVM_MEMS:
        d = dram * dram_scales[m] if dram_scales else dram
        rep = en.evaluate_arrays(reads, writes, d, en.ppa_scalars(cfgs[m]))
        rel[m] = {k: np.asarray(v)
                  for k, v in en.relative_arrays(base, rep).items()}
    return [IsoResult(p.label,
                      {m: {k: float(rel[m][k][i]) for k in rel[m]}
                       for m in NVM_MEMS})
            for i, p in enumerate(profiles)]


def iso_capacity(profiles: Optional[List[MemoryProfile]] = None,
                 capacity_mb: float = GPU_L2_MB) -> List[IsoResult]:
    """Figs 4-5: same capacity, NVM vs SRAM, DRAM identical across mems."""
    profiles = profiles or paper_profiles()
    return _relative_results(profiles, _configs_iso_capacity(capacity_mb))


def iso_area(profiles: Optional[List[MemoryProfile]] = None,
             capacity_mb: float = GPU_L2_MB,
             dram_model: str = "analytic",
             trace_kwargs: Optional[Dict] = None) -> List[IsoResult]:
    """Figs 8-9: same area -> larger NVM caches -> fewer DRAM accesses.

    ``dram_model`` picks how the DRAM-transaction multiplier at the
    iso-area capacities is obtained: ``"analytic"`` uses the power-law
    miss model (core/dram.py); ``"trace"`` runs the batched LRU ladder
    simulator (core/cachesim.py, one launch covering the base capacity
    and both NVM capacities), with ``trace_kwargs`` forwarded to
    ``trace_dram_scale``.
    """
    if dram_model not in ("analytic", "trace"):
        raise ValueError(f"dram_model must be 'analytic' or 'trace', "
                         f"got {dram_model!r}")
    profiles = profiles or paper_profiles()
    cfgs = _configs_iso_area(capacity_mb)
    if dram_model == "trace":
        from repro.core.cachesim import trace_dram_scale
        by_cap = trace_dram_scale(
            [cfgs[m].capacity_mb for m in NVM_MEMS],
            base_mb=capacity_mb, **(trace_kwargs or {}))
        scales = {m: by_cap[cfgs[m].capacity_mb] for m in NVM_MEMS}
    else:
        scales = {m: dram_scale(cfgs[m].capacity_mb, capacity_mb)
                  for m in NVM_MEMS}
    return _relative_results(profiles, cfgs, dram_scales=scales)


def iso_area_capacities(capacity_mb: float = GPU_L2_MB) -> Dict[str, float]:
    cfgs = _configs_iso_area(capacity_mb)
    return {m: cfgs[m].capacity_mb for m in NVM_MEMS}


def summarize(results: List[IsoResult], metric: str) -> Dict[str, Dict[str, float]]:
    """avg / best (max reduction = min ratio) per memory for one metric."""
    out = {}
    for m in NVM_MEMS:
        vals = [r.metrics[m][metric] for r in results]
        out[m] = {
            "mean": sum(vals) / len(vals),
            "min": min(vals),                 # best case (max reduction)
            "max": max(vals),
            "mean_reduction_x": len(vals) / sum(vals),  # harmonic-style
            "best_reduction_x": 1.0 / min(vals),
        }
    return out


def batch_sweep(net: str = "AlexNet", mode: str = "training",
                batches=(4, 8, 16, 32, 64, 128)) -> Dict[int, IsoResult]:
    """Fig 6: EDP (with DRAM) vs batch size, iso-capacity — the whole
    batch grid comes from ONE engine evaluation and one energy pass."""
    cfgs = _configs_iso_capacity()
    tt = tr.compute_traffic(tr.paper_pack(), batches)
    profs = [tt.profile(net, mode, b) for b in batches]
    results = _relative_results(profs, cfgs)
    return {b: res for b, res in zip(batches, results)}
