"""Batched differentiable workload-traffic engine (DESIGN.md §10).

One jit-compiled call computes the full **(workload × mode × batch-grid)**
L2-read / L2-write / DRAM transaction tensor for every packed workload —
the paper's conv/fc layer stacks (``core.workloads``), HPCG, and the
modern ``configs/`` models lowered through the ``LayerStack`` adapter.
``core.profiles.profile()`` / ``paper_profiles()`` are thin views over
this engine; ``core.iso`` / ``core.scaling`` / ``core.crosslayer`` consume
whole traffic tensors; ``tools/calibrate_traffic.py`` differentiates the
§4 claim loss built here with ``jax.grad``.

Array layout (fixed throughout, DESIGN.md §10):

    axis 0  W   workload            (order of ``WorkloadPack.names``)
    axis 1  2   mode                (``MODES`` = inference, training)
    axis 2  NB  batch grid          (order of the ``batches`` argument)

Workloads are packed as padded (W, Lmax) per-layer descriptor arrays
(``in_bytes``, ``out_bytes``, ``weight_bytes``, ``kk``, conv/fc masks,
valid mask).  Because every TRAFFIC knob factors out of the layer sum,
the pack also carries six exact float64 per-workload reductions
(``a_conv = Σ_conv in·k²``, ``a_fc``, ``s_in``, ``s_out``, ``w_conv``,
``w_fc``) computed once at pack time; the jitted hot path combines them
with the knobs in a handful of f32 ops, which keeps the batched outputs
within 1e-6 relative of the float64 scalar reference
(``profiles._layer_traffic``) while staying differentiable in all six
knobs.  HPCG rows carry fixed (reads, writes) counts — batch- and
mode-independent — and override the layer formulas via ``hpc_mask``.

The traffic model itself is unchanged from the scalar seed (paper §3.3):

    inference:  reads  = B·Σ in·k_eff + W·(1 + B/w_tile)
                writes = B·Σ out
    training:   reads  = 2B·Σ in·k_eff + B·Σ out + W·(2 + B/grad_tile)
                writes = B·Σ(in + out) + W·(1 + B/(2·grad_tile))

with ``k_eff = k_im2col·k²`` for conv layers, 1 for fc; fc weight streams
scaled by ``fc_w_factor``; everything divided by ``LINE_BYTES``.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.constants import LINE_BYTES
from repro.core.workloads import HPCG, NETWORKS, HPCGWorkload, Network

# Traffic-model knobs; calibrated against the paper's §4 claims by
# tools/calibrate_traffic.py (Adam over the differentiable claim loss
# built by ``make_claim_loss`` — see DESIGN.md §10 for the claim set).
TRAFFIC = {
    # frozen output of tools/calibrate_traffic.py (mean |log err| 0.18 over
    # the paper's 13 quantitative §4 claims; R/W range penalty 0)
    "k_im2col": 0.51713,   # net im2col amplification / L1 reuse (k^2/r_L1)
    "w_tile": 32.6899,     # samples per weight re-stream (inference)
    "grad_tile": 4.46882,  # samples per weight-grad accumulation RMW
    "fc_w_factor": 0.324592,  # FC weight streams are unit-stride/coalesced
    "dram_frac_i": 0.00848827,  # DRAM:L2 transaction ratio, inference
    "dram_frac_t": 0.00797266,  # DRAM:L2 transaction ratio, training
}

MODES = ("inference", "training")

# Modern-config cohort threaded through the Fig-3 / iso-capacity analyses
# (benchmarks/fig3_rw_ratio.py, tests/test_traffic_engine.py).
MODERN_COHORT = ("llama3-8b", "mamba2-1.3b", "whisper-tiny")


@dataclasses.dataclass(frozen=True)
class MemoryProfile:
    """L2/DRAM transaction counts for one (workload, mode, batch)."""
    name: str
    mode: str            # "inference" | "training" | "hpc"
    batch: int
    l2_reads: float
    l2_writes: float
    dram: float          # DRAM transactions (at the 3MB baseline cache)

    @property
    def rw_ratio(self) -> float:
        return self.l2_reads / max(self.l2_writes, 1.0)

    @property
    def label(self) -> str:
        suffix = {"inference": "I", "training": "T", "hpc": ""}[self.mode]
        return f"{self.name}-{suffix}" if suffix else self.name


# ---------------------------------------------------------------------------
# Layer descriptors and the LayerStack adapter
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerDesc:
    """One layer's byte surfaces, as the traffic formulas see them.

    ``in_bytes`` / ``out_bytes`` are activation bytes per sample;
    ``weight_bytes`` is the streamed parameter surface; ``kk`` is the
    im2col k² amplification (1 for fc / pointwise layers)."""
    name: str
    kind: str            # "conv" | "fc"
    in_bytes: float
    out_bytes: float
    weight_bytes: float
    kk: float = 1.0


@dataclasses.dataclass(frozen=True)
class LayerStack:
    """A workload as a flat tuple of ``LayerDesc`` — the engine's unit.

    ``from_network`` lowers the paper's Table-3 conv/fc descriptors;
    ``from_config`` lowers a modern ``configs/`` model's per-layer byte
    surfaces (projection matrices, attention/scan state, activation
    tensors at ``seq_len`` tokens per sample, sized with the roofline
    dtype convention — ``launch.roofline.dtype_bytes``)."""
    name: str
    layers: Tuple[LayerDesc, ...]

    @classmethod
    def from_network(cls, net: Network) -> "LayerStack":
        descs = tuple(
            LayerDesc(l.name, l.kind, float(l.in_bytes), float(l.out_bytes),
                      float(l.weight_bytes),
                      float(l.k * l.k) if l.kind == "conv" else 1.0)
            for l in net.layers)
        return cls(net.name, descs)

    @classmethod
    def from_config(cls, cfg, seq_len: int = 4096) -> "LayerStack":
        return cls(cfg.arch, tuple(_lower_config(cfg, seq_len)))


def _fc_desc(name: str, tokens: int, d_in: int, d_out: int,
             db: int, weight_bytes: Optional[float] = None) -> LayerDesc:
    w = float(d_in * d_out * db) if weight_bytes is None else weight_bytes
    return LayerDesc(name, "fc", float(tokens * d_in * db),
                     float(tokens * d_out * db), w)


def _attn_desc(name: str, tokens: int, q_dim: int, kv_dim: int,
               db: int) -> LayerDesc:
    # weight-free mixing: reads Q plus the K/V surfaces, writes the context
    return LayerDesc(name, "fc", float(tokens * (q_dim + 2 * kv_dim) * db),
                     float(tokens * q_dim * db), 0.0)


def _lower_config(cfg, seq_len: int) -> List[LayerDesc]:
    """Per-layer byte surfaces of one modern ``ModelConfig``.

    First-order lowering: each projection matrix is an fc layer (tokens ×
    features activation surfaces, full weight matrix streamed — MoE
    streams only the ``top_k`` active experts); attention / SSM-scan
    mixing layers are weight-free with their state read as input surface.
    """
    from repro.launch.roofline import dtype_bytes

    db = dtype_bytes(cfg.dtype)
    tok = seq_len
    d = cfg.d_model
    out: List[LayerDesc] = []

    def attn_block(tag: str, kv_tokens: int = 0):
        q_dim = cfg.num_heads * cfg.head_dim
        kv_dim = cfg.num_kv_heads * cfg.head_dim
        out.append(_fc_desc(f"{tag}.qkv", tok, d, q_dim + 2 * kv_dim, db))
        out.append(_attn_desc(f"{tag}.mix", tok, q_dim, kv_dim, db))
        out.append(_fc_desc(f"{tag}.o", tok, q_dim, d, db))

    def mlp_block(tag: str):
        mlp_in = 2 * cfg.d_ff if cfg.gated_mlp else cfg.d_ff
        if cfg.is_moe:
            out.append(_fc_desc(f"{tag}.router", tok, d, cfg.num_experts, db))
            active = cfg.top_k * (d * mlp_in + cfg.d_ff * d) * db
            out.append(_fc_desc(f"{tag}.experts", tok, d, cfg.d_ff, db,
                                weight_bytes=float(active)))
            out.append(_fc_desc(f"{tag}.combine", tok, cfg.d_ff, d, db, 0.0))
        else:
            out.append(_fc_desc(f"{tag}.up", tok, d, mlp_in, db))
            out.append(_fc_desc(f"{tag}.down", tok, cfg.d_ff, d, db))

    def ssm_block(tag: str):
        d_in = cfg.ssm_expand * d
        d_xbc = d_in + 2 * cfg.ssm_state
        out.append(_fc_desc(f"{tag}.in", tok, d, d_in + d_xbc + cfg.ssm_heads,
                            db))
        # depthwise conv over the xBC stream (width = ssm_conv_width)
        out.append(LayerDesc(f"{tag}.conv", "conv",
                             float(tok * d_xbc * db), float(tok * d_xbc * db),
                             float(cfg.ssm_conv_width * d_xbc * db),
                             kk=float(cfg.ssm_conv_width)))
        # chunked scan: weight-free, reads xBC + recurrent state
        state = cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state
        out.append(LayerDesc(f"{tag}.scan", "fc",
                             float((tok * d_xbc + state) * db),
                             float(tok * d_in * db), 0.0))
        out.append(_fc_desc(f"{tag}.out", tok, d_in, d, db))

    def rglru_block(tag: str):
        w = cfg.lru_width or d
        out.append(_fc_desc(f"{tag}.in", tok, d, 2 * w, db))
        out.append(LayerDesc(f"{tag}.scan", "fc", float(tok * 2 * w * db),
                             float(tok * w * db), float(4 * w * db)))
        out.append(_fc_desc(f"{tag}.out", tok, w, d, db))

    fam = cfg.family
    if fam == "encdec":
        for i in range(cfg.enc_layers):
            attn_block(f"enc{i}")
            mlp_block(f"enc{i}")
        for i in range(cfg.dec_layers):
            attn_block(f"dec{i}.self")
            attn_block(f"dec{i}.cross")
            mlp_block(f"dec{i}")
    elif fam == "ssm":
        for i in range(cfg.num_layers):
            ssm_block(f"l{i}")
    elif fam == "hybrid":
        pat = cfg.block_pattern or "A"
        for i in range(cfg.num_layers):
            if pat[i % len(pat)] == "A":
                attn_block(f"l{i}")
            else:
                rglru_block(f"l{i}")
            mlp_block(f"l{i}")
    else:  # dense | moe | vlm
        for i in range(cfg.num_layers):
            attn_block(f"l{i}")
            mlp_block(f"l{i}")
    out.append(_fc_desc("lm_head", tok, d, cfg.vocab_size, db))
    return out


# ---------------------------------------------------------------------------
# Workload packing
# ---------------------------------------------------------------------------

# padded per-layer descriptor fields, each an (W, Lmax) array in the pack
LAYER_FIELDS = ("in_bytes", "out_bytes", "weight_bytes", "kk",
                "is_conv", "is_fc", "mask")
# exact float64 per-workload reductions; all six TRAFFIC knobs factor out
# of the layer sum, so these are the engine's hot-path inputs
REDUCED_FIELDS = ("a_conv", "a_fc", "s_in", "s_out", "w_conv", "w_fc")


@dataclasses.dataclass(frozen=True, eq=False)  # identity hash: device cache
class WorkloadPack:
    """Padded descriptor arrays for a set of workloads (DESIGN.md §10)."""
    names: Tuple[str, ...]
    layers: Dict[str, np.ndarray]    # LAYER_FIELDS -> (W, Lmax) float64
    reduced: Dict[str, np.ndarray]   # REDUCED_FIELDS -> (W,) float64
    hpc_reads: np.ndarray            # (W,) fixed counts, 0 for DL rows
    hpc_writes: np.ndarray
    hpc_mask: np.ndarray             # (W,) bool

    def index(self, name: str) -> int:
        if name not in self.names:
            raise ValueError(f"{name!r} not in this pack (has {self.names})")
        return self.names.index(name)


def pack_workloads(stacks: Sequence[LayerStack],
                   hpc: Sequence[HPCGWorkload] = ()) -> WorkloadPack:
    """Pack layer stacks (+ fixed-count HPC workloads) into padded arrays."""
    w = len(stacks) + len(hpc)
    lmax = max([len(s.layers) for s in stacks] or [1])
    layers = {f: np.zeros((w, lmax)) for f in LAYER_FIELDS}
    for i, s in enumerate(stacks):
        for j, l in enumerate(s.layers):
            layers["in_bytes"][i, j] = l.in_bytes
            layers["out_bytes"][i, j] = l.out_bytes
            layers["weight_bytes"][i, j] = l.weight_bytes
            layers["kk"][i, j] = l.kk
            layers["is_conv"][i, j] = 1.0 if l.kind == "conv" else 0.0
            layers["is_fc"][i, j] = 1.0 if l.kind == "fc" else 0.0
            layers["mask"][i, j] = 1.0
    conv, fc, m = (layers["is_conv"], layers["is_fc"], layers["mask"])
    reduced = {
        "a_conv": (layers["in_bytes"] * layers["kk"] * conv * m).sum(1),
        "a_fc": (layers["in_bytes"] * fc * m).sum(1),
        "s_in": (layers["in_bytes"] * m).sum(1),
        "s_out": (layers["out_bytes"] * m).sum(1),
        "w_conv": (layers["weight_bytes"] * conv * m).sum(1),
        "w_fc": (layers["weight_bytes"] * fc * m).sum(1),
    }
    hpc_r = np.zeros(w)
    hpc_w = np.zeros(w)
    hpc_m = np.zeros(w, dtype=bool)
    names = [s.name for s in stacks]
    for k, wload in enumerate(hpc):
        i = len(stacks) + k
        r, wr = wload.transactions()
        hpc_r[i], hpc_w[i], hpc_m[i] = r, wr, True
        names.append(wload.name)
    return WorkloadPack(tuple(names), layers, reduced, hpc_r, hpc_w, hpc_m)


@lru_cache(maxsize=None)
def paper_pack() -> WorkloadPack:
    """The paper's workload set: 5 Table-3 DNNs + HPCG-{S,M,L}."""
    return pack_workloads([LayerStack.from_network(n)
                           for n in NETWORKS.values()], tuple(HPCG.values()))


@lru_cache(maxsize=None)
def modern_pack(archs: Tuple[str, ...] = MODERN_COHORT,
                seq_len: int = 4096) -> WorkloadPack:
    """Modern ``configs/`` models lowered through the LayerStack adapter."""
    from repro.configs import get_config
    return pack_workloads([LayerStack.from_config(get_config(a), seq_len)
                           for a in archs])


# ---------------------------------------------------------------------------
# The batched engine
# ---------------------------------------------------------------------------


@jax.jit
def _traffic_jit(red, hpc_rw, hpc_mask, batches, t):
    """(W,) reductions + (NB,) batch grid -> (W, 2, NB) traffic arrays."""
    s_ain = t["k_im2col"] * red["a_conv"] + red["a_fc"]       # (W,)
    s_w = red["w_conv"] + t["fc_w_factor"] * red["w_fc"]
    ain, sw = s_ain[:, None], s_w[:, None]
    sin, sout = red["s_in"][:, None], red["s_out"][:, None]
    b = batches[None, :]                                       # (1, NB)
    inf_r = (b * ain + sw * (1.0 + b / t["w_tile"])) / LINE_BYTES
    inf_w = (b * sout) / LINE_BYTES
    trn_r = (2.0 * b * ain + b * sout
             + sw * (2.0 + b / t["grad_tile"])) / LINE_BYTES
    trn_w = (b * (sin + sout)
             + sw * (1.0 + b / (2.0 * t["grad_tile"]))) / LINE_BYTES
    reads = jnp.stack([inf_r, trn_r], axis=1)                  # (W, 2, NB)
    writes = jnp.stack([inf_w, trn_w], axis=1)
    hm = hpc_mask[:, None, None]
    reads = jnp.where(hm, hpc_rw[:, 0][:, None, None], reads)
    writes = jnp.where(hm, hpc_rw[:, 1][:, None, None], writes)
    frac = jnp.stack([jnp.broadcast_to(t["dram_frac_i"], b.shape),
                      jnp.broadcast_to(t["dram_frac_t"], b.shape)], axis=1)
    frac = jnp.where(hm, t["dram_frac_i"], frac)               # (W, 2, NB)
    dram = (reads + writes) * frac
    return reads, writes, dram


@dataclasses.dataclass(frozen=True)
class TrafficTensor:
    """One batched engine evaluation: (workload × mode × batch) arrays."""
    names: Tuple[str, ...]
    batches: Tuple[float, ...]
    reads: np.ndarray                # (W, 2, NB)
    writes: np.ndarray
    dram: np.ndarray
    hpc: Tuple[bool, ...]

    def _loc(self, name: str, mode: str, batch) -> Tuple[int, int, int]:
        if name not in self.names:
            raise ValueError(f"{name!r} not in this tensor ({self.names})")
        wi = self.names.index(name)
        if self.hpc[wi]:
            # same guard as profiles.profile(): hpc rows are mode/batch-
            # independent, so anything else asks for a mislabeled profile
            if mode != "hpc" or int(batch) != 1:
                raise ValueError(
                    f"{name} is an HPC workload: requires mode='hpc' and "
                    f"batch=1, got mode={mode!r}, batch={batch}")
            mi, bi = 0, 0
        else:
            mi = 1 if mode == "training" else 0
            if float(batch) not in self.batches:
                raise ValueError(f"batch {batch} not in this tensor "
                                 f"(has {self.batches})")
            bi = self.batches.index(float(batch))
        return wi, mi, bi

    def profile(self, name: str, mode: str, batch: int) -> MemoryProfile:
        """``MemoryProfile`` view of one (workload, mode, batch) cell."""
        wi, mi, bi = self._loc(name, mode, batch)
        return MemoryProfile(name, mode, batch,
                             float(self.reads[wi, mi, bi]),
                             float(self.writes[wi, mi, bi]),
                             float(self.dram[wi, mi, bi]))


def _t_arrays(t: Optional[Dict]) -> Dict[str, jnp.ndarray]:
    if t is None:
        # frozen knobs: cache the device dict, keyed on the current values
        # so in-place TRAFFIC edits are picked up
        return _frozen_t_arrays(tuple(TRAFFIC.items()))
    return {k: jnp.asarray(v, jnp.float32) for k, v in t.items()}


@lru_cache(maxsize=8)
def _frozen_t_arrays(items) -> Dict[str, jnp.ndarray]:
    return {k: jnp.asarray(v, jnp.float32) for k, v in items}


@lru_cache(maxsize=32)
def _pack_device_arrays(pack: WorkloadPack):
    """Per-pack device-resident engine inputs (packs hash by identity and
    the pack builders are themselves cached, so this stays warm)."""
    red = {k: jnp.asarray(v, jnp.float32) for k, v in pack.reduced.items()}
    hpc_rw = jnp.asarray(np.stack([pack.hpc_reads, pack.hpc_writes], 1),
                         jnp.float32)
    return red, hpc_rw, jnp.asarray(pack.hpc_mask)


@lru_cache(maxsize=64)
def _batch_array(grid: Tuple[float, ...]) -> jnp.ndarray:
    return jnp.asarray(grid, jnp.float32)


def compute_traffic(pack: WorkloadPack, batches: Sequence[float],
                    t: Optional[Dict] = None) -> TrafficTensor:
    """Evaluate the full (workload × mode × batch-grid) traffic tensor in
    one jitted call.  ``t`` defaults to the frozen TRAFFIC knobs; passing a
    dict of scalars (or tracers) keeps the call differentiable."""
    grid = tuple(float(b) for b in batches)
    red, hpc_rw, hpc_mask = _pack_device_arrays(pack)
    out = _traffic_jit(red, hpc_rw, hpc_mask, _batch_array(grid),
                       _t_arrays(t))
    reads, writes, dram = jax.device_get(out)
    return TrafficTensor(pack.names, grid, reads, writes, dram,
                         tuple(bool(x) for x in pack.hpc_mask))


def modern_profiles(archs: Sequence[str] = MODERN_COHORT,
                    inference_batch: int = 4, training_batch: int = 64,
                    seq_len: int = 4096) -> List[MemoryProfile]:
    """Fig-3-style {I, T} profile rows for the modern-config cohort —
    one batched evaluation, same pipeline as ``paper_profiles()``."""
    pack = modern_pack(tuple(archs), seq_len)
    batches = tuple(dict.fromkeys((float(inference_batch),
                                   float(training_batch))))
    tt = compute_traffic(pack, batches)
    out: List[MemoryProfile] = []
    for name in pack.names:
        out.append(tt.profile(name, "inference", inference_batch))
        out.append(tt.profile(name, "training", training_batch))
    return out


# ---------------------------------------------------------------------------
# Differentiable §4 claim loss (tools/calibrate_traffic.py)
# ---------------------------------------------------------------------------

# (claim key, paper target) — the 13 quantitative §4 claims; see the
# calibration tool's docstring for the sentence each number comes from.
CLAIM_TARGETS = (
    ("dyn_stt", 2.2), ("dyn_sot", 1.3),
    ("leak_stt", 6.3), ("leak_sot", 10.0),
    ("tot_stt", 5.3), ("tot_sot", 8.6),
    ("edp_stt", 3.8), ("edp_sot", 4.7),
    ("ia_edp_stt", 2.0), ("ia_edp_sot", 2.3),
    ("ia_nodram_stt", 1.2),
    ("fig6_lo", 2.3), ("fig6_hi", 4.6),
)


def make_claim_loss(inference_batch: int = 4, training_batch: int = 64):
    """Build the differentiable claim pipeline over the traffic engine.

    Returns ``(loss_fn, claims_fn)``: ``loss_fn(t)`` is the mean
    |log(pred/target)| over the 13 §4 claims plus 0.5× the Fig-3 R/W
    range penalty, traceable/jittable/gradable in the six TRAFFIC knobs;
    ``claims_fn(t)`` returns ``({key: (pred, target)}, penalty)`` for
    reporting.  Cache PPA configurations are technology constants — they
    do not depend on the traffic knobs — so they are baked in as arrays
    and the whole traffic → PPA → energy/EDP pipeline is one jittable
    function of ``t``.
    """
    from repro.core import energy as en
    from repro.core.dram import dram_scale
    from repro.core.sweep import iso_area_search
    from repro.core.tuner import iso_capacity_configs

    cfgs = iso_capacity_configs(3.0)
    nvm = iso_area_search(("STT", "SOT"), cfgs["SRAM"].area_mm2)
    ia_scale = {m: dram_scale(nvm[m].capacity_mb, 3.0) for m in nvm}
    ppa3 = {m: en.ppa_scalars(cfgs[m]) for m in cfgs}
    ppa_ia = {m: en.ppa_scalars(nvm[m]) for m in nvm}

    pack = paper_pack()
    red, hpc_rw, hpc_mask = _pack_device_arrays(pack)
    batches = jnp.asarray([float(inference_batch), float(training_batch),
                           128.0], jnp.float32)
    dl = [i for i, h in enumerate(pack.hpc_mask) if not h]
    hpc = [i for i, h in enumerate(pack.hpc_mask) if h]
    alex = pack.index("AlexNet")
    n_dl = len(dl)

    def _profiles(t):
        """(reads, writes, dram) in paper_profiles() order: per-net I then
        T, then HPCG — shapes (2·n_dl + n_hpc,)."""
        reads, writes, dram = _traffic_jit(red, hpc_rw, hpc_mask, batches, t)
        rows = []
        for i in dl:
            rows.append((reads[i, 0, 0], writes[i, 0, 0], dram[i, 0, 0]))
            rows.append((reads[i, 1, 1], writes[i, 1, 1], dram[i, 1, 1]))
        for i in hpc:
            rows.append((reads[i, 0, 0], writes[i, 0, 0], dram[i, 0, 0]))
        r, w, d = (jnp.stack(x) for x in zip(*rows))
        fig6 = (reads[alex, 1, 0], writes[alex, 1, 0], dram[alex, 1, 0],
                reads[alex, 1, 2], writes[alex, 1, 2], dram[alex, 1, 2])
        return r, w, d, fig6

    def claims(t):
        r, w, d, fig6 = _profiles(t)
        dl_sl = slice(0, 2 * n_dl)
        base = en.evaluate_arrays(r, w, d, ppa3["SRAM"])
        rel = {m: en.relative_arrays(base,
                                     en.evaluate_arrays(r, w, d, ppa3[m]))
               for m in ("STT", "SOT")}
        ia = {m: en.relative_arrays(
            base, en.evaluate_arrays(r, w, d * ia_scale[m], ppa_ia[m]))
            for m in ("STT", "SOT")}
        out = {
            "dyn_stt": jnp.mean(rel["STT"]["dynamic"][dl_sl]),
            "dyn_sot": jnp.mean(rel["SOT"]["dynamic"][dl_sl]),
            "leak_stt": 1.0 / jnp.mean(rel["STT"]["leakage"][dl_sl]),
            "leak_sot": 1.0 / jnp.mean(rel["SOT"]["leakage"][dl_sl]),
            "tot_stt": 1.0 / jnp.mean(rel["STT"]["total"][dl_sl]),
            "tot_sot": 1.0 / jnp.mean(rel["SOT"]["total"][dl_sl]),
            "edp_stt": 1.0 / jnp.min(rel["STT"]["edp_with_dram"]),
            "edp_sot": 1.0 / jnp.min(rel["SOT"]["edp_with_dram"]),
            "ia_edp_stt": 1.0 / jnp.mean(ia["STT"]["edp_with_dram"]),
            "ia_edp_sot": 1.0 / jnp.mean(ia["SOT"]["edp_with_dram"]),
            "ia_nodram_stt": 1.0 / jnp.mean(ia["STT"]["edp"]),
        }
        for key, (ri, wi, di) in (("fig6_lo", fig6[0:3]),
                                  ("fig6_hi", fig6[3:6])):
            b6 = en.evaluate_arrays(ri, wi, di, ppa3["SRAM"])
            s6 = en.evaluate_arrays(ri, wi, di, ppa3["STT"])
            out[key] = 1.0 / en.relative_arrays(b6, s6)["edp_with_dram"]
        rw = r / jnp.maximum(w, 1.0)
        pen = (jnp.sum(jax.nn.relu(rw / 26.0 - 1.0))
               + jnp.sum(jax.nn.relu(1.5 / jnp.maximum(rw, 0.1) - 1.0)))
        return out, pen

    def loss_fn(t):
        preds, pen = claims(t)
        errs = jnp.stack([jnp.abs(jnp.log(preds[k] / tgt))
                          for k, tgt in CLAIM_TARGETS])
        return jnp.mean(errs) + 0.5 * pen

    def claims_fn(t):
        preds, pen = claims(_t_arrays(t))
        return ({k: (float(preds[k]), tgt) for k, tgt in CLAIM_TARGETS},
                float(pen))

    return loss_fn, claims_fn
