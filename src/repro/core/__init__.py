"""DeepNVM++ core: cross-layer NVM cache modeling for DL workloads."""
from repro.core.bitcell import SOT, SRAM, STT, TABLE1, Bitcell
from repro.core.cache_model import CachePPA, evaluate_batch, evaluate_config
from repro.core.sweep import SweepResult, iso_area_search, sweep
from repro.core.traffic import (LayerStack, MemoryProfile, TrafficTensor,
                                WorkloadPack, compute_traffic,
                                modern_profiles, pack_workloads)
from repro.core.tuner import tune, tune_all

__all__ = ["SOT", "SRAM", "STT", "TABLE1", "Bitcell", "CachePPA",
           "LayerStack", "MemoryProfile", "SweepResult", "TrafficTensor",
           "WorkloadPack", "compute_traffic", "evaluate_batch",
           "evaluate_config", "iso_area_search", "modern_profiles",
           "pack_workloads", "sweep", "tune", "tune_all"]
