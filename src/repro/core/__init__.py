"""DeepNVM++ core: cross-layer NVM cache modeling for DL workloads."""
from repro.core.bitcell import SOT, SRAM, STT, TABLE1, Bitcell
from repro.core.cache_model import CachePPA, evaluate_config
from repro.core.tuner import tune, tune_all

__all__ = ["SOT", "SRAM", "STT", "TABLE1", "Bitcell", "CachePPA",
           "evaluate_config", "tune", "tune_all"]
