"""DNN workload descriptors (paper Table 3) + HPCG.

Per-layer configurations of AlexNet, GoogLeNet, VGG-16, ResNet-18 and
SqueezeNet for ImageNet (224x224). Tests validate total weights / MACs
against Table 3 (61M/724M, 7M/1.43G, 138M/15.5G, 11.8M/2G, 1.2M/837M).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple


@dataclasses.dataclass(frozen=True)
class Layer:
    name: str
    kind: str          # conv | fc
    in_ch: int
    out_ch: int
    k: int = 1
    stride: int = 1
    in_hw: int = 0     # input spatial size (square)
    groups: int = 1
    pad: int = -1      # -1 -> 'same-ish' (k//2)

    @property
    def out_hw(self) -> int:
        if self.kind == "fc":
            return 1
        p = self.k // 2 if self.pad < 0 else self.pad
        return (self.in_hw + 2 * p - self.k) // self.stride + 1

    @property
    def weights(self) -> int:
        if self.kind == "fc":
            return self.in_ch * self.out_ch
        return (self.in_ch // self.groups) * self.out_ch * self.k * self.k

    @property
    def macs(self) -> int:
        if self.kind == "fc":
            return self.in_ch * self.out_ch
        return self.weights * self.out_hw * self.out_hw

    @property
    def in_bytes(self) -> int:   # fp32 activations
        if self.kind == "fc":
            return self.in_ch * 4
        return self.in_ch * self.in_hw * self.in_hw * 4

    @property
    def out_bytes(self) -> int:
        if self.kind == "fc":
            return self.out_ch * 4
        return self.out_ch * self.out_hw * self.out_hw * 4

    @property
    def weight_bytes(self) -> int:
        return self.weights * 4


@dataclasses.dataclass(frozen=True)
class Network:
    name: str
    layers: Tuple[Layer, ...]
    top5_error: float

    @property
    def total_weights(self) -> int:
        return sum(l.weights for l in self.layers)

    @property
    def total_macs(self) -> int:
        return sum(l.macs for l in self.layers)

    @property
    def conv_layers(self) -> int:
        return sum(1 for l in self.layers if l.kind == "conv")

    @property
    def fc_layers(self) -> int:
        return sum(1 for l in self.layers if l.kind == "fc")


def _conv(name, in_ch, out_ch, k, s, hw, groups=1, pad=-1):
    return Layer(name, "conv", in_ch, out_ch, k, s, hw, groups, pad)


def _fc(name, i, o):
    return Layer(name, "fc", i, o)


# --- AlexNet ----------------------------------------------------------------

ALEXNET = Network("AlexNet", (
    _conv("conv1", 3, 96, 11, 4, 224, pad=2),     # 55
    _conv("conv2", 96, 256, 5, 1, 27, groups=2),
    _conv("conv3", 256, 384, 3, 1, 13),
    _conv("conv4", 384, 384, 3, 1, 13, groups=2),
    _conv("conv5", 384, 256, 3, 1, 13, groups=2),
    _fc("fc6", 9216, 4096),
    _fc("fc7", 4096, 4096),
    _fc("fc8", 4096, 1000),
), top5_error=16.4)


# --- VGG-16 -----------------------------------------------------------------

def _vgg():
    cfg = [(64, 224), (64, 224), (128, 112), (128, 112),
           (256, 56), (256, 56), (256, 56),
           (512, 28), (512, 28), (512, 28),
           (512, 14), (512, 14), (512, 14)]
    layers: List[Layer] = []
    in_ch = 3
    for i, (c, hw) in enumerate(cfg):
        layers.append(_conv(f"conv{i+1}", in_ch, c, 3, 1, hw))
        in_ch = c
    layers += [_fc("fc1", 25088, 4096), _fc("fc2", 4096, 4096),
               _fc("fc3", 4096, 1000)]
    return Network("VGG-16", tuple(layers), top5_error=7.3)


VGG16 = _vgg()


# --- ResNet-18 ---------------------------------------------------------------

def _resnet18():
    layers = [_conv("conv1", 3, 64, 7, 2, 224, pad=3)]
    hw = 56
    in_ch = 64
    stage_cfg = [(64, 2, 1), (128, 2, 2), (256, 2, 2), (512, 2, 2)]
    for c, blocks, first_stride in stage_cfg:
        for b in range(blocks):
            s = first_stride if b == 0 else 1
            layers.append(_conv(f"s{c}b{b}c1", in_ch, c, 3, s, hw))
            hw_out = layers[-1].out_hw
            layers.append(_conv(f"s{c}b{b}c2", c, c, 3, 1, hw_out))
            if b == 0 and (s != 1 or in_ch != c):
                layers.append(_conv(f"s{c}b{b}ds", in_ch, c, 1, s, hw, pad=0))
            in_ch = c
            hw = hw_out
    layers.append(_fc("fc", 512, 1000))
    return Network("ResNet-18", tuple(layers), top5_error=10.71)


RESNET18 = _resnet18()


# --- GoogLeNet ---------------------------------------------------------------

def _inception(name, hw, in_ch, c1, c3r, c3, c5r, c5, pp):
    return [
        _conv(f"{name}.1x1", in_ch, c1, 1, 1, hw, pad=0),
        _conv(f"{name}.3x3r", in_ch, c3r, 1, 1, hw, pad=0),
        _conv(f"{name}.3x3", c3r, c3, 3, 1, hw),
        _conv(f"{name}.5x5r", in_ch, c5r, 1, 1, hw, pad=0),
        _conv(f"{name}.5x5", c5r, c5, 5, 1, hw),
        _conv(f"{name}.pool", in_ch, pp, 1, 1, hw, pad=0),
    ]


def _googlenet():
    layers = [
        _conv("conv1", 3, 64, 7, 2, 224, pad=3),
        _conv("conv2r", 64, 64, 1, 1, 56, pad=0),
        _conv("conv2", 64, 192, 3, 1, 56),
    ]
    inc = [
        ("3a", 28, 192, 64, 96, 128, 16, 32, 32),
        ("3b", 28, 256, 128, 128, 192, 32, 96, 64),
        ("4a", 14, 480, 192, 96, 208, 16, 48, 64),
        ("4b", 14, 512, 160, 112, 224, 24, 64, 64),
        ("4c", 14, 512, 128, 128, 256, 24, 64, 64),
        ("4d", 14, 512, 112, 144, 288, 32, 64, 64),
        ("4e", 14, 528, 256, 160, 320, 32, 128, 128),
        ("5a", 7, 832, 256, 160, 320, 32, 128, 128),
        ("5b", 7, 832, 384, 192, 384, 48, 128, 128),
    ]
    for args in inc:
        layers += _inception(*args)
    layers.append(_fc("fc", 1024, 1000))
    return Network("GoogLeNet", tuple(layers), top5_error=6.7)


GOOGLENET = _googlenet()


# --- SqueezeNet (v1.0) --------------------------------------------------------

def _fire(name, hw, in_ch, sq, e1, e3):
    return [
        _conv(f"{name}.sq", in_ch, sq, 1, 1, hw, pad=0),
        _conv(f"{name}.e1", sq, e1, 1, 1, hw, pad=0),
        _conv(f"{name}.e3", sq, e3, 3, 1, hw),
    ]


def _squeezenet():
    layers = [_conv("conv1", 3, 96, 7, 2, 224, pad=0)]  # 109 -> pool 54
    fires = [
        ("f2", 54, 96, 16, 64, 64), ("f3", 54, 128, 16, 64, 64),
        ("f4", 54, 128, 32, 128, 128), ("f5", 27, 256, 32, 128, 128),
        ("f6", 27, 256, 48, 192, 192), ("f7", 27, 384, 48, 192, 192),
        ("f8", 27, 384, 64, 256, 256), ("f9", 13, 512, 64, 256, 256),
    ]
    for args in fires:
        layers += _fire(*args)
    layers.append(_conv("conv10", 512, 1000, 1, 1, 13, pad=0))
    return Network("SqueezeNet", tuple(layers), top5_error=16.4)


SQUEEZENET = _squeezenet()

NETWORKS = {n.name: n for n in
            (ALEXNET, GOOGLENET, VGG16, RESNET18, SQUEEZENET)}


# --- HPCG (non-DL HPC workload; paper Fig 3) --------------------------------
# 27-point stencil SpMV dominates: reads ~ 27 matrix entries + vector per
# row, one vector write per row. R/W rises with grid size as the working
# set exceeds cache (less vector reuse). Counts are per CG iteration x 50.


@dataclasses.dataclass(frozen=True)
class HPCGWorkload:
    name: str
    grid: int          # local subgrid dimension (n -> n^3 rows)
    rw_ratio: float    # measured-range read/write transaction ratio (Fig 3)

    @property
    def rows(self) -> int:
        return self.grid ** 3

    def transactions(self, iters: int = 50) -> Tuple[float, float]:
        """(reads, writes) L2 transactions per run."""
        values_per_line = 16          # 128B line / 8B double
        writes = self.rows * iters / values_per_line
        return writes * self.rw_ratio, writes


HPCG_S = HPCGWorkload("HPCG-S", 8, 2.3)
HPCG_M = HPCGWorkload("HPCG-M", 32, 12.0)
HPCG_L = HPCGWorkload("HPCG-L", 128, 26.0)
HPCG = {w.name: w for w in (HPCG_S, HPCG_M, HPCG_L)}
