"""Trace-driven cache simulation driver (GPGPU-Sim replacement, §3.4).

Generates synthetic L2 access traces with power-law reuse distances (the
empirically observed GPU locality shape) and runs them through the
set-associative LRU simulator at several capacities, producing the
DRAM-access-reduction curve that cross-validates the analytical miss
model (core/dram.py).

Two simulation paths (DESIGN.md §3):

- ``simulate_ladder`` — the batched engine: one Pallas launch
  (repro.kernels.cache_sim.cache_sim_ladder) evaluates every
  (workload trace x capacity rung) pair, returning a (W, L, 2)
  [hits, misses] tensor. The default rung sequence is the same
  half-octave ladder the iso-area search sweeps
  (``repro.core.sweep.capacity_ladder``).
- ``simulate_reference`` — the seed per-point path (one kernel launch
  per capacity), retained as the bit-exact parity baseline; the engine
  must reproduce its counts exactly (tests/test_cachesim.py).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.constants import GPU_L2_MB, LINE_BYTES, MB
from repro.core.dram import reduction_pct_from_misses
from repro.core.sweep import capacity_ladder

#: Documented analytic-vs-trace validation tolerance: the simulated Fig-7
#: DRAM-access reduction must sit within this many percentage points of
#: the power-law model's prediction on zipf traffic (DESIGN.md §3).
ANALYTIC_TOL_PCT = 6.0


def synthetic_trace(n: int, footprint_lines: int, *, theta: float = 1.186,
                    seed: int = 0) -> np.ndarray:
    """Independent-reference zipf(theta) line trace.

    Under Che's approximation an LRU cache of C lines misses on the tail
    P(rank > C) ~ C^(1 - theta); theta = 1.186 matches the paper-fitted
    power-law miss exponent alpha = 0.186 (core/dram.py) by construction —
    the simulator then *validates* that a 16-way set-associative cache
    actually behaves like the analytical model on such traffic.
    """
    rng = np.random.RandomState(seed)
    ranks = rng.zipf(theta, size=n) % footprint_lines
    # decorrelate rank -> line id so popular lines spread across sets
    return ((ranks * 2654435761) % footprint_lines).astype(np.int64)


def synthetic_traces(n: int, footprint_lines: int, *,
                     seeds: Sequence[int] = (0,),
                     theta: float = 1.186) -> np.ndarray:
    """Stack of workload traces, one per seed: (len(seeds), n)."""
    return np.stack([synthetic_trace(n, footprint_lines, theta=theta,
                                     seed=s) for s in seeds])


def capacity_lines(capacity_mb: float, *, scale: int = 1) -> int:
    """Cache capacity in lines at 1:``scale`` (power-law traffic is
    scale-free, so miss *ratios* are preserved under scaling)."""
    return int(capacity_mb * MB) // (LINE_BYTES * scale)


def largest_divisor_tile(num_sets: int, sets_tile: int) -> int:
    """Largest set-tile <= ``sets_tile`` that divides ``num_sets``.

    The per-point kernel requires ``num_sets % tile == 0``; the seed's
    halving loop (``while num_sets % tile: tile //= 2``) degenerated to
    tile=1 for any odd set count.
    """
    for tile in range(min(int(sets_tile), int(num_sets)), 0, -1):
        if num_sets % tile == 0:
            return tile
    return 1


def _ladder_sets(capacities_mb: Sequence[float], *, scale: int,
                 ways: int) -> Tuple[int, ...]:
    return tuple(max(1, capacity_lines(c, scale=scale) // ways)
                 for c in capacities_mb)


def simulate_reference(trace: np.ndarray, cap_lines: int, *,
                       ways: int = 16, use_kernel: bool = True,
                       sets_tile: int = 64) -> Tuple[int, int]:
    """(hits, misses) of one trace against one LRU cache size.

    Seed per-point path: set ids / tags precomputed on the host, one
    kernel launch per capacity. Retained as the parity baseline for
    ``simulate_ladder`` (DESIGN.md §3).
    """
    num_sets = max(1, cap_lines // ways)
    set_ids = (trace % num_sets).astype(np.int32)
    tags = (trace // num_sets).astype(np.int32)
    if use_kernel:
        import jax.numpy as jnp

        from repro.kernels.ops import cache_sim
        tile = largest_divisor_tile(num_sets, sets_tile)
        h, m = cache_sim(jnp.asarray(set_ids), jnp.asarray(tags),
                         num_sets=num_sets, ways=ways, sets_tile=tile)
        return int(h), int(m)
    from repro.kernels.ref import cache_sim_python
    return cache_sim_python(set_ids, tags, num_sets=num_sets, ways=ways)


# seed-era name, kept for callers of the per-point API
simulate_capacity_lines = simulate_reference


def simulate_capacity(trace: np.ndarray, capacity_mb: float, *,
                      scale: int = 1, ways: int = 16,
                      use_kernel: bool = True,
                      sets_tile: int = 64) -> Tuple[int, int]:
    return simulate_reference(trace, capacity_lines(capacity_mb, scale=scale),
                              ways=ways, use_kernel=use_kernel,
                              sets_tile=sets_tile)


def simulate_ladder(traces: np.ndarray,
                    capacities_mb: Optional[Sequence[float]] = None, *,
                    scale: int = 1, ways: int = 16, sets_tile: int = 2048,
                    use_kernel: bool = True,
                    interpret: Optional[bool] = None) -> np.ndarray:
    """Batched trace-driven sweep: (workloads x capacity ladder) in one call.

    ``traces`` is (W, T) line ids (a single (T,) trace is promoted);
    ``capacities_mb`` defaults to the iso-area search ladder
    (``sweep.capacity_ladder()``). Returns an (W, L, 2) int64 tensor of
    [hits, misses] counts, bit-exact with ``simulate_reference`` per point.
    """
    caps = tuple(capacities_mb if capacities_mb is not None
                 else capacity_ladder())
    traces = np.atleast_2d(np.asarray(traces))
    if traces.size and (traces.min() < 0 or traces.max() >= 2 ** 31):
        # the kernel runs in int32; a wrapped-negative id would make
        # tag == -1 collide with the EMPTY sentinel and fake cold hits
        raise ValueError(
            "trace line ids must fit int32 (0 <= id < 2**31); got range "
            f"[{traces.min()}, {traces.max()}]")
    ladder = _ladder_sets(caps, scale=scale, ways=ways)
    if use_kernel:
        import jax.numpy as jnp

        from repro.kernels.ops import cache_sim_ladder
        counts = cache_sim_ladder(jnp.asarray(traces, jnp.int32),
                                  num_sets=ladder, ways=ways,
                                  sets_tile=sets_tile, interpret=interpret)
        return np.asarray(counts, np.int64)
    from repro.kernels.ref import cache_sim_ladder_numpy
    return cache_sim_ladder_numpy(traces, ladder, ways=ways)


def dram_reduction_curve(capacities_mb: Sequence[float] = (3, 6, 12, 24),
                         *, trace_len: int = 400_000, scale: int = 32,
                         footprint_mb: float = 256.0, ways: int = 16,
                         use_kernel: bool = True,
                         seed: int = 0) -> Dict[float, float]:
    """Simulated Fig-7 analogue: % DRAM (miss) reduction vs the first
    capacity, from one whole-ladder batch.

    Runs at 1:``scale`` capacity scale (power-law traffic is scale-free, so
    reduction percentages are preserved) to keep trace lengths tractable.
    """
    trace = synthetic_trace(
        trace_len, int(footprint_mb * MB) // (LINE_BYTES * scale), seed=seed)
    counts = simulate_ladder(trace, capacities_mb, scale=scale, ways=ways,
                             use_kernel=use_kernel)
    miss = counts[0, :, 1].astype(float)
    return {c: reduction_pct_from_misses(m, miss[0])
            for c, m in zip(capacities_mb, miss)}


def trace_dram_scale(capacities_mb: Sequence[float],
                     base_mb: float = GPU_L2_MB, *,
                     trace_len: int = 120_000, scale: int = 32,
                     footprint_mb: float = 256.0, ways: int = 16,
                     seed: int = 0,
                     use_kernel: bool = True) -> Dict[float, float]:
    """Trace-driven DRAM-transaction multipliers vs ``base_mb``.

    The simulator-backed drop-in for ``core.dram.dram_scale``: one batched
    ladder run over {base} | {capacities} yields miss(C) / miss(base) for
    every requested capacity — this is what ``core.iso.iso_area`` consumes
    in ``dram_model="trace"`` mode.
    """
    caps = (float(base_mb),) + tuple(float(c) for c in capacities_mb
                                     if float(c) != float(base_mb))
    trace = synthetic_trace(
        trace_len, int(footprint_mb * MB) // (LINE_BYTES * scale), seed=seed)
    counts = simulate_ladder(trace, caps, scale=scale, ways=ways,
                             use_kernel=use_kernel)
    miss = counts[0, :, 1].astype(float)
    scales = {c: m / miss[0] for c, m in zip(caps, miss)}
    return {float(c): scales[float(c)] for c in capacities_mb}
