"""Trace-driven cache simulation driver (GPGPU-Sim replacement, §3.4).

Generates synthetic L2 access traces with power-law reuse distances (the
empirically observed GPU locality shape) and runs them through the
set-associative LRU simulator (Pallas kernel repro.kernels.cache_sim /
jnp oracle) at several capacities, producing the DRAM-access-reduction
curve that cross-validates the analytical miss model (core/dram.py).
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.constants import LINE_BYTES, MB


def synthetic_trace(n: int, footprint_lines: int, *, theta: float = 1.186,
                    seed: int = 0) -> np.ndarray:
    """Independent-reference zipf(theta) line trace.

    Under Che's approximation an LRU cache of C lines misses on the tail
    P(rank > C) ~ C^(1 - theta); theta = 1.186 matches the paper-fitted
    power-law miss exponent alpha = 0.186 (core/dram.py) by construction —
    the simulator then *validates* that a 16-way set-associative cache
    actually behaves like the analytical model on such traffic.
    """
    rng = np.random.RandomState(seed)
    ranks = rng.zipf(theta, size=n) % footprint_lines
    # decorrelate rank -> line id so popular lines spread across sets
    return ((ranks * 2654435761) % footprint_lines).astype(np.int64)


def simulate_capacity_lines(trace: np.ndarray, capacity_lines: int, *,
                            ways: int = 16, use_kernel: bool = True,
                            sets_tile: int = 64) -> Tuple[int, int]:
    """(hits, misses) of the trace against an LRU cache of given size."""
    num_sets = max(1, capacity_lines // ways)
    set_ids = (trace % num_sets).astype(np.int32)
    tags = (trace // num_sets).astype(np.int32)
    if use_kernel:
        import jax.numpy as jnp

        from repro.kernels.ops import cache_sim
        tile = min(sets_tile, num_sets)
        while num_sets % tile:
            tile //= 2
        h, m = cache_sim(jnp.asarray(set_ids), jnp.asarray(tags),
                         num_sets=num_sets, ways=ways, sets_tile=tile)
        return int(h), int(m)
    from repro.kernels.ref import cache_sim_python
    return cache_sim_python(set_ids, tags, num_sets=num_sets, ways=ways)


def simulate_capacity(trace: np.ndarray, capacity_mb: float, *,
                      scale: int = 1, ways: int = 16,
                      use_kernel: bool = True,
                      sets_tile: int = 64) -> Tuple[int, int]:
    lines = int(capacity_mb * MB) // (LINE_BYTES * scale)
    return simulate_capacity_lines(trace, lines, ways=ways,
                                   use_kernel=use_kernel,
                                   sets_tile=sets_tile)


def dram_reduction_curve(capacities_mb: Sequence[float] = (3, 6, 12, 24),
                         *, trace_len: int = 400_000, scale: int = 32,
                         footprint_mb: float = 256.0, ways: int = 16,
                         use_kernel: bool = False,
                         seed: int = 0) -> Dict[float, float]:
    """Simulated Fig-7 analogue: % DRAM (miss) reduction vs the 3MB base.

    Runs at 1:``scale`` capacity scale (power-law traffic is scale-free, so
    reduction percentages are preserved) to keep trace lengths tractable.
    """
    trace = synthetic_trace(
        trace_len, int(footprint_mb * MB) // (LINE_BYTES * scale), seed=seed)
    base = None
    out: Dict[float, float] = {}
    for c in capacities_mb:
        _, miss = simulate_capacity(trace, c, scale=scale, ways=ways,
                                    use_kernel=use_kernel)
        if base is None:
            base = miss
        out[c] = 100.0 * (1.0 - miss / base)
    return out
