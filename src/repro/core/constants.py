"""Shared constants for the DeepNVM++ reproduction.

GPU-mode constants model the paper's platform (NVIDIA GTX 1080 Ti, 16nm,
3 MB L2, 128 B lines, GDDR5X). TPU-mode constants (crosslayer) model a
v5e-class chip where the "LLC" is an on-chip SRAM tier and "DRAM" is HBM.
"""

# --- cache geometry --------------------------------------------------------
LINE_BYTES = 128                     # L2 line == one transaction
MB = 1 << 20

# --- paper platform (GTX 1080 Ti) -----------------------------------------
GPU_L2_MB = 3
GPU_CLOCK_GHZ = 1.481                # core/L2 clock
GPU_MEM_CLOCK_GHZ = 2.750

# --- DRAM (GDDR5X-class) ---------------------------------------------------
# Energy per 128B DRAM transaction. ~20 pJ/bit access+IO at GDDR5X-class
# interfaces -> 128 * 8 * 20 pJ ~= 20 nJ; latency ~ a few hundred core cycles.
DRAM_ENERGY_NJ = 20.0
DRAM_LATENCY_NS = 180.0
DRAM_IDLE_POWER_MW = 0.0             # background power folded into GPU board

# --- iso-area / miss model -------------------------------------------------
# Power-law miss exponent: solves Fig 7's (7MB, 14.6%) and (10MB, 19.8%)
# DRAM-access reductions from the 3MB baseline (see core/dram.py).
MISS_ALPHA = 0.186

# --- TPU v5e-class (crosslayer mode) ---------------------------------------
TPU_PEAK_FLOPS = 197e12
TPU_HBM_BW = 819e9
TPU_HBM_ENERGY_NJ_PER_128B = 128 * 8 * 0.004   # ~4 pJ/bit HBM2e-class
TPU_SRAM_TIER_MB = 128               # modeled on-chip last-level SRAM tier
TPU_CLOCK_GHZ = 0.94
