"""Batched design-space sweep engine (DESIGN.md §9).

One jit-compiled call evaluates the full PPA tensor over
(memory x capacity x banks x rows x access-type) and runs the paper's
Algorithm 1 as a masked argmin over the grid axes — no Python loops, no
per-point ``CachePPA`` materialization.  This is the engine behind
``repro.core.tuner`` (which keeps the paper-shaped public API), the
iso-capacity/iso-area analyses, the scalability sweeps, and the
differentiable Table-2 calibration in ``tools/calibrate_cache.py``.

Layout conventions (fixed throughout):

    axis 0  M  memory technology        (order of ``mems``)
    axis 1  C  capacity in MB           (order of ``capacities_mb``)
    axis 2  B  bank count               (``cache_model.BANKS``)
    axis 3  R  subarray rows            (``cache_model.ROWS``)
    axis 4  A  access type              (``cache_model.ACCESS_TYPES``)

Algorithm 1 (tuning): for each optimization target in ``OPT_TARGETS``
crossed with each access type, the per-(B, R) argmin is a candidate; the
candidate minimizing EDAP wins.  Ties resolve to the first candidate in
(target-major, access-minor) order and the first (bank-major) grid point —
the exact iteration order of the legacy per-point loop, so selections are
identical to ``tuner.tune_reference``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitcell import TABLE1
from repro.core.cache_model import (ACCESS_TYPES, BANKS, CAL, CachePPA,
                                    PPA_METRICS, ROWS, cell_arrays,
                                    evaluate_batch)

# Algorithm 1's objective set O (paper §3.2); order = legacy iteration order.
OPT_TARGETS = (
    "read_latency", "write_latency", "read_energy", "write_energy",
    "read_edp", "write_edp", "area", "leakage",
)


def _edap(grid: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    e = 0.5 * (grid["read_energy_nj"] + grid["write_energy_nj"])
    d = 0.5 * (grid["read_latency_ns"] + grid["write_latency_ns"])
    return e * d * grid["area_mm2"]


def _objectives(grid: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Stack the Algorithm-1 objective tensors: (O, M, C, B, R, A)."""
    return jnp.stack([
        grid["read_latency_ns"],
        grid["write_latency_ns"],
        grid["read_energy_nj"],
        grid["write_energy_nj"],
        grid["read_energy_nj"] * grid["read_latency_ns"],
        grid["write_energy_nj"] * grid["write_latency_ns"],
        grid["area_mm2"],
        grid["leakage_mw"],
    ])


def _algorithm1(grid: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Masked-argmin Algorithm 1 over the grid axes.

    Returns (M, C) int32 flat indices into the (B, R, A) design space.
    """
    edap = _edap(grid)
    objs = _objectives(grid)
    o, m, c, b, r, a = objs.shape
    # line 9-10: per (target, access) candidate = argmin over (banks, rows)
    cand_br = jnp.argmin(objs.reshape(o, m, c, b * r, a), axis=3)  # (O,M,C,A)
    edap_flat = edap.reshape(m, c, b * r, a)
    cand_edap = jnp.take_along_axis(
        edap_flat[None], cand_br[:, :, :, None, :], axis=3)[:, :, :, 0, :]
    # lines 11-13: EDAP-best candidate, first win on ties in legacy
    # (target-major, access-minor) iteration order
    cand_br = jnp.moveaxis(cand_br, 0, 2).reshape(m, c, o * a)
    cand_edap = jnp.moveaxis(cand_edap, 0, 2).reshape(m, c, o * a)
    win = jnp.argmin(cand_edap, axis=2)                            # (M, C)
    br = jnp.take_along_axis(cand_br, win[:, :, None], axis=2)[:, :, 0]
    return (br * a + win % a).astype(jnp.int32)


@jax.jit
def _sweep_jit(cells, caps, cal):
    grid = evaluate_batch(cells, caps, cal)
    grid["edap"] = _edap(grid)
    idx = _algorithm1(grid)
    m, c = idx.shape
    flat_idx = idx[:, :, None]
    tuned = {k: jnp.take_along_axis(v.reshape(m, c, -1), flat_idx,
                                    axis=2)[:, :, 0]
             for k, v in grid.items()}
    return grid, idx, tuned


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Full PPA tensor + Algorithm-1 selections for one batched sweep.

    ``grid`` maps each metric (plus ``edap``) to an (M, C, B, R, A) array;
    ``tuned`` holds the same metrics gathered at the selected design point,
    shaped (M, C); ``sel`` is the (M, C) flat index into (B, R, A).
    """
    mems: Tuple[str, ...]
    capacities_mb: Tuple[float, ...]
    grid: Dict[str, np.ndarray]
    sel: np.ndarray
    tuned: Dict[str, np.ndarray]

    def _loc(self, mem: str, capacity_mb: float) -> Tuple[int, int]:
        if mem not in self.mems:
            raise ValueError(f"{mem!r} not in this sweep (has {self.mems})")
        if float(capacity_mb) not in self.capacities_mb:
            raise ValueError(f"{capacity_mb} MB not in this sweep (has "
                             f"{self.capacities_mb})")
        return self.mems.index(mem), self.capacities_mb.index(
            float(capacity_mb))

    def selection(self, mem: str, capacity_mb: float) -> Tuple[int, int, str]:
        """Selected (banks, rows, access_type) for one (mem, capacity)."""
        mi, ci = self._loc(mem, capacity_mb)
        bi, ri, ai = np.unravel_index(
            self.sel[mi, ci], (len(BANKS), len(ROWS), len(ACCESS_TYPES)))
        return BANKS[bi], ROWS[ri], ACCESS_TYPES[ai]

    def config(self, mem: str, capacity_mb: float) -> CachePPA:
        """EDAP-tuned ``CachePPA`` view of one (mem, capacity) cell."""
        mi, ci = self._loc(mem, capacity_mb)
        banks, rows, acc = self.selection(mem, capacity_mb)
        vals = {k: float(self.tuned[k][mi, ci]) for k in PPA_METRICS}
        return CachePPA(mem=mem, capacity_mb=float(capacity_mb), banks=banks,
                        rows=rows, access_type=acc, **vals)

    def configs(self) -> Dict[str, Dict[float, CachePPA]]:
        """{mem: {capacity: CachePPA}} over the whole sweep."""
        return {m: {c: self.config(m, c) for c in self.capacities_mb}
                for m in self.mems}


def sweep(mems: Sequence[str], capacities_mb: Sequence[float],
          cal: Optional[Dict] = None) -> SweepResult:
    """Evaluate + tune the full (mems x capacities) design space in one
    jitted call.  ``cal`` defaults to the frozen calibration constants."""
    mems = tuple(mems)
    caps = tuple(float(c) for c in capacities_mb)
    cal = {k: float(v) for k, v in (cal or CAL).items()}
    cells = cell_arrays([TABLE1[m] for m in mems])
    grid, idx, tuned = _sweep_jit(cells, jnp.asarray(caps, jnp.float32), cal)
    return SweepResult(
        mems=mems, capacities_mb=caps,
        grid={k: np.asarray(v) for k, v in grid.items()},
        sel=np.asarray(idx),
        tuned={k: np.asarray(v) for k, v in tuned.items()},
    )


# --- iso-area capacity search ----------------------------------------------


def capacity_ladder(start_mb: float = 0.5, max_mb: float = 64.0,
                    steps_per_octave: int = 2,
                    include: Sequence[float] = ()) -> Tuple[float, ...]:
    """Geometric capacity ladder; the default replicates the legacy
    half-octave search (0.5 MB .. 64 MB in x sqrt(2) steps).

    ``include`` splices extra capacities into the rung sequence (sorted,
    deduplicated) — e.g. the 3 MB GPU-L2 baseline, so the trace-driven
    ladder simulation (``core.cachesim.simulate_ladder``) covers both the
    iso-area search rungs and the normalization point in one batch.
    """
    caps = []
    k = 0
    while True:
        # direct exponentiation, not repeated multiplication: accumulated
        # error made 0.5 * sqrt(2)^14 > 64, silently dropping the top rung
        # (whole-octave rungs are now exactly round: 2.0 ** (k / steps))
        cap = start_mb * 2.0 ** (k / steps_per_octave)
        if cap > max_mb and not math.isclose(cap, max_mb, rel_tol=1e-9):
            break
        caps.append(cap)
        k += 1
    for extra in include:
        # rungs accumulate float error (0.5 * sqrt(2)^k), so exact
        # membership would duplicate whole-number includes like 2.0
        if not any(math.isclose(float(extra), c, rel_tol=1e-9)
                   for c in caps):
            caps.append(float(extra))
    return tuple(sorted(caps))


def iso_area_search(mems: Sequence[str], area_budget_mm2: float,
                    tol: float = 0.08,
                    ladder: Optional[Sequence[float]] = None
                    ) -> Dict[str, CachePPA]:
    """Largest capacity per memory whose EDAP-tuned area fits the budget.

    One batched sweep over the whole (mems x ladder) grid replaces the
    legacy per-capacity tune loop.  Raises ``ValueError`` when no ladder
    capacity fits for some memory (the legacy path returned ``None`` and
    callers dereferenced it).
    """
    ladder = tuple(ladder if ladder is not None else capacity_ladder())
    s = sweep(mems, ladder)
    fits = s.tuned["area_mm2"] <= area_budget_mm2 * (1.0 + tol)  # (M, C)
    out = {}
    for mi, mem in enumerate(s.mems):
        fitting = np.flatnonzero(fits[mi])
        if fitting.size == 0:
            raise ValueError(
                f"iso-area search: no {mem} capacity in "
                f"[{ladder[0]:g}, {ladder[-1]:g}] MB fits the area budget "
                f"{area_budget_mm2:.3f} mm^2 (tol {tol:.0%}); smallest tuned "
                f"area is {float(s.tuned['area_mm2'][mi].min()):.3f} mm^2")
        out[mem] = s.config(mem, s.capacities_mb[int(fitting[-1])])
    return out


# --- differentiable Table-2 calibration ------------------------------------


def make_calibration_loss(targets: Dict[Tuple[str, float], Dict[str, float]],
                          weights: Dict[str, float],
                          field_map: Dict[str, str]):
    """Build a jit-able, ``jax.grad``-able loss over the sweep engine.

    ``targets`` maps (mem, capacity_mb) -> {short_key: target_value} (the
    Table-2 anchors); ``weights`` maps short_key -> weight; ``field_map``
    maps short_key -> PPA metric name.  The returned ``loss(cal)`` is the
    weighted mean |log(pred / target)| over all anchor numbers, where pred
    comes from the Algorithm-1-tuned configuration — the argmin selection
    is piecewise constant in ``cal``, so gradients flow through the
    selected design point (envelope-style), which is exactly what a tuner
    user experiences.
    """
    mems = tuple(dict.fromkeys(m for m, _ in targets))
    caps = tuple(dict.fromkeys(float(c) for _, c in targets))
    cells = cell_arrays([TABLE1[m] for m in mems])
    caps_arr = jnp.asarray(caps, jnp.float32)

    mi, ci, fi, tgt, wgt = [], [], [], [], []
    fields = tuple(field_map.values())
    for (mem, cap), row in targets.items():
        for key, value in row.items():
            mi.append(mems.index(mem))
            ci.append(caps.index(float(cap)))
            fi.append(fields.index(field_map[key]))
            tgt.append(value)
            wgt.append(weights[key])
    mi, ci, fi = jnp.asarray(mi), jnp.asarray(ci), jnp.asarray(fi)
    tgt = jnp.asarray(tgt, jnp.float32)
    wgt = jnp.asarray(wgt, jnp.float32)

    @jax.jit
    def loss(cal: Dict) -> jnp.ndarray:
        grid = evaluate_batch(cells, caps_arr, cal)
        idx = jax.lax.stop_gradient(_algorithm1(grid))
        m, c = idx.shape
        tuned = jnp.stack([
            jnp.take_along_axis(grid[f].reshape(m, c, -1),
                                idx[:, :, None], axis=2)[:, :, 0]
            for f in fields])                                  # (F, M, C)
        pred = tuned[fi, mi, ci]
        return jnp.sum(wgt * jnp.abs(jnp.log(pred / tgt))) / mi.shape[0]

    return loss
