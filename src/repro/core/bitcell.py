"""Circuit-level bitcell characterization (paper §3.1, Table 1).

The paper runs transient SPICE on perpendicular STT [Kim2015] / SOT
[Kazemi2016] MTJ compact models against a commercial 16nm FinFET PDK,
sweeping access-transistor fin counts and read/write pulse widths to the
point of failure. Neither the PDK nor the compact models are available
offline, so this module provides:

  * ``TABLE1``: the published characterization results (ground truth), and
  * ``characterize()``: a parametric MTJ+FinFET switching model that
    reproduces Table 1 from device-physics inputs (thermal stability,
    critical current, fin drive current), used by tests to show the
    characterization *flow* end-to-end and by the design-space explorer to
    extrapolate bitcells the paper did not publish.

Latency/energy/area conventions match Table 1: sense measured to 25 mV
bitline differential; write to full magnetization reversal; area normalized
to the foundry SRAM bitcell.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict


@dataclasses.dataclass(frozen=True)
class Bitcell:
    name: str
    sense_latency_ps: float
    sense_energy_pj: float
    write_latency_set_ps: float
    write_latency_reset_ps: float
    write_energy_set_pj: float
    write_energy_reset_pj: float
    area_rel_sram: float              # normalized to foundry SRAM bitcell
    leak_rel_sram: float              # array leakage vs SRAM bitcell
    fins: str = ""

    @property
    def write_latency_ps(self) -> float:
        return 0.5 * (self.write_latency_set_ps + self.write_latency_reset_ps)

    @property
    def write_energy_pj(self) -> float:
        return 0.5 * (self.write_energy_set_pj + self.write_energy_reset_pj)


# --- Table 1 (published) ----------------------------------------------------

SRAM = Bitcell(
    name="SRAM",
    # 6T SRAM at 16nm: sub-200ps sense, symmetric fast write, unit area.
    sense_latency_ps=180.0, sense_energy_pj=0.011,
    write_latency_set_ps=250.0, write_latency_reset_ps=250.0,
    write_energy_set_pj=0.015, write_energy_reset_pj=0.015,
    area_rel_sram=1.0, leak_rel_sram=1.0, fins="foundry 6T",
)

STT = Bitcell(
    name="STT-MRAM",
    sense_latency_ps=650.0, sense_energy_pj=0.076,
    write_latency_set_ps=8400.0, write_latency_reset_ps=7780.0,
    write_energy_set_pj=1.1, write_energy_reset_pj=2.2,
    area_rel_sram=0.34, leak_rel_sram=0.0, fins="4 (read/write)",
)

SOT = Bitcell(
    name="SOT-MRAM",
    sense_latency_ps=650.0, sense_energy_pj=0.020,
    write_latency_set_ps=313.0, write_latency_reset_ps=243.0,
    write_energy_set_pj=0.08, write_energy_reset_pj=0.08,
    area_rel_sram=0.29, leak_rel_sram=0.0, fins="3 (write) + 1 (read)",
)

TABLE1: Dict[str, Bitcell] = {"SRAM": SRAM, "STT": STT, "SOT": SOT}


# --- parametric characterization flow --------------------------------------


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    """Inputs a circuit designer would pull from the MTJ compact model."""
    ic0_ua: float            # critical switching current (uA)
    tau0_ns: float           # attempt time (~1 ns)
    delta: float             # thermal stability factor
    r_low_kohm: float
    r_high_kohm: float
    vdd: float = 0.8
    fin_current_ua: float = 35.0   # drive current per fin at 16nm, ~Vdd
    cell_cap_ff: float = 0.12      # bit/sense-line cap per cell (fF)
    sram_bitcell_um2: float = 0.074


# Ic0 back-solved from Table 1's write latencies at the published fin
# counts (4W for STT at 140uA drive; 3W for SOT with the 3x spin-orbit
# current efficiency): the same Sun-model constants then reproduce the
# published set/reset asymmetry and the fin-sweep trade-off shape.
STT_DEVICE = DeviceModel(ic0_ua=83.4, tau0_ns=1.0, delta=60.0,
                         r_low_kohm=3.0, r_high_kohm=6.0)
SOT_DEVICE = DeviceModel(ic0_ua=15.2, tau0_ns=1.0, delta=60.0,
                         r_low_kohm=3.0, r_high_kohm=6.0)


def switching_time_ns(dev: DeviceModel, i_write_ua: float) -> float:
    """Precessional-regime MTJ switching time: t ~ tau0 * ln(4*delta)/ (I/Ic0 - 1).

    Standard macromodel (Sun model) for I > Ic0; diverges near Ic0.
    """
    ratio = i_write_ua / dev.ic0_ua
    if ratio <= 1.02:
        return float("inf")
    return dev.tau0_ns * math.log(4.0 * dev.delta) / (ratio - 1.0)


def characterize(dev: DeviceModel, *, write_fins: int, read_fins: int,
                 sot: bool = False, name: str = "custom") -> Bitcell:
    """Produce a Bitcell from device inputs (the paper's §3.1 flow).

    The access transistor supplies ``write_fins * fin_current_ua``; SOT's
    separate (lower-resistance) write path gets a 3x current-efficiency
    factor into the free layer, which is what makes its sub-ns switching
    possible at small fin counts.
    """
    i_w = write_fins * dev.fin_current_ua * (3.0 if sot else 1.0)
    t_w_ns = switching_time_ns(dev, i_w)
    # set/reset asymmetry: AP->P is ~8% faster (lower effective Ic)
    t_set, t_reset = t_w_ns * 1.04, t_w_ns * 0.96
    v_write = dev.vdd * (0.5 if sot else 0.9)
    # x2.2: write path overhead (bitline charging, driver crowbar)
    e_w_pj = 2.2 * i_w * 1e-6 * v_write * t_w_ns * 1e-9 * 1e12
    # sense: discharge to 25mV differential through R_avg with read current
    i_r = read_fins * dev.fin_current_ua * 0.25   # read bias far below Ic0
    r_avg = 0.5 * (dev.r_low_kohm + dev.r_high_kohm)
    t_sense_ps = 520.0 + 2.2 * r_avg * dev.cell_cap_ff * 110.0
    e_sense_pj = 4.2 * (i_r * 1e-6) * dev.vdd * (t_sense_ps * 1e-12) * 1e12 \
        * (0.27 if sot else 1.0)
    # layout area per [Seo&Roy 2018] formulation: transistor-pitch dominated
    fin_area = (write_fins + (read_fins if sot else 0)) * 0.0105
    area_um2 = fin_area + 0.008
    return Bitcell(
        name=name,
        sense_latency_ps=t_sense_ps,
        sense_energy_pj=e_sense_pj,
        write_latency_set_ps=t_set * 1e3,
        write_latency_reset_ps=t_reset * 1e3,
        write_energy_set_pj=e_w_pj * (1.0 if sot else 0.85),
        write_energy_reset_pj=e_w_pj * (1.0 if sot else 1.7),
        area_rel_sram=area_um2 / dev.sram_bitcell_um2,
        leak_rel_sram=0.0,
        fins=f"{write_fins}W/{read_fins}R",
    )


def fin_sweep(dev: DeviceModel, *, sot: bool, max_fins: int = 8):
    """Sweep access-device fin counts (paper: 'swept a range of fin counts
    ... to find the optimal balance between latency, energy, and area')."""
    out = []
    for wf in range(1, max_fins + 1):
        rf = 1 if sot else wf  # STT shares the device; SOT separates paths
        cell = characterize(dev, write_fins=wf, read_fins=rf, sot=sot,
                            name=f"{'SOT' if sot else 'STT'}-{wf}F")
        if math.isfinite(cell.write_latency_ps):
            out.append(cell)
    return out
