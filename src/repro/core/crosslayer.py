"""Cross-layer TPU-mode analysis — the paper's pipeline pointed at our own
framework (DESIGN.md §3, "beyond paper").

DeepNVM++'s cross-layer link is: measured workload memory behavior ->
technology-dependent cache PPA -> energy/EDP verdict. Here the "measured
memory behavior" is the per-device HBM traffic of each compiled
(architecture x shape x mesh) dry-run cell (launch/dryrun.py records), and
the modeled cache is an NVM-vs-SRAM *on-chip SRAM tier* of a TPU-class
accelerator (v5e-like). Reads vs writes are split with the roofline
convention (every modeled surface byte is one write + one read ->
read fraction ~ operand share; we use the measured dot/elementwise mix).

Outputs, per cell: SRAM/STT/SOT tier energy per step, leakage over the
step's roofline-bound time, EDP ratios — i.e. "would an MRAM last-level
tier help THIS workload on THIS mesh", the exact question the paper asks
for 2016-era GPUs, asked of 2026-era LM training/serving.
"""
from __future__ import annotations

import dataclasses
import json
from functools import lru_cache
from pathlib import Path
from typing import Dict, List, Optional

from repro.core.cache_model import CachePPA
from repro.core.constants import LINE_BYTES, TPU_SRAM_TIER_MB
from repro.core.tuner import iso_capacity_configs

# traffic split: fraction of modeled surface bytes that are reads
READ_FRACTION = 0.60
# a 100+MB accelerator SRAM tier uses high-density low-leak cells, not the
# HP cells the GPU-L2 calibration fit; derate SRAM leakage accordingly so
# the TPU-mode verdict is not an HP-leakage artifact (DESIGN.md §3).
SRAM_LEAK_DERATE = 0.12


@dataclasses.dataclass(frozen=True)
class CellVerdict:
    arch: str
    shape: str
    mesh: str
    reads: float                  # tier transactions per step per device
    writes: float
    step_s: float                 # roofline-bound step time
    energy_ratio: Dict[str, float]    # mem -> vs SRAM
    edp_ratio: Dict[str, float]

    def to_dict(self):
        return dataclasses.asdict(self)


@lru_cache(maxsize=None)
def _tier_configs(tier_mb: float) -> Dict[str, CachePPA]:
    return iso_capacity_configs(tier_mb)


def _tier_energy(reads: float, writes: float, step_s: float,
                 ppa: CachePPA, leak_derate: float = 1.0) -> float:
    dyn = reads * ppa.read_energy_nj + writes * ppa.write_energy_nj  # nJ
    leak = leak_derate * ppa.leakage_mw * 1e-3 * step_s * 1e9        # nJ
    return dyn + leak


def analyze_record(rec: Dict, tier_mb: float = TPU_SRAM_TIER_MB
                   ) -> CellVerdict:
    roof = rec["roofline"]
    byts = roof["bytes_per_device"]
    reads = byts * READ_FRACTION / LINE_BYTES
    writes = byts * (1 - READ_FRACTION) / LINE_BYTES
    step_s = max(roof["compute_s"], roof["memory_s"], roof["collective_s"])
    cfgs = _tier_configs(tier_mb)
    e = {m: _tier_energy(reads, writes, step_s, cfgs[m],
                         SRAM_LEAK_DERATE if m == "SRAM" else 1.0)
         for m in cfgs}
    # NVM extra access latency only matters on the memory-bound fraction;
    # step time is roofline-bound, so delay scales with the tier's read
    # latency when memory dominates, else stays put.
    d = {}
    for m, ppa in cfgs.items():
        mem_scale = ppa.read_latency_ns / cfgs["SRAM"].read_latency_ns
        mem_s = roof["memory_s"] * mem_scale
        d[m] = max(roof["compute_s"], mem_s, roof["collective_s"])
    return CellVerdict(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        reads=reads, writes=writes, step_s=step_s,
        energy_ratio={m: e[m] / e["SRAM"] for m in ("STT", "SOT")},
        edp_ratio={m: (e[m] * d[m]) / (e["SRAM"] * d["SRAM"])
                   for m in ("STT", "SOT")},
    )


def analyze_dryrun_dir(results_dir: str, tag: str = "baseline",
                       tier_mb: float = TPU_SRAM_TIER_MB
                       ) -> List[CellVerdict]:
    out = []
    for p in sorted(Path(results_dir).glob(f"*__{tag}.json")):
        rec = json.loads(p.read_text())
        out.append(analyze_record(rec, tier_mb))
    return out
