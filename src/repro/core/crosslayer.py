"""Cross-layer TPU-mode analysis — the paper's pipeline pointed at our own
framework (DESIGN.md §3, "beyond paper").

DeepNVM++'s cross-layer link is: measured workload memory behavior ->
technology-dependent cache PPA -> energy/EDP verdict. Here the "measured
memory behavior" is the per-device HBM traffic of each compiled
(architecture x shape x mesh) dry-run cell (launch/dryrun.py records), and
the modeled cache is an NVM-vs-SRAM *on-chip SRAM tier* of a TPU-class
accelerator (v5e-like). Reads vs writes are split with the roofline
convention (every modeled surface byte is one write + one read ->
read fraction ~ operand share; we use the measured dot/elementwise mix).

Outputs, per cell: SRAM/STT/SOT tier energy per step, leakage over the
step's roofline-bound time, EDP ratios — i.e. "would an MRAM last-level
tier help THIS workload on THIS mesh", the exact question the paper asks
for 2016-era GPUs, asked of 2026-era LM training/serving.
"""
from __future__ import annotations

import dataclasses
import json
from functools import lru_cache
from pathlib import Path
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.cache_model import CachePPA
from repro.core.constants import LINE_BYTES, TPU_SRAM_TIER_MB
from repro.core.tuner import iso_capacity_configs

# traffic split: fraction of modeled surface bytes that are reads.
# Inference/dry-run convention (operand-reuse dominated): 0.60.  Training
# adds whole write streams the inference mix lacks — gradients, Adam
# moments, activation spills for backward — so its split sits at the
# one-write-one-read-per-surface-byte point (paper Fig. 3: training R/W
# ratios cluster near 1, vs >2 for inference); the STT/SOT verdicts hinge
# on this because MRAM write energy is the dominant penalty term.
READ_FRACTION = 0.60
TRAIN_READ_FRACTION = 0.50
# recurrent-bank serving (ssm/hybrid slot-state banks) rewrites the whole
# conv/SSD/RG-LRU state every tick where KV decode appends one row and
# reads the rest — the write-heaviest serve regime we model, below even
# the training split (DESIGN.md §17; cf. arXiv 2308.02024 on STT-MRAM
# write asymmetry dominating exactly this small-hot-state pattern).
RECURRENT_READ_FRACTION = 0.45
# a 100+MB accelerator SRAM tier uses high-density low-leak cells, not the
# HP cells the GPU-L2 calibration fit; derate SRAM leakage accordingly so
# the TPU-mode verdict is not an HP-leakage artifact (DESIGN.md §3).
SRAM_LEAK_DERATE = 0.12


@dataclasses.dataclass(frozen=True)
class CellVerdict:
    arch: str
    shape: str
    mesh: str
    reads: float                  # tier transactions per step per device
    writes: float
    step_s: float                 # roofline-bound step time
    energy_ratio: Dict[str, float]    # mem -> vs SRAM
    edp_ratio: Dict[str, float]

    def to_dict(self):
        return dataclasses.asdict(self)


@lru_cache(maxsize=None)
def _tier_configs(tier_mb: float) -> Dict[str, CachePPA]:
    return iso_capacity_configs(tier_mb)


def analyze_records(recs: List[Dict], tier_mb: float = TPU_SRAM_TIER_MB,
                    read_fraction: float = READ_FRACTION
                    ) -> List[CellVerdict]:
    """Batched verdicts: every cell's (reads, writes, step time) is stacked
    into (N,) arrays and evaluated against all three tier memories in one
    array-native pass — the cross-layer consumer of the traffic-tensor
    convention (DESIGN.md §10).  ``read_fraction`` is the mode-dependent
    read share of the modeled surface bytes (train mode passes the
    write-heavier ``TRAIN_READ_FRACTION``); a scalar applies to every
    record, an (N,) array gives each record its own split (serve mode
    mixes families with different splits)."""
    if not recs:
        return []
    cfgs = _tier_configs(tier_mb)
    roofs = [r["roofline"] for r in recs]
    byts = jnp.asarray([r["bytes_per_device"] for r in roofs], jnp.float32)
    reads = byts * read_fraction / LINE_BYTES
    writes = byts * (1 - read_fraction) / LINE_BYTES
    comp = jnp.asarray([r["compute_s"] for r in roofs], jnp.float32)
    mem = jnp.asarray([r["memory_s"] for r in roofs], jnp.float32)
    coll = jnp.asarray([r["collective_s"] for r in roofs], jnp.float32)
    step = jnp.maximum(jnp.maximum(comp, mem), coll)
    e, d = {}, {}
    for m, ppa in cfgs.items():
        derate = SRAM_LEAK_DERATE if m == "SRAM" else 1.0
        dyn = (reads * ppa.read_energy_nj + writes * ppa.write_energy_nj)
        leak = derate * ppa.leakage_mw * 1e-3 * step * 1e9          # nJ
        e[m] = dyn + leak
        # NVM extra access latency only matters on the memory-bound
        # fraction; step time is roofline-bound, so delay scales with the
        # tier's read latency when memory dominates, else stays put.
        mem_scale = ppa.read_latency_ns / cfgs["SRAM"].read_latency_ns
        d[m] = jnp.maximum(jnp.maximum(comp, mem * mem_scale), coll)
    e = {m: np.asarray(v) for m, v in e.items()}
    d = {m: np.asarray(v) for m, v in d.items()}
    reads, writes, step = (np.asarray(x) for x in (reads, writes, step))
    return [CellVerdict(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        reads=float(reads[i]), writes=float(writes[i]),
        step_s=float(step[i]),
        energy_ratio={m: float(e[m][i] / e["SRAM"][i])
                      for m in ("STT", "SOT")},
        edp_ratio={m: float((e[m][i] * d[m][i])
                            / (e["SRAM"][i] * d["SRAM"][i]))
                   for m in ("STT", "SOT")},
    ) for i, rec in enumerate(recs)]


def analyze_record(rec: Dict, tier_mb: float = TPU_SRAM_TIER_MB
                   ) -> CellVerdict:
    """Single-cell view over the batched ``analyze_records``."""
    return analyze_records([rec], tier_mb)[0]


_SERVE_ROOF_KEYS = ("bytes_per_device", "compute_s", "memory_s",
                    "collective_s")


def _require_roofline(records: List[Dict], hint: str) -> None:
    """Validate engine-measured records carry the roofline terms the
    batched verdict pass needs, naming the offending record."""
    for rec in records:
        roof = rec.get("roofline") or {}
        missing = [k for k in _SERVE_ROOF_KEYS if k not in roof]
        if missing:
            raise ValueError(
                f"record {rec.get('shape', '?')!r} is missing roofline "
                f"terms {missing}; {hint}")


def analyze_serve(records: List[Dict], tier_mb: float = TPU_SRAM_TIER_MB
                  ) -> List[CellVerdict]:
    """Serve-mode NVM verdicts from engine-measured traffic records.

    ``records`` come from ``repro.serve.Engine.serve_records()``: one
    record per serve phase whose roofline terms are the compiled engine
    tick's (decode) or prefill call's measured per-device HBM traffic —
    the live-traffic analogue of the dry-run records ``analyze_records``
    was built for.  Decode ticks are the memory-bound regime where
    DeepNVM++ (arXiv 2012.04559) predicts MRAM last-level tiers pay off
    most, and Roy et al. (arXiv 2308.02024) show the verdict hinges on
    measured per-step traffic — which is exactly what these records carry.

    Records may carry a per-record ``read_fraction`` — the serve engines
    tag ssm/hybrid traffic with ``RECURRENT_READ_FRACTION`` because
    recurrent banks are rewritten in full every tick — which overrides
    the inference-convention ``READ_FRACTION`` for that record only, so
    one family-mixed record list scores each family on its own
    read/write split (ISSUE 10, tentpole (d)).

    Records carrying a ``unique_page_fraction`` (the paged engine's
    measured share of physically-unique KV page reads per decode window,
    ``serve.engine.PagedEngine.serve_records``) get their
    ``bytes_per_device`` and ``memory_s`` scaled by it before scoring:
    radix-tree prefix sharing maps many slots onto the same physical
    pages, so the tier's real KV traffic — and with it the SRAM/STT/SOT
    energy/EDP verdicts — shrinks with sharing.  Compute and collective
    terms are left alone (every slot still runs its own matmuls).

    Raises ``ValueError`` naming the offending record when roofline terms
    are missing (e.g. the engine ran with ``record_traffic=False`` and a
    record was assembled by hand).
    """
    _require_roofline(records, "run the engine with record_traffic=True")
    scaled = []
    for rec in records:
        upf = rec.get("unique_page_fraction")
        if upf is None:
            scaled.append(rec)
            continue
        if not 0.0 < upf <= 1.0:
            raise ValueError(
                f"record {rec.get('shape', '?')!r}: unique_page_fraction "
                f"{upf} outside (0, 1]")
        roof = dict(rec["roofline"])
        roof["bytes_per_device"] *= upf
        roof["memory_s"] *= upf
        scaled.append({**rec, "roofline": roof})
    for rec in scaled:
        rf = rec.get("read_fraction")
        if rf is not None and not 0.0 < rf < 1.0:
            raise ValueError(
                f"record {rec.get('shape', '?')!r}: read_fraction {rf} "
                f"outside (0, 1)")
    rfs = jnp.asarray(
        [float(r.get("read_fraction", READ_FRACTION)) for r in scaled],
        jnp.float32)
    return analyze_records(scaled, tier_mb, read_fraction=rfs)


def analyze_train(records: List[Dict], tier_mb: float = TPU_SRAM_TIER_MB
                  ) -> List[CellVerdict]:
    """Train-mode NVM verdicts from fused-window measured traffic records.

    ``records`` come from ``repro.train.trainer.TrainWindow
    .train_records()``: per-STEP roofline terms of the compiled K-step
    window (forward + backward + optimizer + on-device batch hashing).
    Training is the write-heavy regime the paper's Fig. 3 R/W ratios and
    EDP analysis cover, and the one where Roy et al. (arXiv 2308.02024)
    show the STT-MRAM endurance/energy trade-off is sharpest — DeepNVM++
    (arXiv 2012.04559) positions exactly this traffic as a first-class
    input to the cross-layer model.  Accordingly the read/write split is
    ``TRAIN_READ_FRACTION`` (gradient/optimizer/spill write streams), not
    the inference convention, so identical roofline terms score
    differently here than under ``analyze_serve`` — at the calibrated
    tier the sectored-write convention makes MRAM writes cheaper than
    SRAM line writes, so the write-heavier mix shifts the verdict in
    MRAM's favor (tests pin the direction).

    Raises ``ValueError`` naming the offending record when roofline terms
    are missing (e.g. the window ran with ``record_traffic=False`` and a
    record was assembled by hand).
    """
    _require_roofline(records,
                      "run the train window with record_traffic=True")
    return analyze_records(records, tier_mb,
                           read_fraction=TRAIN_READ_FRACTION)


def analyze_dryrun_dir(results_dir: str, tag: str = "baseline",
                       tier_mb: float = TPU_SRAM_TIER_MB
                       ) -> List[CellVerdict]:
    """Batched verdicts for every ``*__{tag}.json`` record in a dry-run
    results dir.  Raises ``FileNotFoundError`` naming the dir and tag when
    the dir is missing or holds no matching records (the legacy path
    silently returned ``[]``)."""
    d = Path(results_dir)
    if not d.is_dir():
        raise FileNotFoundError(
            f"dry-run results dir {str(d)!r} does not exist "
            f"(tag {tag!r}); run launch/dryrun.py first")
    paths = sorted(d.glob(f"*__{tag}.json"))
    if not paths:
        raise FileNotFoundError(
            f"no '*__{tag}.json' records in {str(d)!r}; "
            f"run launch/dryrun.py with --tag {tag}")
    return analyze_records([json.loads(p.read_text()) for p in paths],
                           tier_mb)
