"""Workload memory-behavior profiles (paper §3.3) — engine views.

The paper obtains L2 read/write transaction counts from nvprof on a GTX
1080 Ti.  Without the GPU, they are derived analytically from per-layer
workload descriptors.  Since the traffic-engine refactor the
*implementation* lives in ``repro.core.traffic``: workloads are packed
into padded JAX descriptor arrays and the whole (workload × mode ×
batch-grid) traffic tensor is computed in one jitted, differentiable call
(DESIGN.md §10).  This module keeps the paper-shaped public API —
``profile()`` / ``paper_profiles()`` / ``dl_profiles()`` are thin views
over one engine evaluation, and ``_layer_traffic`` survives as the
float64 scalar reference the engine is parity-tested against
(``tests/test_traffic_engine.py``, 1e-6 relative).

Traffic model (knobs in ``traffic.TRAFFIC``, calibrated against the
paper's §4 claims by ``tools/calibrate_traffic.py``):

inference (batch B), per layer:
    reads  = B * in_bytes * k_im2col / r_L1          (fmap tiles via im2col)
           + W * (1 + B / W_TILE)                    (weights streamed to SMs)
    writes = B * out_bytes

training adds the backward pass: activations re-read for dW and dX,
weight-gradient accumulation read-modify-write per GRAD_TILE samples.
This reproduces the paper's measured characteristics: per-workload R/W in
the Fig-3 range [2, 26], inference R/W decreasing and training R/W
increasing with batch size (§4.1, Fig 6).  DRAM transaction counts come
from the calibrated DRAM:L2 fractions (core/dram.py models their scaling
with capacity).
"""
from __future__ import annotations

from functools import lru_cache
from typing import List

from repro.core.constants import LINE_BYTES
from repro.core.traffic import (MemoryProfile, TRAFFIC, compute_traffic,
                                paper_pack)
from repro.core.workloads import HPCG, NETWORKS, HPCGWorkload, Network

__all__ = ["MemoryProfile", "TRAFFIC", "profile", "profile_reference",
           "paper_profiles", "dl_profiles"]


def _layer_traffic(net: Network, batch: int, training: bool, t=None):
    """Scalar float64 reference (the seed implementation) — the engine's
    parity oracle and the per-point baseline of
    ``benchmarks/traffic_engine.py``.  Keep in sync with
    ``traffic._traffic_jit``."""
    t = t or TRAFFIC
    reads = writes = 0.0
    for l in net.layers:
        k_eff = (t["k_im2col"] * l.k * l.k if l.kind == "conv" else 1.0)
        a_in = l.in_bytes * k_eff
        W = l.weight_bytes * (t["fc_w_factor"] if l.kind == "fc" else 1.0)
        if training:
            reads += (2.0 * batch * a_in + batch * l.out_bytes
                      + W * (2.0 + batch / t["grad_tile"]))
            writes += (batch * (l.in_bytes + l.out_bytes)
                       + W * (1.0 + batch / (2 * t["grad_tile"])))
        else:
            reads += batch * a_in + W * (1.0 + batch / t["w_tile"])
            writes += batch * l.out_bytes
    return reads / LINE_BYTES, writes / LINE_BYTES


def _check_hpcg_args(name: str, mode: str, batch: int) -> None:
    if mode != "hpc":
        raise ValueError(
            f"{name} is an HPC workload: mode must be 'hpc', got {mode!r} "
            f"(HPCG has no inference/training split)")
    if batch != 1:
        raise ValueError(
            f"{name} is an HPC workload: batch must be 1, got {batch} "
            f"(HPCG traffic is batch-independent)")


def profile_reference(net_name: str, mode: str, batch: int,
                      t=None) -> MemoryProfile:
    """Per-point scalar path (seed implementation) — parity oracle."""
    t = t or TRAFFIC
    if net_name in HPCG:
        _check_hpcg_args(net_name, mode, batch)
        w = HPCG[net_name]
        r, wr = w.transactions()
        return MemoryProfile(w.name, "hpc", 1, r, wr,
                             (r + wr) * t["dram_frac_i"])
    net = NETWORKS[net_name]
    training = mode == "training"
    r, w = _layer_traffic(net, batch, training, t)
    frac = t["dram_frac_t"] if training else t["dram_frac_i"]
    return MemoryProfile(net.name, mode, batch, r, w, (r + w) * frac)


def profile(net_name: str, mode: str, batch: int, t=None) -> MemoryProfile:
    """One (workload, mode, batch) profile — a view over one engine cell.

    Raises ``ValueError`` for HPCG names with ``mode != "hpc"`` or
    ``batch != 1`` (the legacy path silently returned a mislabeled
    batch-1 hpc profile)."""
    if net_name in HPCG:
        _check_hpcg_args(net_name, mode, batch)
    tt = compute_traffic(paper_pack(), (float(batch),), t)
    return tt.profile(net_name, mode, batch)


def paper_profiles(inference_batch: int = 4,
                   training_batch: int = 64) -> List[MemoryProfile]:
    """The paper's workload set: 5 DNNs x {I, T} + HPCG-{S,M,L} (§4.1) —
    one batched engine evaluation over the whole set."""
    # the knob values join the cache key so in-place TRAFFIC edits
    # (calibration experiments) can never serve stale cached profiles
    return list(_paper_profiles_cached(int(inference_batch),
                                       int(training_batch),
                                       tuple(TRAFFIC.values())))


@lru_cache(maxsize=8)
def _paper_profiles_cached(inference_batch: int, training_batch: int,
                           _knobs):
    batches = tuple(dict.fromkeys((float(inference_batch),
                                   float(training_batch))))
    tt = compute_traffic(paper_pack(), batches)
    out: List[MemoryProfile] = []
    for name in NETWORKS:
        out.append(tt.profile(name, "inference", inference_batch))
        out.append(tt.profile(name, "training", training_batch))
    for name in HPCG:
        out.append(tt.profile(name, "hpc", 1))
    return tuple(out)


def dl_profiles(inference_batch: int = 4,
                training_batch: int = 64) -> List[MemoryProfile]:
    return [p for p in paper_profiles(inference_batch, training_batch)
            if p.mode != "hpc"]
