"""Workload memory-behavior profiles (paper §3.3).

The paper obtains L2 read/write transaction counts from nvprof on a GTX
1080 Ti. Without the GPU, we derive them analytically from the per-layer
workload descriptors with a small, documented traffic model:

inference (batch B), per layer:
    reads  = B * in_bytes * k_im2col / r_L1          (fmap tiles via im2col)
           + W * (1 + B / W_TILE)                    (weights streamed to SMs)
    writes = B * out_bytes

training adds the backward pass: activations re-read for dW and dX,
weight-gradient accumulation read-modify-write per GRAD_TILE samples:
    reads  = 3 * B * act * k / r + W * (2 + B / GRAD_TILE)
    writes = B * (in + out) + W * (1 + B / (2 * GRAD_TILE))

This reproduces the paper's measured characteristics: per-workload R/W in
the Fig-3 range [2, 26], DL-average read-energy share ~83% (=> count-
weighted R/W ~ 4.4 with Table-2 energies), inference R/W decreasing and
training R/W increasing with batch size (§4.1, Fig 6 discussion).
DRAM transaction counts come from core/dram.py's miss model.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List

from repro.core.constants import LINE_BYTES
from repro.core.workloads import HPCG, NETWORKS, HPCGWorkload, Network

# Traffic-model knobs; calibrated against the paper's §4 claims by
# tools/calibrate_traffic.py (see DESIGN.md §3 for the claim set).
TRAFFIC = {
    # frozen output of tools/calibrate_traffic.py (mean |log err| 0.18 over
    # the paper's 13 quantitative §4 claims; R/W range penalty 0)
    "k_im2col": 0.51713,   # net im2col amplification / L1 reuse (k^2/r_L1)
    "w_tile": 32.6899,     # samples per weight re-stream (inference)
    "grad_tile": 4.46882,  # samples per weight-grad accumulation RMW
    "fc_w_factor": 0.324592,  # FC weight streams are unit-stride/coalesced
    "dram_frac_i": 0.00848827,  # DRAM:L2 transaction ratio, inference
    "dram_frac_t": 0.00797266,  # DRAM:L2 transaction ratio, training
}


@dataclasses.dataclass(frozen=True)
class MemoryProfile:
    """L2/DRAM transaction counts for one (workload, mode, batch)."""
    name: str
    mode: str            # "inference" | "training" | "hpc"
    batch: int
    l2_reads: float
    l2_writes: float
    dram: float          # DRAM transactions (at the 3MB baseline cache)

    @property
    def rw_ratio(self) -> float:
        return self.l2_reads / max(self.l2_writes, 1.0)

    @property
    def label(self) -> str:
        suffix = {"inference": "I", "training": "T", "hpc": ""}[self.mode]
        return f"{self.name}-{suffix}" if suffix else self.name


def _layer_traffic(net: Network, batch: int, training: bool, t=None):
    t = t or TRAFFIC
    reads = writes = 0.0
    for l in net.layers:
        k_eff = (t["k_im2col"] * l.k * l.k if l.kind == "conv" else 1.0)
        a_in = l.in_bytes * k_eff
        W = l.weight_bytes * (t["fc_w_factor"] if l.kind == "fc" else 1.0)
        if training:
            reads += (2.0 * batch * a_in + batch * l.out_bytes
                      + W * (2.0 + batch / t["grad_tile"]))
            writes += (batch * (l.in_bytes + l.out_bytes)
                       + W * (1.0 + batch / (2 * t["grad_tile"])))
        else:
            reads += batch * a_in + W * (1.0 + batch / t["w_tile"])
            writes += batch * l.out_bytes
    return reads / LINE_BYTES, writes / LINE_BYTES


def profile(net_name: str, mode: str, batch: int, t=None) -> MemoryProfile:
    t = t or TRAFFIC
    if net_name in HPCG:
        w = HPCG[net_name]
        r, wr = w.transactions()
        return MemoryProfile(w.name, "hpc", 1, r, wr,
                             (r + wr) * t["dram_frac_i"])
    net = NETWORKS[net_name]
    training = mode == "training"
    r, w = _layer_traffic(net, batch, training, t)
    frac = t["dram_frac_t"] if training else t["dram_frac_i"]
    return MemoryProfile(net.name, mode, batch, r, w, (r + w) * frac)


def paper_profiles(inference_batch: int = 4,
                   training_batch: int = 64) -> List[MemoryProfile]:
    """The paper's workload set: 5 DNNs x {I, T} + HPCG-{S,M,L} (§4.1)."""
    out: List[MemoryProfile] = []
    for name in NETWORKS:
        out.append(profile(name, "inference", inference_batch))
        out.append(profile(name, "training", training_batch))
    for name in HPCG:
        out.append(profile(name, "hpc", 1))
    return out


def dl_profiles(inference_batch: int = 4,
                training_batch: int = 64) -> List[MemoryProfile]:
    return [p for p in paper_profiles(inference_batch, training_batch)
            if p.mode != "hpc"]
