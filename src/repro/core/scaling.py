"""Scalability analysis (paper §4.3, Figs 10-13).

Each memory is EDAP-tuned independently at every capacity (1..32 MB), then
evaluated on every workload; results are normalized to SRAM at the same
capacity. DRAM terms are held at the 3MB-baseline counts (iso-capacity
convention) so the curves isolate cache scalability.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.core import energy as en
from repro.core.cache_model import CachePPA
from repro.core.profiles import MemoryProfile, paper_profiles
from repro.core.tuner import CAPACITIES_MB, MEMORIES, tune_all


def ppa_scaling(capacities: Sequence[float] = CAPACITIES_MB
                ) -> Dict[str, Dict[float, CachePPA]]:
    """Fig 10: area / latency / energy vs capacity per memory — one batched
    sweep over the full (memory x capacity) grid."""
    return tune_all(MEMORIES, capacities)


def workload_scaling(profiles: Optional[List[MemoryProfile]] = None,
                     capacities: Sequence[float] = CAPACITIES_MB,
                     mode_filter: Optional[str] = None):
    """Figs 11-13: normalized energy / latency / EDP vs capacity.

    Returns {capacity: {mem: {metric: {mean, std}}}} across workloads.
    """
    import math

    profiles = profiles or paper_profiles()
    if mode_filter:
        profiles = [p for p in profiles if p.mode == mode_filter]
    cfgs = ppa_scaling(capacities)
    out: Dict[float, Dict[str, Dict[str, Dict[str, float]]]] = {}
    for c in capacities:
        sram = cfgs["SRAM"][c]
        per_mem: Dict[str, Dict[str, Dict[str, float]]] = {}
        for m in ("STT", "SOT"):
            ratios = {"total": [], "delay": [], "edp": []}
            for p in profiles:
                base = en.evaluate(p, sram)
                rel = en.relative(base, en.evaluate(p, cfgs[m][c]))
                ratios["total"].append(rel["total"])
                ratios["delay"].append(rel["delay"])
                ratios["edp"].append(rel["edp_with_dram"])
            per_mem[m] = {
                k: {
                    "mean": sum(v) / len(v),
                    "std": math.sqrt(sum((x - sum(v) / len(v)) ** 2
                                         for x in v) / len(v)),
                    "min": min(v),
                } for k, v in ratios.items()
            }
        out[c] = per_mem
    return out
