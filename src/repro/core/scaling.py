"""Scalability analysis (paper §4.3, Figs 10-13).

Each memory is EDAP-tuned independently at every capacity (1..32 MB), then
evaluated on every workload; results are normalized to SRAM at the same
capacity. DRAM terms are held at the 3MB-baseline counts (iso-capacity
convention) so the curves isolate cache scalability.

Since the traffic-engine refactor ``workload_scaling`` consumes the whole
traffic tensor at once: profiles are stacked into (P,) arrays, each
memory's tuned PPA across the capacity grid into (C, 1) arrays, and one
broadcasted array-energy pass (``energy.evaluate_arrays``) yields the
(C, P) relative-metric tensor per memory — no per-(capacity, workload)
Python loops.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import energy as en
from repro.core.cache_model import CachePPA
from repro.core.profiles import MemoryProfile, paper_profiles
from repro.core.tuner import CAPACITIES_MB, MEMORIES, tune_all


def ppa_scaling(capacities: Sequence[float] = CAPACITIES_MB
                ) -> Dict[str, Dict[float, CachePPA]]:
    """Fig 10: area / latency / energy vs capacity per memory — one batched
    sweep over the full (memory x capacity) grid."""
    return tune_all(MEMORIES, capacities)


def _ppa_columns(cfgs: Dict[float, CachePPA],
                 capacities: Sequence[float]) -> Dict[str, jnp.ndarray]:
    """Stack one memory's tuned PPA over the capacity grid as (C, 1)
    arrays, broadcastable against (P,) profile arrays."""
    return {f: jnp.asarray([[getattr(cfgs[c], f)] for c in capacities],
                           jnp.float32)
            for f in en.PPA_ENERGY_FIELDS}


def workload_scaling(profiles: Optional[List[MemoryProfile]] = None,
                     capacities: Sequence[float] = CAPACITIES_MB,
                     mode_filter: Optional[str] = None):
    """Figs 11-13: normalized energy / latency / EDP vs capacity.

    Returns {capacity: {mem: {metric: {mean, std, min}}}} across workloads.
    """
    profiles = profiles or paper_profiles()
    if mode_filter:
        profiles = [p for p in profiles if p.mode == mode_filter]
    cfgs = ppa_scaling(capacities)
    reads = jnp.asarray([p.l2_reads for p in profiles], jnp.float32)
    writes = jnp.asarray([p.l2_writes for p in profiles], jnp.float32)
    dram = jnp.asarray([p.dram for p in profiles], jnp.float32)
    base = en.evaluate_arrays(reads, writes, dram,
                              _ppa_columns(cfgs["SRAM"], capacities))
    metric_map = {"total": "total", "delay": "delay",
                  "edp": "edp_with_dram"}
    out: Dict[float, Dict[str, Dict[str, Dict[str, float]]]] = {
        c: {} for c in capacities}
    for m in ("STT", "SOT"):
        rep = en.evaluate_arrays(reads, writes, dram,
                                 _ppa_columns(cfgs[m], capacities))
        rel = en.relative_arrays(base, rep)            # each (C, P)
        for k, src in metric_map.items():
            v = np.asarray(rel[src])
            mean, std, vmin = v.mean(1), v.std(1), v.min(1)
            for ci, c in enumerate(capacities):
                out[c].setdefault(m, {})[k] = {
                    "mean": float(mean[ci]),
                    "std": float(std[ci]),
                    "min": float(vmin[ci]),
                }
    return out
