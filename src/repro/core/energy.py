"""Transaction-level energy / delay / EDP model (paper §4 calculations).

Per the paper: "we multiply the number of read and write transactions by
the corresponding latency and energy values for those operations"; leakage
energy integrates leakage power over the execution window; DRAM energy and
latency are added where stated (Figs 5, 6, 9).

Two views of the same math: the scalar ``evaluate``/``relative`` pair
(one ``MemoryProfile`` against one ``CachePPA``), and the array-native
``evaluate_arrays``/``relative_arrays`` pair that ``iso``/``scaling``/
``crosslayer`` and the traffic-engine claim loss (``core.traffic``) run
over whole traffic tensors — plain ``jnp`` broadcasting, jittable and
differentiable end-to-end.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Dict, Optional

from repro.core.cache_model import CachePPA
from repro.core.constants import DRAM_ENERGY_NJ, DRAM_LATENCY_NS

if TYPE_CHECKING:  # runtime import would cycle through core.traffic
    from repro.core.profiles import MemoryProfile


@dataclasses.dataclass(frozen=True)
class EnergyReport:
    """All paper §4 quantities for one (workload, cache) pair. Units:
    energy nJ, delay ns."""
    workload: str
    mem: str
    dynamic_nj: float
    leakage_nj: float
    dram_nj: float
    delay_ns: float           # L2-only execution window
    delay_dram_ns: float      # incl. DRAM transactions

    @property
    def total_nj(self) -> float:
        return self.dynamic_nj + self.leakage_nj

    @property
    def total_with_dram_nj(self) -> float:
        return self.dynamic_nj + self.leakage_nj + self.dram_nj

    @property
    def edp(self) -> float:   # no DRAM (Fig 9 top)
        return self.total_nj * self.delay_ns

    @property
    def edp_with_dram(self) -> float:  # Figs 5/6/9 bottom
        return self.total_with_dram_nj * self.delay_dram_ns


def evaluate(p: MemoryProfile, ppa: CachePPA,
             dram_transactions: Optional[float] = None) -> EnergyReport:
    """Energy/delay of running profile ``p`` against cache ``ppa``."""
    n_dram = p.dram if dram_transactions is None else dram_transactions
    dyn = p.l2_reads * ppa.read_energy_nj + p.l2_writes * ppa.write_energy_nj
    delay = (p.l2_reads * ppa.read_latency_ns
             + p.l2_writes * ppa.write_latency_ns)
    delay_dram = delay + n_dram * DRAM_LATENCY_NS
    # mW * ns = pJ -> /1000 nJ; leakage integrates over the DRAM-inclusive
    # execution window (the cache leaks while DRAM is serving misses too)
    leak = ppa.leakage_mw * delay_dram * 1e-3
    dram_e = n_dram * DRAM_ENERGY_NJ
    return EnergyReport(
        workload=p.label, mem=ppa.mem,
        dynamic_nj=dyn, leakage_nj=leak, dram_nj=dram_e,
        delay_ns=delay, delay_dram_ns=delay_dram,
    )


def relative(base: EnergyReport, other: EnergyReport) -> Dict[str, float]:
    """Normalized-to-base metrics (paper plots are normalized to SRAM)."""
    return {
        "dynamic": other.dynamic_nj / base.dynamic_nj,
        "leakage": other.leakage_nj / base.leakage_nj,
        "total": other.total_nj / base.total_nj,
        "delay": other.delay_ns / base.delay_ns,
        "edp": other.edp / base.edp,
        "edp_with_dram": other.edp_with_dram / base.edp_with_dram,
    }


# --- array-native view (whole traffic tensors) ------------------------------

# PPA fields consumed by the energy math, in the order ``ppa_scalars`` emits
PPA_ENERGY_FIELDS = ("read_energy_nj", "write_energy_nj", "read_latency_ns",
                     "write_latency_ns", "leakage_mw")

# metric keys shared by ``relative`` and ``relative_arrays``
RELATIVE_METRICS = ("dynamic", "leakage", "total", "delay", "edp",
                    "edp_with_dram")


def ppa_scalars(ppa: CachePPA) -> Dict[str, float]:
    """The energy-relevant fields of one tuned config, as plain floats
    (broadcast against traffic arrays of any shape)."""
    return {f: float(getattr(ppa, f)) for f in PPA_ENERGY_FIELDS}


def evaluate_arrays(reads, writes, dram, ppa: Dict,
                    leak_scale: float = 1.0) -> Dict:
    """Array version of ``evaluate``: all §4 quantities for traffic arrays
    of any (broadcastable) shape against one PPA field dict — the same
    formulas, element-wise.  ``ppa`` values may themselves be arrays
    (e.g. a capacity axis) as long as they broadcast against the traffic.
    ``leak_scale`` derates leakage (crosslayer's SRAM tier)."""
    dyn = reads * ppa["read_energy_nj"] + writes * ppa["write_energy_nj"]
    delay = (reads * ppa["read_latency_ns"]
             + writes * ppa["write_latency_ns"])
    delay_dram = delay + dram * DRAM_LATENCY_NS
    leak = leak_scale * ppa["leakage_mw"] * delay_dram * 1e-3
    dram_e = dram * DRAM_ENERGY_NJ
    total = dyn + leak
    return {
        "dynamic": dyn, "leakage": leak, "total": total,
        "dram": dram_e, "delay": delay, "delay_dram": delay_dram,
        "edp": total * delay,
        "edp_with_dram": (total + dram_e) * delay_dram,
    }


def relative_arrays(base: Dict, other: Dict) -> Dict:
    """Array version of ``relative`` — element-wise normalized metrics."""
    return {k: other[k] / base[k] for k in RELATIVE_METRICS}
