"""Transaction-level energy / delay / EDP model (paper §4 calculations).

Per the paper: "we multiply the number of read and write transactions by
the corresponding latency and energy values for those operations"; leakage
energy integrates leakage power over the execution window; DRAM energy and
latency are added where stated (Figs 5, 6, 9). All functions are JAX-
vectorizable scalars (plain float math also works).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.cache_model import CachePPA
from repro.core.constants import DRAM_ENERGY_NJ, DRAM_LATENCY_NS
from repro.core.profiles import MemoryProfile


@dataclasses.dataclass(frozen=True)
class EnergyReport:
    """All paper §4 quantities for one (workload, cache) pair. Units:
    energy nJ, delay ns."""
    workload: str
    mem: str
    dynamic_nj: float
    leakage_nj: float
    dram_nj: float
    delay_ns: float           # L2-only execution window
    delay_dram_ns: float      # incl. DRAM transactions

    @property
    def total_nj(self) -> float:
        return self.dynamic_nj + self.leakage_nj

    @property
    def total_with_dram_nj(self) -> float:
        return self.dynamic_nj + self.leakage_nj + self.dram_nj

    @property
    def edp(self) -> float:   # no DRAM (Fig 9 top)
        return self.total_nj * self.delay_ns

    @property
    def edp_with_dram(self) -> float:  # Figs 5/6/9 bottom
        return self.total_with_dram_nj * self.delay_dram_ns


def evaluate(p: MemoryProfile, ppa: CachePPA,
             dram_transactions: Optional[float] = None) -> EnergyReport:
    """Energy/delay of running profile ``p`` against cache ``ppa``."""
    n_dram = p.dram if dram_transactions is None else dram_transactions
    dyn = p.l2_reads * ppa.read_energy_nj + p.l2_writes * ppa.write_energy_nj
    delay = (p.l2_reads * ppa.read_latency_ns
             + p.l2_writes * ppa.write_latency_ns)
    delay_dram = delay + n_dram * DRAM_LATENCY_NS
    # mW * ns = pJ -> /1000 nJ; leakage integrates over the DRAM-inclusive
    # execution window (the cache leaks while DRAM is serving misses too)
    leak = ppa.leakage_mw * delay_dram * 1e-3
    dram_e = n_dram * DRAM_ENERGY_NJ
    return EnergyReport(
        workload=p.label, mem=ppa.mem,
        dynamic_nj=dyn, leakage_nj=leak, dram_nj=dram_e,
        delay_ns=delay, delay_dram_ns=delay_dram,
    )


def relative(base: EnergyReport, other: EnergyReport) -> Dict[str, float]:
    """Normalized-to-base metrics (paper plots are normalized to SRAM)."""
    return {
        "dynamic": other.dynamic_nj / base.dynamic_nj,
        "leakage": other.leakage_nj / base.leakage_nj,
        "total": other.total_nj / base.total_nj,
        "delay": other.delay_ns / base.delay_ns,
        "edp": other.edp / base.edp,
        "edp_with_dram": other.edp_with_dram / base.edp_with_dram,
    }
